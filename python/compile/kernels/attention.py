"""L1 Pallas attention kernels — the compute hot-spot of the served model.

Two kernels, mirroring the paper's two phases:

- :func:`flash_prefill_attention` — FlashAttention-style causal attention
  for the compute-bound prefill phase.  Tiled with ``BlockSpec`` so each
  grid step holds one (block_q x head_dim) query tile plus the K/V stripe
  of its KV head in VMEM, accumulating with online softmax.  The grid is
  (n_heads, n_q_blocks): the TPU analog of the threadblock decomposition
  the paper analyses for wave quantization (the L3 simulator applies
  Eq. 1 to exactly this grid).
- :func:`decode_attention` — single-token attention over a padded KV
  cache for the memory-bound decode phase, one grid step per
  (batch element, KV head), GQA query heads packed per step.

Both run under ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads.  Correctness is pinned to ``ref.py`` by the pytest +
hypothesis suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

# Default tile sizes.  For the tiny served model (head_dim 32, seq <= 192)
# a (16 x 32)-float32 Q tile plus a (32 x 32) K/V tile is ~6 KiB of VMEM
# per step — far under the ~16 MiB/core budget; on a real TPU these would
# be raised to multiples of 128 to fill the MXU (see DESIGN.md
# §Hardware-Adaptation).
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 32


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq, scale):
    """One grid step: queries [block_q, hd] of one head vs all K/V chunks."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # [block_q, hd]
    k_all = k_ref[0].astype(jnp.float32)  # [seq, hd] — VMEM-resident stripe
    v_all = v_ref[0].astype(jnp.float32)

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_chunk = jax.lax.dynamic_slice_in_dim(k_all, j * block_k, block_k, axis=0)
        v_chunk = jax.lax.dynamic_slice_in_dim(v_all, j * block_k, block_k, axis=0)
        s = jnp.dot(q, k_chunk.T) * scale  # [block_q, block_k]
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + jnp.dot(p, v_chunk)
        return m_cur, l_cur, acc

    n_chunks = seq // block_k
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, q.shape[-1]), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_prefill_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Causal GQA attention for the prefill phase.

    q: [n_heads, seq, head_dim]; k, v: [n_kv_heads, seq, head_dim].
    Returns [n_heads, seq, head_dim].  ``seq`` must be divisible by both
    block sizes (the AOT buckets guarantee this).
    """
    n_heads, seq, head_dim = q.shape
    n_kv = k.shape[0]
    assert n_heads % n_kv == 0, "query heads must be a multiple of KV heads"
    n_rep = n_heads // n_kv
    block_q = min(block_q, seq)
    block_k = min(block_k, seq)
    assert seq % block_q == 0 and seq % block_k == 0
    scale = 1.0 / (head_dim ** 0.5)

    grid = (n_heads, seq // block_q)
    kernel = functools.partial(
        _prefill_kernel, block_q=block_q, block_k=block_k, seq=seq, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, head_dim), lambda h, qi: (h, qi, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda h, qi: (h // n_rep, 0, 0)),
            pl.BlockSpec((1, seq, head_dim), lambda h, qi: (h // n_rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, head_dim), lambda h, qi: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)


def _decode_kernel(ctx_ref, q_ref, kc_ref, vc_ref, kn_ref, vn_ref, o_ref, *, max_ctx, scale):
    """One grid step: all GQA query heads of one (batch, kv_head) pair."""
    ctx = ctx_ref[0]
    q = q_ref[0].astype(jnp.float32)  # [n_rep, hd]
    kc = kc_ref[0, 0].astype(jnp.float32)  # [max_ctx, hd]
    vc = vc_ref[0, 0].astype(jnp.float32)
    kn = kn_ref[0, 0].astype(jnp.float32)  # [hd]
    vn = vn_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, kc.T) * scale  # [n_rep, max_ctx]
    pos = jax.lax.iota(jnp.int32, max_ctx)
    s = jnp.where((pos < ctx)[None, :], s, NEG_INF)
    s_self = jnp.sum(q * kn[None, :], axis=-1) * scale  # [n_rep]

    m = jnp.maximum(s.max(axis=-1), s_self)
    p = jnp.exp(s - m[:, None])
    p_self = jnp.exp(s_self - m)
    denom = p.sum(axis=-1) + p_self
    out = (jnp.dot(p, vc) + p_self[:, None] * vn[None, :]) / denom[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, k_new, v_new, ctx_lens):
    """Single-token GQA decode attention (see ``ref.decode_attention_ref``).

    q:        [batch, n_heads, head_dim]
    k_cache:  [batch, n_kv_heads, max_ctx, head_dim] (padded; positions >=
              ctx_lens[b] are ignored)
    k_new/v_new: [batch, n_kv_heads, head_dim] — current token's K/V, kept
              separate so the Rust KV manager appends them host-side.
    ctx_lens: [batch] int32.
    Returns [batch, n_heads, head_dim].
    """
    batch, n_heads, head_dim = q.shape
    n_kv, max_ctx = k_cache.shape[1], k_cache.shape[2]
    n_rep = n_heads // n_kv
    scale = 1.0 / (head_dim ** 0.5)

    grid = (batch, n_kv)
    kernel = functools.partial(_decode_kernel, max_ctx=max_ctx, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kh: (b,)),
            pl.BlockSpec((1, n_rep, head_dim), lambda b, kh: (b, kh, 0)),
            pl.BlockSpec((1, 1, max_ctx, head_dim), lambda b, kh: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, max_ctx, head_dim), lambda b, kh: (b, kh, 0, 0)),
            pl.BlockSpec((1, 1, head_dim), lambda b, kh: (b, kh, 0)),
            pl.BlockSpec((1, 1, head_dim), lambda b, kh: (b, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_rep, head_dim), lambda b, kh: (b, kh, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(ctx_lens, q, k_cache, v_cache, k_new, v_new)
