"""Pure-jnp reference oracles for the Pallas attention kernels.

These are the ground truth the L1 kernels are validated against (pytest +
hypothesis in ``python/tests/``).  They are deliberately written in the
most direct way possible — full score matrices, explicit masks — so that a
mismatch always indicts the kernel, not the oracle.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """Expand KV heads to query heads for grouped-query attention.

    x: [n_kv_heads, seq, head_dim] -> [n_kv_heads * n_rep, seq, head_dim]
    """
    if n_rep == 1:
        return x
    nk, s, d = x.shape
    return jnp.broadcast_to(x[:, None, :, :], (nk, n_rep, s, d)).reshape(nk * n_rep, s, d)


def prefill_attention_ref(q, k, v):
    """Causal self-attention over a full sequence (one request).

    q: [n_heads, seq, head_dim]; k, v: [n_kv_heads, seq, head_dim].
    Returns [n_heads, seq, head_dim].
    """
    n_heads, seq, head_dim = q.shape
    n_kv = k.shape[0]
    k = repeat_kv(k, n_heads // n_kv)
    v = repeat_kv(v, n_heads // n_kv)
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, dtype=q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((seq, seq), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def decode_attention_ref(q, k_cache, v_cache, k_new, v_new, ctx_lens):
    """Single-token decode attention over a padded KV cache plus the
    current token's own K/V.

    q:        [batch, n_heads, head_dim]   — current-token queries
    k_cache:  [batch, n_kv_heads, max_ctx, head_dim] (positions >= ctx_lens
              are padding and must be masked out)
    v_cache:  same shape as k_cache
    k_new:    [batch, n_kv_heads, head_dim] — current token's key
    v_new:    [batch, n_kv_heads, head_dim]
    ctx_lens: [batch] int32 — number of valid cache positions per request
    Returns   [batch, n_heads, head_dim].
    """
    b, n_heads, head_dim = q.shape
    n_kv = k_cache.shape[1]
    max_ctx = k_cache.shape[2]
    n_rep = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, dtype=q.dtype))

    # [batch, n_heads, max_ctx, head_dim]
    kc = jnp.repeat(k_cache, n_rep, axis=1)
    vc = jnp.repeat(v_cache, n_rep, axis=1)
    kn = jnp.repeat(k_new, n_rep, axis=1)  # [batch, n_heads, head_dim]
    vn = jnp.repeat(v_new, n_rep, axis=1)

    scores = jnp.einsum("bhd,bhkd->bhk", q, kc) * scale
    pos = jnp.arange(max_ctx)[None, None, :]
    valid = pos < ctx_lens[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    score_self = jnp.einsum("bhd,bhd->bh", q, kn)[..., None] * scale  # [b,h,1]
    all_scores = jnp.concatenate([scores, score_self], axis=-1)
    m = all_scores.max(axis=-1, keepdims=True)
    p = jnp.exp(all_scores - m)
    denom = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhk,bhkd->bhd", p[..., :-1], vc) + p[..., -1:] * vn
    return out / denom
