"""AOT lowering: JAX model -> HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids so text round-trips cleanly (see
/opt/xla-example/README.md).

Emits, per shape bucket:
  artifacts/prefill_{seq}.hlo.txt    (w0..wN, tokens[seq] i32, true_len i32)
      -> (first_token i32, k_cache [L,kv,seq,hd] f32, v_cache f32)
  artifacts/decode_{bs}.hlo.txt      (w0..wN, tokens[bs] i32, ctx_lens[bs] i32,
                                      k_cache [L,bs,kv,ctx,hd] f32, v_cache f32)
      -> (next_tokens [bs] i32, k_new [L,bs,kv,hd] f32, v_new f32)
plus artifacts/meta.json (config, weight ABI, bucket lists).

Run via `make artifacts` (no-op when inputs are unchanged).  Python never
runs on the request path: these files are everything Rust needs.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, param_order, prefill_fn_flat, decode_fn_flat


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (return_tuple=True ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs(cfg: ModelConfig):
    return [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in param_order(cfg)]


def lower_prefill(cfg: ModelConfig, seq: int) -> str:
    fn, _ = prefill_fn_flat(cfg)
    specs = weight_specs(cfg) + [
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_decode(cfg: ModelConfig, bs: int) -> str:
    fn, _ = decode_fn_flat(cfg)
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, bs, cfg.n_kv_heads, cfg.max_ctx, cfg.head_dim), jnp.float32
    )
    specs = weight_specs(cfg) + [
        jax.ShapeDtypeStruct((bs,), jnp.int32),
        jax.ShapeDtypeStruct((bs,), jnp.int32),
        cache,
        cache,
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_meta(cfg: ModelConfig) -> dict:
    return {
        "config": {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "ffn_dim": cfg.ffn_dim,
            "head_dim": cfg.head_dim,
            "max_ctx": cfg.max_ctx,
            "rope_theta": cfg.rope_theta,
            "norm_eps": cfg.norm_eps,
        },
        "weights": [
            {"name": name, "shape": list(shape)} for name, shape in param_order(cfg)
        ],
        "prefill_buckets": list(cfg.prefill_buckets),
        "decode_buckets": list(cfg.decode_buckets),
        "prefill_artifacts": {
            str(s): f"prefill_{s}.hlo.txt" for s in cfg.prefill_buckets
        },
        "decode_artifacts": {str(b): f"decode_{b}.hlo.txt" for b in cfg.decode_buckets},
    }


def source_fingerprint() -> str:
    """Hash of the compile-path sources, for the no-op rebuild check."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()

    here = os.path.dirname(os.path.abspath(__file__))
    out_dir = args.out_dir or os.path.join(here, "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    fp = source_fingerprint()
    stamp = os.path.join(out_dir, ".stamp")
    if not args.force and os.path.exists(stamp):
        with open(stamp) as f:
            if f.read().strip() == fp:
                print(f"artifacts up to date in {out_dir} (fingerprint {fp[:12]})")
                return

    cfg = ModelConfig()
    meta = build_meta(cfg)

    for seq in cfg.prefill_buckets:
        text = lower_prefill(cfg, seq)
        path = os.path.join(out_dir, meta["prefill_artifacts"][str(seq)])
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for bs in cfg.decode_buckets:
        text = lower_decode(cfg, bs)
        path = os.path.join(out_dir, meta["decode_artifacts"][str(bs)])
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    with open(stamp, "w") as f:
        f.write(fp)
    print(f"wrote {out_dir}/meta.json; done")


if __name__ == "__main__":
    main()
