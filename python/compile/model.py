"""L2: the served model — a Llama-style transformer in JAX.

Defines the two phase functions the Rust coordinator executes via PJRT:

- :func:`prefill_step` — process one (padded) prompt, return the first
  generated token plus the post-RoPE KV cache for every layer.
- :func:`decode_step`  — one iteration for a (padded) decode batch over a
  padded KV cache, returning the next tokens plus each layer's new K/V
  vectors (appended host-side by the Rust KV manager).

Architecture: RMSNorm, rotary embeddings, grouped-query attention (via the
L1 Pallas kernels), SwiGLU MLP, untied LM head — i.e. the Llama-3 block
structure at toy scale.  Both functions are pure (weights are arguments),
so AOT lowering fixes only shapes, and Rust owns the weights.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import flash_prefill_attention, decode_attention


@dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the served model.

    The default is the "tiny" config used by the end-to-end example
    (~4.4M parameters).  The analytical Llama-3.1-8B descriptor used by the
    GPU simulator lives on the Rust side (`model::llama`); this config only
    shapes the real, PJRT-executed model.
    """

    vocab_size: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    ffn_dim: int = 704
    max_ctx: int = 192  # decode KV-cache capacity (prefill bucket + output budget)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    prefill_buckets: tuple = (16, 32, 64, 128)
    decode_buckets: tuple = (1, 2, 4, 8)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def param_order(cfg: ModelConfig):
    """The canonical flattened weight list: (name, shape) pairs.

    This exact order is recorded in artifacts/meta.json and is the ABI
    between aot.py and the Rust weight generator (`runtime::weights`).
    """
    d, hd = cfg.d_model, cfg.head_dim
    out = [("embed", (cfg.vocab_size, d))]
    for i in range(cfg.n_layers):
        out += [
            (f"layer{i}.attn_norm", (d,)),
            (f"layer{i}.wq", (d, cfg.n_heads * hd)),
            (f"layer{i}.wk", (d, cfg.kv_dim)),
            (f"layer{i}.wv", (d, cfg.kv_dim)),
            (f"layer{i}.wo", (cfg.n_heads * hd, d)),
            (f"layer{i}.mlp_norm", (d,)),
            (f"layer{i}.w_gate", (d, cfg.ffn_dim)),
            (f"layer{i}.w_up", (d, cfg.ffn_dim)),
            (f"layer{i}.w_down", (cfg.ffn_dim, d)),
        ]
    out += [("out_norm", (d,)), ("lm_head", (d, cfg.vocab_size))]
    return out


def init_params(cfg: ModelConfig, seed: int = 0):
    """Random-normal weights (test/demo use; Rust generates its own)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_order(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = 0.05 * jax.random.normal(sub, shape, jnp.float32)
    return params


def params_to_flat(cfg: ModelConfig, params: dict):
    return [params[name] for name, _ in param_order(cfg)]


def flat_to_params(cfg: ModelConfig, flat):
    return {name: w for (name, _), w in zip(param_order(cfg), flat)}


def rms_norm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_freqs(cfg: ModelConfig):
    hd = cfg.head_dim
    return cfg.rope_theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))


def apply_rope(x, positions, cfg: ModelConfig):
    """Rotate-half rotary embedding.

    x: [..., seq, head_dim]; positions: [seq] (broadcast over leading dims).
    """
    freqs = rope_freqs(cfg)  # [hd/2]
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [seq, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(cfg, params, i, x):
    """Project x [n, d] -> q [heads, n, hd], k/v [kv_heads, n, hd]."""
    n = x.shape[0]
    q = (x @ params[f"layer{i}.wq"]).reshape(n, cfg.n_heads, cfg.head_dim)
    k = (x @ params[f"layer{i}.wk"]).reshape(n, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ params[f"layer{i}.wv"]).reshape(n, cfg.n_kv_heads, cfg.head_dim)
    return (
        jnp.transpose(q, (1, 0, 2)),
        jnp.transpose(k, (1, 0, 2)),
        jnp.transpose(v, (1, 0, 2)),
    )


def _mlp(cfg, params, i, x):
    gate = jax.nn.silu(x @ params[f"layer{i}.w_gate"])
    up = x @ params[f"layer{i}.w_up"]
    return (gate * up) @ params[f"layer{i}.w_down"]


def prefill_step(cfg: ModelConfig, params: dict, tokens, true_len):
    """Prefill one request.

    tokens:   [seq] int32, padded to the bucket size (pad ids arbitrary —
              causal masking keeps them from influencing real positions).
    true_len: scalar int32, number of real tokens.
    Returns (first_token i32, k_cache [L, n_kv, seq, hd], v_cache same).
    Cache entries beyond true_len are garbage; the Rust KV manager only
    copies the first true_len positions into its paged pool.
    """
    seq = tokens.shape[0]
    positions = jnp.arange(seq, dtype=jnp.int32)
    h = jnp.take(params["embed"], tokens, axis=0)  # [seq, d]

    k_layers, v_layers = [], []
    for i in range(cfg.n_layers):
        x = rms_norm(h, params[f"layer{i}.attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, params, i, x)
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
        attn = flash_prefill_attention(q, k, v)  # [heads, seq, hd]
        attn = jnp.transpose(attn, (1, 0, 2)).reshape(seq, -1)
        h = h + attn @ params[f"layer{i}.wo"]
        x = rms_norm(h, params[f"layer{i}.mlp_norm"], cfg.norm_eps)
        h = h + _mlp(cfg, params, i, x)
        k_layers.append(k)
        v_layers.append(v)

    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    last = jax.lax.dynamic_index_in_dim(h, true_len - 1, axis=0, keepdims=False)
    logits = last @ params["lm_head"]
    first_token = jnp.argmax(logits).astype(jnp.int32)
    return first_token, jnp.stack(k_layers), jnp.stack(v_layers)


def decode_step(cfg: ModelConfig, params: dict, tokens, ctx_lens, k_cache, v_cache):
    """One decode iteration for a padded batch.

    tokens:   [batch] int32 — the most recent token of each request.
    ctx_lens: [batch] int32 — valid KV positions per request (0 for padding
              slots; their outputs are discarded by the coordinator).
    k_cache:  [L, batch, n_kv, max_ctx, hd] padded post-RoPE keys.
    v_cache:  same shape, values.
    Returns (next_tokens [batch] i32,
             k_new [L, batch, n_kv, hd], v_new same) — the current token's
    K/V per layer, which Rust appends to its paged pool.
    """
    batch = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0)  # [batch, d]

    k_news, v_news = [], []
    for i in range(cfg.n_layers):
        x = rms_norm(h, params[f"layer{i}.attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, params, i, x)  # q: [heads, batch, hd]
        # Each batch element sits at its own position: rope indexed per
        # element (the "seq" axis of apply_rope is the batch here).
        q = apply_rope(q, ctx_lens, cfg)
        k = apply_rope(k, ctx_lens, cfg)
        q_b = jnp.transpose(q, (1, 0, 2))  # [batch, heads, hd]
        k_b = jnp.transpose(k, (1, 0, 2))  # [batch, kv, hd]
        v_b = jnp.transpose(v, (1, 0, 2))
        attn = decode_attention(q_b, k_cache[i], v_cache[i], k_b, v_b, ctx_lens)
        h = h + attn.reshape(batch, -1) @ params[f"layer{i}.wo"]
        x = rms_norm(h, params[f"layer{i}.mlp_norm"], cfg.norm_eps)
        h = h + _mlp(cfg, params, i, x)
        k_news.append(k_b)
        v_news.append(v_b)

    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]  # [batch, vocab]
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, jnp.stack(k_news), jnp.stack(v_news)


def prefill_fn_flat(cfg: ModelConfig):
    """Positional-args wrapper for AOT lowering: (w0..wN, tokens, true_len)."""
    n_w = len(param_order(cfg))

    def fn(*args):
        params = flat_to_params(cfg, args[:n_w])
        tokens, true_len = args[n_w], args[n_w + 1]
        return prefill_step(cfg, params, tokens, true_len)

    return fn, n_w


def decode_fn_flat(cfg: ModelConfig):
    """Positional-args wrapper: (w0..wN, tokens, ctx_lens, k_cache, v_cache)."""
    n_w = len(param_order(cfg))

    def fn(*args):
        params = flat_to_params(cfg, args[:n_w])
        tokens, ctx_lens, k_cache, v_cache = args[n_w : n_w + 4]
        return decode_step(cfg, params, tokens, ctx_lens, k_cache, v_cache)

    return fn, n_w
