"""AOT pipeline tests: artifacts are well-formed and the ABI is honest.

These tests lower small bucket shapes in-process (not the full artifact
set, which `make artifacts` owns) and verify the HLO text has the
parameter/result signature the Rust runtime (`runtime::pjrt`) relies on.
"""

import json
import os
import re

import jax.numpy as jnp
import pytest

from compile.aot import build_meta, lower_decode, lower_prefill, source_fingerprint
from compile.model import ModelConfig, param_order

SMALL = ModelConfig(
    vocab_size=64,
    d_model=16,
    n_layers=1,
    n_heads=2,
    n_kv_heads=1,
    ffn_dim=24,
    max_ctx=32,
    prefill_buckets=(16,),
    decode_buckets=(1, 2),
)


@pytest.fixture(scope="module")
def prefill_hlo():
    return lower_prefill(SMALL, 16)


@pytest.fixture(scope="module")
def decode_hlo():
    return lower_decode(SMALL, 2)


def test_prefill_hlo_is_text_module(prefill_hlo):
    assert prefill_hlo.startswith("HloModule")
    assert "ENTRY" in prefill_hlo


def test_prefill_param_count(prefill_hlo):
    """weights + tokens + true_len parameters must all appear."""
    n_expected = len(param_order(SMALL)) + 2
    params = set(re.findall(r"parameter\((\d+)\)", prefill_hlo))
    assert len(params) == n_expected


def test_prefill_result_is_tuple_of_three(prefill_hlo):
    # return_tuple=True: result shape is (s32[], f32[...], f32[...]),
    # recorded in the entry_computation_layout header.
    m = re.search(r"->\((.*?)\)\}", prefill_hlo.splitlines()[0])
    assert m, "entry signature not found"
    result = m.group(1)
    assert result.startswith("s32[]") and result.count("f32[") == 2


def test_decode_param_count(decode_hlo):
    n_expected = len(param_order(SMALL)) + 4
    params = set(re.findall(r"parameter\((\d+)\)", decode_hlo))
    assert len(params) == n_expected


def test_decode_cache_shape_in_signature(decode_hlo):
    # k_cache shape [L=1, bs=2, kv=1, ctx=32, hd=8]
    assert "f32[1,2,1,32,8]" in decode_hlo


def test_meta_weights_match_param_order():
    meta = build_meta(SMALL)
    assert [w["name"] for w in meta["weights"]] == [n for n, _ in param_order(SMALL)]
    assert [tuple(w["shape"]) for w in meta["weights"]] == [
        s for _, s in param_order(SMALL)
    ]


def test_meta_json_serializable():
    meta = build_meta(SMALL)
    text = json.dumps(meta)
    assert json.loads(text) == meta


def test_meta_config_fields():
    meta = build_meta(SMALL)
    cfg = meta["config"]
    for key in (
        "vocab_size", "d_model", "n_layers", "n_heads", "n_kv_heads",
        "ffn_dim", "head_dim", "max_ctx", "rope_theta", "norm_eps",
    ):
        assert key in cfg
    assert cfg["head_dim"] == SMALL.head_dim


def test_fingerprint_stable():
    assert source_fingerprint() == source_fingerprint()
    assert len(source_fingerprint()) == 64


def test_repo_artifacts_exist_if_built():
    """If `make artifacts` has run, the artifact set must be complete."""
    here = os.path.dirname(os.path.abspath(__file__))
    art = os.path.abspath(os.path.join(here, "..", "..", "artifacts"))
    meta_path = os.path.join(art, "meta.json")
    if not os.path.exists(meta_path):
        pytest.skip("artifacts not built")
    with open(meta_path) as f:
        meta = json.load(f)
    for rel in list(meta["prefill_artifacts"].values()) + list(
        meta["decode_artifacts"].values()
    ):
        path = os.path.join(art, rel)
        assert os.path.exists(path), f"missing artifact {rel}"
        with open(path) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule")
