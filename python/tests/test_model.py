"""L2 correctness: the phase functions compose into correct generation.

The decisive test is prefill/decode *consistency*: greedily generating
tokens through the bucketed prefill_step + decode_step pipeline (exactly
what the Rust runtime does) must match a naive full-recompute reference
that re-runs unchunked prefill over the growing sequence each step.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    ModelConfig,
    apply_rope,
    decode_step,
    flat_to_params,
    init_params,
    param_order,
    params_to_flat,
    prefill_step,
    rms_norm,
)

CFG = ModelConfig(
    vocab_size=128,
    d_model=32,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    ffn_dim=48,
    max_ctx=48,
    prefill_buckets=(16, 32),
    decode_buckets=(1, 2, 4),
)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=7)


def reference_next_token(cfg, params, tokens):
    """Full unchunked forward over `tokens`; greedy next token."""
    t = jnp.asarray(tokens, jnp.int32)
    first, _, _ = prefill_step(cfg, params, t, jnp.asarray(len(tokens), jnp.int32))
    return int(first)


def pad_to(arr, n, axis=0):
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, n - arr.shape[axis])
    return jnp.pad(arr, pad)


class TestPrefill:
    def test_shapes(self, params):
        tokens = jnp.arange(16, dtype=jnp.int32) % CFG.vocab_size
        tok, kc, vc = prefill_step(CFG, params, tokens, jnp.asarray(10, jnp.int32))
        assert tok.shape == () and tok.dtype == jnp.int32
        assert kc.shape == (CFG.n_layers, CFG.n_kv_heads, 16, CFG.head_dim)
        assert vc.shape == kc.shape

    def test_padding_invariance(self, params):
        """Same prompt in a larger bucket must give the same first token
        and identical KV entries for the real positions."""
        prompt = jnp.asarray([3, 17, 42, 99, 5, 23, 8, 61, 77, 2], jnp.int32)
        tl = jnp.asarray(len(prompt), jnp.int32)
        t16, k16, v16 = prefill_step(CFG, params, pad_to(prompt, 16), tl)
        t32, k32, v32 = prefill_step(CFG, params, pad_to(prompt, 32), tl)
        assert int(t16) == int(t32)
        np.testing.assert_allclose(
            k16[:, :, :10], k32[:, :, :10], rtol=1e-4, atol=1e-5
        )
        np.testing.assert_allclose(
            v16[:, :, :10], v32[:, :, :10], rtol=1e-4, atol=1e-5
        )

    def test_pad_token_value_irrelevant(self, params):
        prompt = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
        tl = jnp.asarray(5, jnp.int32)
        a = prefill_step(CFG, params, pad_to(prompt, 16), tl)[0]
        noisy = jnp.concatenate([prompt, jnp.full((11,), 111, jnp.int32)])
        b = prefill_step(CFG, params, noisy, tl)[0]
        assert int(a) == int(b)

    def test_deterministic(self, params):
        tokens = jnp.arange(16, dtype=jnp.int32)
        tl = jnp.asarray(16, jnp.int32)
        a = prefill_step(CFG, params, tokens, tl)
        b = prefill_step(CFG, params, tokens, tl)
        assert int(a[0]) == int(b[0])
        np.testing.assert_array_equal(a[1], b[1])


class TestDecode:
    def test_shapes(self, params):
        bs = 2
        cache = jnp.zeros(
            (CFG.n_layers, bs, CFG.n_kv_heads, CFG.max_ctx, CFG.head_dim), jnp.float32
        )
        toks, kn, vn = decode_step(
            CFG,
            params,
            jnp.asarray([5, 9], jnp.int32),
            jnp.asarray([0, 0], jnp.int32),
            cache,
            cache,
        )
        assert toks.shape == (bs,)
        assert kn.shape == (CFG.n_layers, bs, CFG.n_kv_heads, CFG.head_dim)

    def test_batch_slot_independence(self, params):
        """A request's output must not depend on its co-batched neighbours."""
        bs = 4
        rng = np.random.default_rng(0)
        cache_k = jnp.asarray(
            rng.normal(size=(CFG.n_layers, bs, CFG.n_kv_heads, CFG.max_ctx, CFG.head_dim)),
            jnp.float32,
        )
        cache_v = jnp.asarray(
            rng.normal(size=cache_k.shape), jnp.float32
        )
        toks = jnp.asarray([5, 9, 13, 2], jnp.int32)
        cls = jnp.asarray([3, 10, 0, 7], jnp.int32)
        full, _, _ = decode_step(CFG, params, toks, cls, cache_k, cache_v)
        # run slot 1 alone (batch of 1)
        solo, _, _ = decode_step(
            CFG, params, toks[1:2], cls[1:2], cache_k[:, 1:2], cache_v[:, 1:2]
        )
        assert int(full[1]) == int(solo[0])


class TestGenerationConsistency:
    def test_prefill_then_decode_matches_full_recompute(self, params):
        """The bucketed prefill->decode pipeline equals full recompute."""
        prompt = [3, 17, 42, 99, 5, 23, 8, 61]
        n_new = 6

        # Pipeline path (what Rust does).
        tl = jnp.asarray(len(prompt), jnp.int32)
        tok, kc, vc = prefill_step(
            CFG, params, pad_to(jnp.asarray(prompt, jnp.int32), 16), tl
        )
        generated = [int(tok)]
        # Build padded decode cache [L, 1, kv, max_ctx, hd].
        cache_k = pad_to(kc[:, None, :, : len(prompt)], CFG.max_ctx, axis=3)
        cache_v = pad_to(vc[:, None, :, : len(prompt)], CFG.max_ctx, axis=3)
        ctx = len(prompt)
        cur = int(tok)
        for _ in range(n_new - 1):
            toks = jnp.asarray([cur], jnp.int32)
            cls = jnp.asarray([ctx], jnp.int32)
            nxt, kn, vn = decode_step(CFG, params, toks, cls, cache_k, cache_v)
            cache_k = cache_k.at[:, :, :, ctx, :].set(kn)
            cache_v = cache_v.at[:, :, :, ctx, :].set(vn)
            ctx += 1
            cur = int(nxt[0])
            generated.append(cur)

        # Reference path: full recompute each step.
        seq = list(prompt)
        expect = []
        for _ in range(n_new):
            nxt = reference_next_token(CFG, params, seq)
            expect.append(nxt)
            seq.append(nxt)

        assert generated == expect

    def test_decode_cache_append_positions(self, params):
        """KV appended at ctx then used: two singleton steps == one fresh
        decode with the longer explicit cache."""
        rng = np.random.default_rng(1)
        ctx0 = 5
        cache_shape = (CFG.n_layers, 1, CFG.n_kv_heads, CFG.max_ctx, CFG.head_dim)
        ck = jnp.zeros(cache_shape, jnp.float32)
        cv = jnp.zeros(cache_shape, jnp.float32)
        fill_k = jnp.asarray(rng.normal(size=(CFG.n_layers, 1, CFG.n_kv_heads, ctx0, CFG.head_dim)), jnp.float32)
        fill_v = jnp.asarray(rng.normal(size=fill_k.shape), jnp.float32)
        ck = ck.at[:, :, :, :ctx0].set(fill_k)
        cv = cv.at[:, :, :, :ctx0].set(fill_v)

        t0 = jnp.asarray([7], jnp.int32)
        n1, kn, vn = decode_step(CFG, params, t0, jnp.asarray([ctx0], jnp.int32), ck, cv)
        ck2 = ck.at[:, :, :, ctx0].set(kn)
        cv2 = cv.at[:, :, :, ctx0].set(vn)
        n2a, _, _ = decode_step(CFG, params, n1, jnp.asarray([ctx0 + 1], jnp.int32), ck2, cv2)

        # identical fresh run
        n2b, _, _ = decode_step(CFG, params, n1, jnp.asarray([ctx0 + 1], jnp.int32), ck2, cv2)
        assert int(n2a[0]) == int(n2b[0])


class TestComponents:
    def test_rms_norm_scale_invariant_direction(self):
        x = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
        w = jnp.ones((4,))
        a = rms_norm(x, w, 1e-6)
        b = rms_norm(10.0 * x, w, 1e-6)
        np.testing.assert_allclose(a, b, rtol=1e-4)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(2, 8, CFG.head_dim)), jnp.float32)
        pos = jnp.arange(8, dtype=jnp.int32)
        y = apply_rope(x, pos, CFG)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-4
        )

    def test_rope_position_zero_identity(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(1, 1, CFG.head_dim)), jnp.float32)
        y = apply_rope(x, jnp.zeros((1,), jnp.int32), CFG)
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)

    def test_rope_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m - n."""
        rng = np.random.default_rng(5)
        q = jnp.asarray(rng.normal(size=(1, 1, CFG.head_dim)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, CFG.head_dim)), jnp.float32)

        def ip(m, n):
            qm = apply_rope(q, jnp.asarray([m], jnp.int32), CFG)
            kn = apply_rope(k, jnp.asarray([n], jnp.int32), CFG)
            return float(jnp.sum(qm * kn))

        assert abs(ip(3, 1) - ip(7, 5)) < 1e-3
        assert abs(ip(10, 10) - ip(0, 0)) < 1e-3

    def test_param_order_roundtrip(self, params):
        flat = params_to_flat(CFG, params)
        back = flat_to_params(CFG, flat)
        assert set(back.keys()) == set(params.keys())
        for k in params:
            np.testing.assert_array_equal(params[k], back[k])

    def test_param_shapes_match_order(self, params):
        for name, shape in param_order(CFG):
            assert params[name].shape == shape
