"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Fixed cases pin exact behaviours (masking, GQA, numerical stability);
hypothesis sweeps shapes and distributions.  This is the core correctness
signal for the compile path — if these pass, the HLO the Rust runtime
executes contains a correct attention.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_prefill_attention, decode_attention
from compile.kernels.ref import prefill_attention_ref, decode_attention_ref, repeat_kv

RTOL, ATOL = 1e-4, 1e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------- prefill


class TestPrefillFixed:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        q, k, v = rand(rng, 8, 64, 32), rand(rng, 4, 64, 32), rand(rng, 4, 64, 32)
        np.testing.assert_allclose(
            flash_prefill_attention(q, k, v), prefill_attention_ref(q, k, v),
            rtol=RTOL, atol=ATOL,
        )

    def test_mha_no_gqa(self):
        rng = np.random.default_rng(2)
        q, k, v = rand(rng, 4, 32, 16), rand(rng, 4, 32, 16), rand(rng, 4, 32, 16)
        np.testing.assert_allclose(
            flash_prefill_attention(q, k, v), prefill_attention_ref(q, k, v),
            rtol=RTOL, atol=ATOL,
        )

    def test_seq_equals_block(self):
        rng = np.random.default_rng(3)
        q, k, v = rand(rng, 2, 16, 8), rand(rng, 2, 16, 8), rand(rng, 2, 16, 8)
        np.testing.assert_allclose(
            flash_prefill_attention(q, k, v), prefill_attention_ref(q, k, v),
            rtol=RTOL, atol=ATOL,
        )

    def test_first_position_attends_only_self(self):
        """Causality: output at position 0 must equal v normalized by itself."""
        rng = np.random.default_rng(4)
        q, k, v = rand(rng, 2, 32, 8), rand(rng, 2, 32, 8), rand(rng, 2, 32, 8)
        out = flash_prefill_attention(q, k, v)
        # softmax over a single (self) score is 1 -> output == v[0]
        np.testing.assert_allclose(out[:, 0, :], v[:, 0, :], rtol=RTOL, atol=ATOL)

    def test_causality_future_perturbation_invisible(self):
        """Changing K/V at position p must not change outputs before p."""
        rng = np.random.default_rng(5)
        q, k, v = rand(rng, 2, 64, 8), rand(rng, 2, 64, 8), rand(rng, 2, 64, 8)
        base = flash_prefill_attention(q, k, v)
        k2 = k.at[:, 48:, :].set(99.0)
        v2 = v.at[:, 48:, :].set(-99.0)
        pert = flash_prefill_attention(q, k2, v2)
        np.testing.assert_allclose(base[:, :48, :], pert[:, :48, :], rtol=RTOL, atol=ATOL)

    def test_large_magnitude_stability(self):
        rng = np.random.default_rng(6)
        q = 30.0 * rand(rng, 2, 32, 8)
        k = 30.0 * rand(rng, 2, 32, 8)
        v = rand(rng, 2, 32, 8)
        out = flash_prefill_attention(q, k, v)
        assert bool(jnp.isfinite(out).all())
        np.testing.assert_allclose(out, prefill_attention_ref(q, k, v), rtol=1e-3, atol=1e-4)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(7)
        q, k, v = rand(rng, 4, 64, 16), rand(rng, 2, 64, 16), rand(rng, 2, 64, 16)
        a = flash_prefill_attention(q, k, v, block_q=16, block_k=32)
        b = flash_prefill_attention(q, k, v, block_q=32, block_k=16)
        c = flash_prefill_attention(q, k, v, block_q=64, block_k=64)
        np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(a, c, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    seq_pow=st.integers(4, 7),
    heads=st.sampled_from([2, 4, 8]),
    rep=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 5.0]),
)
def test_prefill_hypothesis(seq_pow, heads, rep, hd, seed, scale):
    seq = 2 ** seq_pow
    n_kv = max(1, heads // rep)
    rng = np.random.default_rng(seed)
    q = scale * rand(rng, n_kv * rep, seq, hd)
    k = scale * rand(rng, n_kv, seq, hd)
    v = rand(rng, n_kv, seq, hd)
    np.testing.assert_allclose(
        flash_prefill_attention(q, k, v), prefill_attention_ref(q, k, v),
        rtol=5e-4, atol=5e-5,
    )


# ----------------------------------------------------------------- decode


class TestDecodeFixed:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(11)
        b, H, KV, D, CTX = 4, 8, 4, 32, 48
        args = (
            rand(rng, b, H, D),
            rand(rng, b, KV, CTX, D),
            rand(rng, b, KV, CTX, D),
            rand(rng, b, KV, D),
            rand(rng, b, KV, D),
            jnp.asarray([5, 48, 0, 17], jnp.int32),
        )
        np.testing.assert_allclose(
            decode_attention(*args), decode_attention_ref(*args), rtol=RTOL, atol=ATOL
        )

    def test_zero_context_attends_only_self(self):
        """ctx_len == 0: output must be exactly v_new (softmax over self)."""
        rng = np.random.default_rng(12)
        b, H, KV, D, CTX = 2, 4, 2, 16, 32
        q = rand(rng, b, H, D)
        kc, vc = rand(rng, b, KV, CTX, D), rand(rng, b, KV, CTX, D)
        kn, vn = rand(rng, b, KV, D), rand(rng, b, KV, D)
        cl = jnp.zeros((b,), jnp.int32)
        out = decode_attention(q, kc, vc, kn, vn, cl)
        expect = jnp.repeat(vn, H // KV, axis=1)
        np.testing.assert_allclose(out, expect, rtol=RTOL, atol=ATOL)

    def test_padding_garbage_is_masked(self):
        """Values beyond ctx_len must not affect the output at all."""
        rng = np.random.default_rng(13)
        b, H, KV, D, CTX = 2, 4, 4, 8, 64
        q = rand(rng, b, H, D)
        kc, vc = rand(rng, b, KV, CTX, D), rand(rng, b, KV, CTX, D)
        kn, vn = rand(rng, b, KV, D), rand(rng, b, KV, D)
        cl = jnp.asarray([10, 30], jnp.int32)
        base = decode_attention(q, kc, vc, kn, vn, cl)
        kc2 = kc.at[0, :, 10:, :].set(1e4).at[1, :, 30:, :].set(-1e4)
        vc2 = vc.at[0, :, 10:, :].set(-1e4).at[1, :, 30:, :].set(1e4)
        pert = decode_attention(q, kc2, vc2, kn, vn, cl)
        np.testing.assert_allclose(base, pert, rtol=RTOL, atol=ATOL)

    def test_batch_one(self):
        rng = np.random.default_rng(14)
        args = (
            rand(rng, 1, 8, 32),
            rand(rng, 1, 4, 192, 32),
            rand(rng, 1, 4, 192, 32),
            rand(rng, 1, 4, 32),
            rand(rng, 1, 4, 32),
            jnp.asarray([100], jnp.int32),
        )
        np.testing.assert_allclose(
            decode_attention(*args), decode_attention_ref(*args), rtol=RTOL, atol=ATOL
        )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    rep=st.sampled_from([1, 2, 4]),
    n_kv=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    ctx_cap=st.sampled_from([16, 48, 192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_hypothesis(b, rep, n_kv, hd, ctx_cap, seed):
    rng = np.random.default_rng(seed)
    H = n_kv * rep
    q = rand(rng, b, H, hd)
    kc, vc = rand(rng, b, n_kv, ctx_cap, hd), rand(rng, b, n_kv, ctx_cap, hd)
    kn, vn = rand(rng, b, n_kv, hd), rand(rng, b, n_kv, hd)
    cl = jnp.asarray(rng.integers(0, ctx_cap + 1, size=b), jnp.int32)
    np.testing.assert_allclose(
        decode_attention(q, kc, vc, kn, vn, cl),
        decode_attention_ref(q, kc, vc, kn, vn, cl),
        rtol=5e-4, atol=5e-5,
    )


# ------------------------------------------------------------------ misc


def test_repeat_kv_identity():
    rng = np.random.default_rng(20)
    x = rand(rng, 4, 8, 16)
    assert repeat_kv(x, 1) is x


def test_repeat_kv_layout():
    """Head h of the expanded tensor must be kv head h // n_rep."""
    rng = np.random.default_rng(21)
    x = rand(rng, 2, 4, 8)
    y = repeat_kv(x, 3)
    assert y.shape == (6, 4, 8)
    for h in range(6):
        np.testing.assert_array_equal(y[h], x[h // 3])


def test_prefill_rejects_bad_gqa():
    rng = np.random.default_rng(22)
    q, k, v = rand(rng, 6, 16, 8), rand(rng, 4, 16, 8), rand(rng, 4, 16, 8)
    with pytest.raises(AssertionError):
        flash_prefill_attention(q, k, v)
