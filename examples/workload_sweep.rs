//! Workload sweep: all systems × all datasets × request rates — the
//! interactive version of the Fig. 11 bench, sized to finish quickly.
//!
//! ```bash
//! cargo run --release --offline --example workload_sweep [-- --requests 80]
//! ```

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::cli::Args;
use bullet::util::tbl::{f, ms, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 80);
    let seed = args.get_u64("seed", 42);

    for ds in Dataset::all() {
        let slo = match ds.name {
            "azure-code" => SloSpec::azure_code(),
            "arxiv-summary" => SloSpec::arxiv_summary(),
            _ => SloSpec::sharegpt(),
        };
        let cfg = ServingConfig { slo, ..ServingConfig::default() };
        let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
        let rates: &[f64] = match ds.name {
            "sharegpt" => &[10.0, 20.0],
            "azure-code" => &[4.0, 8.0],
            _ => &[1.0, 2.0],
        };
        for &rate in rates {
            let trace = generate_n_requests(&ds, rate, n, seed);
            let mut t = Table::new(&format!("{} @ {} req/s ({} requests)", ds.name, rate, n))
                .header(&["system", "TTFT ms", "P90 TTFT", "TPOT ms", "tok/s", "SLO %"]);
            for sys in System::evaluation_set() {
                let recs = run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, seed);
                let s = summarize(&recs, &cfg.slo, None);
                t.row(&[
                    sys.label(),
                    ms(s.mean_ttft),
                    ms(s.p90_ttft),
                    ms(s.mean_tpot),
                    f(s.throughput_tok_s, 0),
                    f(s.slo_attainment * 100.0, 1),
                ]);
            }
            t.print();
            println!();
        }
    }
}
