//! Cluster scaling: near-linear trace throughput from multi-replica
//! serving.
//!
//! Serves one heavily saturating Azure-Code trace on 1, 2 and 4
//! simulated A100 replicas of the full Bullet system behind the
//! least-outstanding-KV router, then compares the three routing policies
//! at 4 replicas.  Azure-Code's long prompts make the GPUs *compute*
//! bound on serial prefills, so arrivals outpace one GPU by a wide
//! margin and N replicas serve the trace close to N× faster (the
//! acceptance bar: ≥3× at 4 replicas).  A decode-dominated trace would
//! understate scaling — decode iterations are weight-read-dominated, so
//! one GPU can co-host a large batch nearly as fast as four can.
//!
//! ```bash
//! cargo run --release --offline --example cluster_scaling
//! ```

use bullet::cluster::{ClusterConfig, RouterPolicy};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::{f, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    // Saturating load: 60 req/s of long-prompt traffic into ~1 GPU's
    // worth of prefill capacity — the queue, not the arrival process,
    // bounds makespan.
    let trace = generate_n_requests(&Dataset::azure_code(), 60.0, 240, 42);
    println!(
        "trace: {} Azure-Code requests arriving over {:.1}s",
        trace.len(),
        trace.last().unwrap().arrival
    );

    // 1. Replica scaling under the least-kv router.
    let mut base_throughput = 0.0;
    let mut four_replica_speedup = 0.0;
    let mut t = Table::new("replica scaling (Bullet, least-kv router)").header(&[
        "replicas",
        "makespan (s)",
        "throughput (tok/s)",
        "speedup",
        "P90 TTFT (ms)",
        "per-replica requests",
    ]);
    for replicas in [1usize, 2, 4] {
        let out = server.serve_cluster(
            &trace,
            &ClusterConfig {
                replicas,
                router: RouterPolicy::LeastKv,
                ..Default::default()
            },
        );
        assert_eq!(out.records.len(), trace.len(), "lost records");
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        if replicas == 1 {
            base_throughput = s.throughput_tok_s;
        }
        let speedup = s.throughput_tok_s / base_throughput;
        if replicas == 4 {
            four_replica_speedup = speedup;
        }
        t.row(&[
            replicas.to_string(),
            f(out.virtual_duration, 1),
            f(s.throughput_tok_s, 0),
            format!("{:.2}x", speedup),
            f(s.p90_ttft * 1e3, 0),
            format!("{:?}", out.per_replica_counts()),
        ]);
    }
    t.print();

    // 2. Router comparison at 4 replicas.
    let mut t = Table::new("router comparison (Bullet x4)").header(&[
        "router",
        "makespan (s)",
        "throughput (tok/s)",
        "mean TTFT (ms)",
        "SLO attainment",
    ]);
    for router in RouterPolicy::all() {
        let ccfg = ClusterConfig { replicas: 4, router, ..Default::default() };
        let out = server.serve_cluster(&trace, &ccfg);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        t.row(&[
            router.label().to_string(),
            f(out.virtual_duration, 1),
            f(s.throughput_tok_s, 0),
            f(s.mean_ttft * 1e3, 0),
            f(s.slo_attainment * 100.0, 1) + "%",
        ]);
    }
    t.print();

    println!(
        "4-replica speedup: {:.2}x {}",
        four_replica_speedup,
        if four_replica_speedup >= 3.0 {
            "(>= 3x: near-linear scaling confirmed)"
        } else {
            "(BELOW the 3x near-linear bar!)"
        }
    );
    assert!(
        four_replica_speedup >= 3.0,
        "expected >=3x trace throughput at 4 replicas, got {four_replica_speedup:.2}x"
    );
}
