//! End-to-end validation: serve a REAL model through all three layers.
//!
//! L1 Pallas attention kernels → L2 JAX tiny-Llama → AOT HLO text →
//! L3 Rust: PJRT compile, deterministic weights, paged KV store, and the
//! live concurrent prefill/decode engines (threads + shared metadata
//! buffer + copy-free migration).  Poisson arrivals, batched decode,
//! latency/throughput report — the serving-paper e2e driver required by
//! the reproduction plan (recorded in EXPERIMENTS.md).
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_real_model
//! ```

use bullet::config::SloSpec;
use bullet::coordinator::Tokenizer;
use bullet::engine::live_engine::serve_live;
use bullet::metrics::summarize;
use bullet::runtime::{ModelMeta, ModelRuntime};
use bullet::util::rng::Rng;
use bullet::util::stats;
use bullet::workload::Request;

fn main() {
    let dir = ModelMeta::default_dir();
    println!("loading + compiling artifacts from {} ...", dir.display());
    let t0 = std::time::Instant::now();
    let rt = ModelRuntime::load(&dir, 7).unwrap_or_else(|e| {
        eprintln!("error: {e:#}\nhint: run `make artifacts` first");
        std::process::exit(1);
    });
    let meta = rt.engine.meta.clone();
    println!(
        "compiled {} prefill + {} decode executables in {:.1}s ({} weights, vocab {})",
        meta.prefill_buckets.len(),
        meta.decode_buckets.len(),
        t0.elapsed().as_secs_f64(),
        meta.weights.len(),
        meta.vocab_size
    );

    // Poisson request stream over text prompts.
    let tok = Tokenizer::new(meta.vocab_size);
    let corpus = [
        "The prefill phase is compute bound while decode streams the KV cache.",
        "Wave quantization leaves SMs idle when grids misalign.",
        "SM masks partition the GPU between concurrent phases.",
        "Chunked prefill trades time-to-first-token for decode latency.",
        "A scheduler should react before the SLO is violated, not after.",
        "Bullet provisions resources with a profile-augmented model.",
    ];
    let n = 16usize;
    let rate = 4.0; // req/s
    let mut rng = Rng::new(2026);
    let mut t = 0.0;
    let mut prompts: Vec<Vec<i32>> = Vec::with_capacity(n);
    let trace: Vec<Request> = (0..n as u64)
        .map(|i| {
            t += rng.exponential(rate);
            let text = corpus[i as usize % corpus.len()];
            let mut prompt = tok.encode(text);
            prompt.truncate(rt.max_prompt());
            let input_len = prompt.len();
            prompts.push(prompt);
            Request {
                id: i,
                arrival: t,
                input_len,
                output_len: 8 + (i as usize % 9),
                ..Default::default()
            }
        })
        .collect();
    let total_out: usize = trace.iter().map(|r| r.output_len).sum();
    println!("\nserving {n} requests (~{rate} req/s Poisson, {total_out} output tokens) ...");

    let wall0 = std::time::Instant::now();
    let (records, stats_live) = serve_live(rt, trace, prompts).unwrap();
    let wall = wall0.elapsed().as_secs_f64();

    let slo = SloSpec::sharegpt();
    let s = summarize(&records, &slo, Some(wall));
    let ttfts: Vec<f64> = records.iter().map(|r| r.ttft()).collect();
    println!("\n=== live serving results (tiny Llama, PJRT CPU) ===");
    println!("  wall time          {:>8.2} s", wall);
    println!("  mean TTFT          {:>8.1} ms", s.mean_ttft * 1e3);
    println!("  P90  TTFT          {:>8.1} ms", stats::percentile(&ttfts, 90.0) * 1e3);
    println!("  mean TPOT          {:>8.1} ms", s.mean_tpot * 1e3);
    println!("  throughput         {:>8.1} output tok/s", s.throughput_tok_s);
    println!("  decode iterations  {:>8}", stats_live.decode_iterations);
    println!("  max decode batch   {:>8}", stats_live.max_batch_seen);
    println!("  mean handoff lat.  {:>8.2} ms", stats_live.handoff_latency_mean * 1e3);

    // Show one generation to prove real tokens flow end to end.
    let r0 = &records[0];
    println!(
        "\nrequest 0: input {} tokens -> {} output tokens, ttft {:.1} ms, e2e {:.1} ms",
        r0.input_len,
        r0.output_len,
        r0.ttft() * 1e3,
        r0.e2e_latency() * 1e3
    );
    assert_eq!(records.len(), n);
    println!("\nall {} requests completed — three-layer stack verified.", n);
}
