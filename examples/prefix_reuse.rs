//! Prefix reuse: the TTFT and goodput gain of shared-prefix KV caching
//! on multi-turn conversational traffic.
//!
//! Serves one conversational trace (tenants with shared system prompts,
//! sessions whose later turns re-send the whole conversation) twice on
//! the full Bullet system — prefix cache OFF, then ON — and compares.
//! With the cache on, admission matches each arrival against the
//! content-hash prefix index, adopts the cached blocks, and prefills
//! only the uncached suffix, so the perf estimator sees (and the SM
//! partitioner provisions for) far fewer prefill tokens.  A third pass
//! shows the cluster angle: the prefix-affinity router keeps a session's
//! turns on the replica that already holds its KV.
//!
//! ```bash
//! cargo run --release --offline --example prefix_reuse
//! ```

use bullet::cluster::{ClusterConfig, RouterPolicy};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::{goodput_req_s, summarize};
use bullet::util::tbl::{f, Table};
use bullet::workload::{generate_sessions, SessionProfile};

fn main() {
    // A bursty assistant workload: 40 sessions arriving at 4/s, short
    // think times, so conversations overlap and re-prefill pressure is
    // real.  Identical trace for both runs — only the cache differs.
    let profile = SessionProfile {
        think_mu: 0.7, // median ~2 s between turns
        min_turns: 3,
        max_turns: 6,
        ..SessionProfile::conversational()
    };
    let trace = generate_sessions(&profile, 4.0, 40, 42);
    let turns = trace.len();
    let prompt_tokens: usize = trace.iter().map(|r| r.input_len).sum();
    println!(
        "trace: {} turns across 40 sessions ({} prompt tokens, {} tenants, system prompt {} tokens)",
        turns, prompt_tokens, profile.tenants, profile.system_prompt_tokens
    );

    let serve = |prefix_cache: bool| {
        let cfg = ServingConfig {
            slo: SloSpec::sharegpt(),
            prefix_cache,
            ..ServingConfig::default()
        };
        let server = BulletServer::build(cfg.clone(), BuildOptions::default());
        (server.serve(&trace), cfg)
    };

    let (off, cfg_off) = serve(false);
    let (on, cfg_on) = serve(true);
    assert_eq!(off.records.len(), turns, "cache-off run lost records");
    assert_eq!(on.records.len(), turns, "cache-on run lost records");

    let s_off = summarize(&off.records, &cfg_off.slo, Some(off.virtual_duration));
    let s_on = summarize(&on.records, &cfg_on.slo, Some(on.virtual_duration));
    let g_off = goodput_req_s(&off.records, &cfg_off.slo, Some(off.virtual_duration));
    let g_on = goodput_req_s(&on.records, &cfg_on.slo, Some(on.virtual_duration));
    let ps = on.prefix;

    let mut t = Table::new("prefix cache off vs on (Bullet, conversational)").header(&[
        "metric",
        "cache off",
        "cache on",
    ]);
    t.row(&["mean TTFT (ms)".to_string(), f(s_off.mean_ttft * 1e3, 1), f(s_on.mean_ttft * 1e3, 1)]);
    t.row(&["P90 TTFT (ms)".to_string(), f(s_off.p90_ttft * 1e3, 1), f(s_on.p90_ttft * 1e3, 1)]);
    t.row(&["goodput (req/s)".to_string(), f(g_off, 2), f(g_on, 2)]);
    t.row(&[
        "SLO attainment".to_string(),
        f(s_off.slo_attainment * 100.0, 1) + "%",
        f(s_on.slo_attainment * 100.0, 1) + "%",
    ]);
    t.row(&["makespan (s)".to_string(), f(off.virtual_duration, 1), f(on.virtual_duration, 1)]);
    t.row(&["prefix hit rate".to_string(), "-".into(), f(ps.hit_rate() * 100.0, 1) + "%"]);
    t.row(&[
        "cached-token ratio".to_string(),
        "-".into(),
        f(ps.cached_token_ratio() * 100.0, 1) + "%",
    ]);
    t.row(&[
        "prefill tokens saved".to_string(),
        "0".into(),
        ps.tokens_saved().to_string(),
    ]);
    t.print();

    // Cluster angle: stickiness converts later turns into hits even when
    // the trace is spread over replicas.
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        prefix_cache: true,
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    let mut t = Table::new("routing x prefix cache (Bullet x3, cache on)").header(&[
        "router",
        "prefix hit rate",
        "mean TTFT (ms)",
        "goodput (req/s)",
    ]);
    let mut rates = std::collections::BTreeMap::new();
    for router in [RouterPolicy::RoundRobin, RouterPolicy::PrefixAffinity] {
        let ccfg = ClusterConfig { replicas: 3, router, ..Default::default() };
        let out = server.serve_cluster(&trace, &ccfg);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        let g = goodput_req_s(&out.records, &cfg.slo, Some(out.virtual_duration));
        let cps = out.prefix_stats();
        rates.insert(router.label(), cps.hit_rate());
        t.row(&[
            router.label().to_string(),
            f(cps.hit_rate() * 100.0, 1) + "%",
            f(s.mean_ttft * 1e3, 1),
            f(g, 2),
        ]);
    }
    t.print();

    println!(
        "cache on: mean TTFT {:.0} ms vs {:.0} ms off ({:.2}x), goodput {:.2} vs {:.2} req/s, \
         hit rate {:.0}%",
        s_on.mean_ttft * 1e3,
        s_off.mean_ttft * 1e3,
        s_off.mean_ttft / s_on.mean_ttft.max(1e-9),
        g_on,
        g_off,
        ps.hit_rate() * 100.0
    );

    // The acceptance bars (mirrored by tests/serving_integration.rs).
    assert!(ps.hits > 0, "conversational trace must produce prefix hits");
    assert!(
        s_on.mean_ttft < s_off.mean_ttft,
        "prefix cache must cut mean TTFT: on {} vs off {}",
        s_on.mean_ttft,
        s_off.mean_ttft
    );
    assert!(
        g_on >= g_off,
        "prefix cache must not hurt goodput: on {g_on} vs off {g_off}"
    );
    assert!(
        rates["prefix-affinity"] >= rates["round-robin"],
        "affinity routing must not lose hit rate to round-robin: {rates:?}"
    );
    println!("prefix-reuse bars met: hit rate > 0, TTFT down, goodput preserved or better");
}
