//! Live serving gateway: the full request lifecycle under the virtual
//! clock, asserted end to end.
//!
//! Three legs over the same ShareGPT trace, each proving one lifecycle
//! mechanism on the wall-clock front door (run deterministically here on
//! [`VirtualClock`]; pass `--live wall` to the CLI for real time):
//!
//! - **A — cancellation**: clients disconnect mid-stream
//!   (`Request::cancel_at`); their KV blocks return to the pool before
//!   the run ends and every stream still closes with a terminal chunk;
//! - **B — deadlines**: a blanket deadline expires long-running
//!   requests; expired requests are counted and never consume decode
//!   iterations past their deadline;
//! - **C — failure injection**: a replica crashes mid-trace; sessions
//!   re-home to survivors, cold orphans re-queue (keeping their stream),
//!   in-flight work is counted lost, and the ledger stays total:
//!   `completed + cancelled + expired + lost == submitted`.
//!
//! Every leg is run twice and asserted bit-identical — the lifecycle
//! machinery is deterministic under the virtual clock.
//!
//! ```bash
//! cargo run --release --offline --example live_gateway
//! ```

use bullet::baselines::System;
use bullet::cluster::RouterPolicy;
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::gateway::{
    serve_gateway, FailureSpec, GatewayConfig, GatewayOutput, VirtualClock,
};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::RequestOutcome;
use bullet::perf::PerfModel;
use bullet::workload::{
    annotate_lifecycle, generate_n_requests, generate_sessions, Dataset, LifecycleProfile,
    Request, SessionProfile,
};

fn run(
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    gw: &GatewayConfig,
    seed: u64,
) -> GatewayOutput {
    let mut clock = VirtualClock::new();
    serve_gateway(System::Bullet, cfg, perf, gt, trace, seed, gw, &mut clock)
}

fn main() {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());

    // ---- leg A: cancellation-heavy traffic ----
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 11);
    annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), 11);
    let gw = GatewayConfig { replicas: 2, router: RouterPolicy::LeastKv, ..Default::default() };
    let a = run(&cfg, &perf, &gt, &trace, &gw, 5);
    let a2 = run(&cfg, &perf, &gt, &trace, &gw, 5);
    assert_eq!(a.records, a2.records, "leg A must be deterministic");
    assert_eq!(a.outcomes, a2.outcomes, "leg A must be deterministic");
    assert_eq!(a.streams, a2.streams, "leg A must be deterministic");
    let lc = a.lifecycle;
    assert_eq!(lc.submitted(), trace.len(), "leg A ledger: {lc:?}");
    assert!(lc.cancelled > 0, "cancellation-heavy trace must cancel: {lc:?}");
    // (a) cancelled KV is back in the pool before the run ends: every
    // cancel outcome lands strictly inside the run, and nothing leaks
    for o in a.outcomes.iter().filter(|o| o.outcome == RequestOutcome::Cancelled) {
        assert!(
            o.t < a.virtual_duration,
            "cancel of {} at {} must precede run end {}",
            o.id,
            o.t,
            a.virtual_duration
        );
    }
    for (i, o) in a.per_replica.iter().enumerate() {
        assert_eq!(o.final_kv_blocks, 0, "replica {i} leaked KV blocks");
    }
    // stream sanity: every admitted request gets a closed stream
    assert_eq!(a.streams.len(), trace.len());
    for (id, chunks) in &a.streams {
        assert!(
            chunks.last().map(|c| c.done).unwrap_or(true),
            "request {id} stream left open"
        );
        for w in chunks.windows(2) {
            assert!(w[1].t >= w[0].t, "request {id} stream went backwards");
        }
    }
    println!(
        "leg A (cancellation): {} submitted = {} completed + {} cancelled; \
         {} stream chunks, mean TTFB {:.0} ms, no KV leaks",
        lc.submitted(),
        lc.completed,
        lc.cancelled,
        a.stream.chunks,
        a.stream.mean_ttfb * 1e3
    );

    // ---- leg B: deadlines, blanket and explicit ----
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 40, 13);
    // even ids carry a far-future explicit deadline (which the blanket
    // must NOT override); odd ids carry none and inherit the gateway's
    // 0.75s blanket — far too tight for a multi-hundred-token decode
    for r in trace.iter_mut().filter(|r| r.id % 2 == 0) {
        r.deadline = Some(r.arrival + 1e9);
    }
    let gw = GatewayConfig {
        replicas: 2,
        router: RouterPolicy::LeastKv,
        default_deadline_s: Some(0.75),
        ..Default::default()
    };
    let b = run(&cfg, &perf, &gt, &trace, &gw, 5);
    let lc = b.lifecycle;
    assert_eq!(lc.submitted(), trace.len(), "leg B ledger: {lc:?}");
    assert!(lc.expired > 0, "the 0.75s blanket must expire long decodes: {lc:?}");
    assert!(lc.completed > 0, "far-future deadlines must still finish: {lc:?}");
    for o in &b.outcomes {
        assert_eq!(o.id % 2, 1, "request {} expired against a 1e9s deadline", o.id);
    }
    // (b) expired requests stop early and consume no decode iterations
    // past the deadline: the abort is the stream's last event, and the
    // request never reaches its full output length
    for o in b.outcomes.iter().filter(|o| o.outcome == RequestOutcome::Expired) {
        let r = trace.iter().find(|r| r.id == o.id).unwrap();
        let deadline = r.arrival + 0.75;
        assert!(
            o.tokens_out < r.output_len,
            "expired request {} decoded to completion anyway",
            o.id
        );
        let (_, chunks) = b.streams.iter().find(|(id, _)| *id == o.id).unwrap();
        if let Some(last) = chunks.last() {
            assert!(last.done);
            assert!(
                (last.t - o.t).abs() < 1e-9,
                "stream of {} outlived its expiry: {} vs {}",
                o.id,
                last.t,
                o.t
            );
        }
        // tokens may land up to one in-flight iteration past the
        // deadline; beyond the abort instant there is nothing
        for c in chunks.iter().filter(|c| !c.done) {
            assert!(
                c.t <= o.t,
                "request {} decoded at {} after its expiry at {} (deadline {})",
                o.id,
                c.t,
                o.t,
                deadline
            );
        }
    }
    for (i, o) in b.per_replica.iter().enumerate() {
        assert_eq!(o.final_kv_blocks, 0, "replica {i} leaked KV blocks");
    }
    println!(
        "leg B (deadlines): {} submitted = {} completed + {} expired; \
         expired streams close at their abort instant",
        lc.submitted(),
        lc.completed,
        lc.expired
    );

    // ---- leg C: replica crash mid-trace ----
    let trace = generate_sessions(&SessionProfile::conversational(), 2.0, 14, 17);
    let crash_at = trace[trace.len() / 2].arrival + 1e-3;
    let gw = GatewayConfig {
        replicas: 3,
        router: RouterPolicy::PrefixAffinity,
        failures: vec![FailureSpec { replica: 0, at: crash_at }],
        ..Default::default()
    };
    let c = run(&cfg, &perf, &gt, &trace, &gw, 5);
    let c2 = run(&cfg, &perf, &gt, &trace, &gw, 5);
    assert_eq!(c.records, c2.records, "leg C must be deterministic");
    assert_eq!(c.outcomes, c2.outcomes, "leg C must be deterministic");
    let lc = c.lifecycle;
    // (c) the ledger is total across the crash
    assert_eq!(
        lc.completed + lc.cancelled + lc.expired + lc.lost,
        trace.len(),
        "leg C ledger must be total: {lc:?}"
    );
    assert_eq!(c.scale_events.len(), 1);
    assert!((c.scale_events[0].t - crash_at).abs() < 1e-12);
    // sessions re-home: traffic arriving after the crash routes to
    // survivors only
    for &(id, k) in &c.assignments {
        let r = trace.iter().find(|r| r.id == id).unwrap();
        if r.arrival > crash_at {
            assert_ne!(k, 0, "request {id} routed to the crashed replica");
        }
    }
    // the dead replica's KV is fully torn down
    assert_eq!(c.per_replica[0].final_kv_blocks, 0, "crashed replica leaked KV");
    println!(
        "leg C (crash @ {crash_at:.2}s): {} completed + {} lost of {} submitted; \
         sessions re-homed off replica 0, no KV leaks",
        lc.completed,
        lc.lost,
        trace.len()
    );

    println!("\nlive gateway lifecycle verified: cancellation, deadlines, crash re-homing.");
}
