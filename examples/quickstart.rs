//! Quickstart: the minimal Bullet API tour.
//!
//! Builds the serving system on the simulated A100, runs the offline
//! profiling pass, serves a small ShareGPT-like trace, and prints the
//! headline metrics.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use bullet::config::ServingConfig;
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::workload::Dataset;

fn main() {
    // 1. Configure: A100 + Llama-3.1-8B defaults, ShareGPT SLOs.
    let cfg = ServingConfig::default();
    println!(
        "GPU: {} SMs | model: {} ({:.1}B params) | KV capacity: {} tokens",
        cfg.gpu.num_sms,
        cfg.model.name,
        cfg.model.param_count() as f64 / 1e9,
        cfg.kv_capacity_tokens
    );

    // 2. Build: constructs the simulated GPU and runs the §3.2.2
    //    offline profiling pass to fit the performance estimator.
    let t0 = std::time::Instant::now();
    let mut server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    println!(
        "built in {:.2}s (contention factors: p_c={:.3}, p_b={:.3})",
        t0.elapsed().as_secs_f64(),
        server.perf().p_c,
        server.perf().p_b
    );

    // 3. Serve: 100 requests at 10 req/s, concurrent prefill/decode with
    //    dynamic SM partitioning.
    server.record_timeline(true);
    let out = server.serve_dataset(&Dataset::sharegpt(), 10.0, 100, 42);

    // 4. Inspect.
    let s = summarize(&out.records, &server.cfg().slo, Some(out.virtual_duration));
    println!("\nserved {} requests in {:.1}s (virtual):", s.n_requests, s.duration);
    println!("  mean TTFT       {:>8.1} ms (P90 {:.1} ms)", s.mean_ttft * 1e3, s.p90_ttft * 1e3);
    println!("  mean TPOT       {:>8.1} ms (P90 {:.1} ms)", s.mean_tpot * 1e3, s.p90_tpot * 1e3);
    println!("  throughput      {:>8.1} tok/s", s.throughput_tok_s);
    println!("  SLO attainment  {:>8.1} %", s.slo_attainment * 100.0);
    println!("  SM re-configs   {:>8}", out.reconfigs);
    println!("  decode pauses   {:>8}", out.decode_pauses);

    // 5. The dynamic partition at a glance: mean prefill share over time.
    let mean_pm = out.timeline.mean_of(|s| s.prefill_sms as f64);
    println!("  mean prefill SM {:>8.1} / {}", mean_pm, cfg.gpu.num_sms);
}
