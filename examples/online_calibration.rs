//! Online calibration: closing the §3.2 performance-model loop at
//! runtime, under GPU regimes the offline profile never saw.
//!
//! Three legs:
//!
//! 1. **Inertness** — with no drift regime and calibration off, the new
//!    subsystem is provably absent: records are bit-identical whether
//!    the drift machinery is default or explicitly `none`.
//! 2. **Drift** — the serving-time GPU diverges from the profiled one
//!    (an SM-stealing co-tenant lands mid-run, clocks throttle, plus a
//!    device lottery).  Frozen-model Bullet keeps scheduling on stale
//!    predictions; calibrated Bullet ingests lane-drain residuals and
//!    re-partitions on what the GPU actually does.  Calibrated must
//!    strictly beat frozen on P90 TTFT and goodput.
//! 3. **Heterogeneous fleet** — four replicas with different silicon
//!    (clean / throttling / co-tenant / half-speed bin) behind the
//!    slo-slack router.  Each replica calibrates independently; their
//!    learned slowdowns diverge from the single shared offline grid.
//!
//! ```bash
//! cargo run --release --offline --example online_calibration
//! ```

use bullet::cluster::{serve_cluster, ClusterConfig, ReplicaSpec, RouterPolicy};
use bullet::config::{CalibrationConfig, DriftSpec, GpuSpec, ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::engine::sim_engine::{serve_bullet, SimEngineOptions};
use bullet::metrics::{goodput_req_s, summarize};
use bullet::util::tbl::{f, Table};
use bullet::workload::{generate_n_requests, Dataset};

fn main() {
    // ShareGPT at a rate that makes decode BINDING (TPOT near budget,
    // big batches) on a KV-tight deployment.  Compute-side drift then
    // shifts exactly what the frozen model cannot see: at small decode
    // shares the skinny decode GEMMs turn compute-bound, so a squeezed
    // decode engine is twice as slow as predicted — tokens crawl, KV
    // stays pinned, admission stalls, and both TTFT and goodput pay.
    // kv 150k / step 2.5x: the decode-binding margin hardened per the
    // PR 3 flake note (widen drift, tighten KV before weakening bars);
    // tests/calibration.rs::leg2_regime_stays_decode_binding pins it.
    let base = ServingConfig {
        slo: SloSpec::sharegpt(),
        kv_capacity_tokens: 150_000,
        ..ServingConfig::default()
    };
    // The offline profile runs on the CLEAN ground truth — that is the
    // whole premise: profiling happens before deployment.
    let server = BulletServer::build(base.clone(), BuildOptions::with_coarse_profiling(&base));
    let trace = generate_n_requests(&Dataset::sharegpt(), 9.0, 150, 42);
    println!(
        "trace: {} ShareGPT requests over {:.1}s (offline profile: coarse grid, clean GPU)",
        trace.len(),
        trace.last().unwrap().arrival
    );

    // ---- Leg 1: inertness -------------------------------------------
    let clean = server.ground_truth().clone();
    let explicit_none = clean.clone().with_drift(DriftSpec::none());
    let opts = SimEngineOptions::default();
    let a = serve_bullet(&base, server.perf(), &clean, &trace, &opts);
    let b = serve_bullet(&base, server.perf(), &explicit_none, &trace, &opts);
    assert_eq!(
        a.records, b.records,
        "an explicit none-drift regime must be bit-identical"
    );
    assert_eq!(a.calibration.samples, 0, "calibration off must ingest nothing");
    println!("leg 1: drift=none + calibration=off is bit-identical to the legacy run");

    // ---- Leg 2: frozen vs calibrated under drift --------------------
    // Mid-run regime change: a co-tenant steals 60% of the SM cycles
    // from t=4s, clocks throttle to 80% over 30s, and this device drew
    // a lottery factor — none of it visible to the offline profile.
    let drift = DriftSpec {
        step_at_s: 4.0,
        step_factor: 2.5,
        throttle_floor: 0.8,
        throttle_ramp_s: 30.0,
        lottery_sigma: 0.15,
    };
    let drifted = clean.clone().with_drift(drift.clone());
    let frozen_cfg = base.clone();
    let calibrated_cfg = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..base.clone()
    };
    let frozen = serve_bullet(&frozen_cfg, server.perf(), &drifted, &trace, &opts);
    let calibrated = serve_bullet(&calibrated_cfg, server.perf(), &drifted, &trace, &opts);
    assert_eq!(frozen.records.len(), trace.len());
    assert_eq!(calibrated.records.len(), trace.len());

    let s_f = summarize(&frozen.records, &base.slo, Some(frozen.virtual_duration));
    let s_c = summarize(&calibrated.records, &base.slo, Some(calibrated.virtual_duration));
    let g_f = goodput_req_s(&frozen.records, &base.slo, Some(frozen.virtual_duration));
    let g_c = goodput_req_s(&calibrated.records, &base.slo, Some(calibrated.virtual_duration));
    let cs = calibrated.calibration;

    let mut t = Table::new("frozen vs calibrated Bullet under drift (co-tenant + throttle)")
        .header(&["metric", "frozen", "calibrated"]);
    t.row(&["mean TTFT (ms)".to_string(), f(s_f.mean_ttft * 1e3, 0), f(s_c.mean_ttft * 1e3, 0)]);
    t.row(&["P90 TTFT (ms)".to_string(), f(s_f.p90_ttft * 1e3, 0), f(s_c.p90_ttft * 1e3, 0)]);
    t.row(&["P90 TPOT (ms)".to_string(), f(s_f.p90_tpot * 1e3, 1), f(s_c.p90_tpot * 1e3, 1)]);
    t.row(&["goodput (req/s)".to_string(), f(g_f, 2), f(g_c, 2)]);
    t.row(&[
        "SLO attainment".to_string(),
        f(s_f.slo_attainment * 100.0, 1) + "%",
        f(s_c.slo_attainment * 100.0, 1) + "%",
    ]);
    t.row(&["calib samples".to_string(), "0".into(), cs.samples.to_string()]);
    t.row(&[
        "calib mean |residual|".to_string(),
        "-".into(),
        f(cs.mean_abs_residual() * 100.0, 1) + "%",
    ]);
    t.row(&["drift events".to_string(), "-".into(), cs.drift_events.to_string()]);
    t.row(&["learned slowdown".to_string(), "-".into(), f(cs.slowdown, 2) + "x"]);
    t.print();

    assert!(cs.samples > 100, "calibration must ingest the run: {cs:?}");
    assert!(
        cs.drift_events >= 1,
        "the residual trend must flag the regime change: {cs:?}"
    );
    assert!(
        s_c.p90_ttft < s_f.p90_ttft,
        "calibrated Bullet must beat frozen on P90 TTFT under drift: \
         {:.0} ms vs {:.0} ms",
        s_c.p90_ttft * 1e3,
        s_f.p90_ttft * 1e3
    );
    assert!(
        g_c > g_f,
        "calibrated Bullet must beat frozen on goodput under drift: {g_c:.2} vs {g_f:.2} req/s"
    );
    println!(
        "leg 2: calibrated wins — P90 TTFT {:.0} vs {:.0} ms, goodput {:.2} vs {:.2} req/s",
        s_c.p90_ttft * 1e3,
        s_f.p90_ttft * 1e3,
        g_c,
        g_f
    );

    // ---- Leg 3: heterogeneous fleet ---------------------------------
    // Four devices, one shared offline grid.  Replica 0 is the profiled
    // GPU; 1 throttles; 2 hosts a co-tenant; 3 is a half-speed bin.
    let half_speed = GpuSpec {
        peak_flops: GpuSpec::a100().peak_flops * 0.5,
        peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.5,
        ..GpuSpec::a100()
    };
    let specs = vec![
        ReplicaSpec::default(),
        ReplicaSpec {
            drift: Some(DriftSpec {
                throttle_floor: 0.6,
                throttle_ramp_s: 10.0,
                ..DriftSpec::none()
            }),
            ..Default::default()
        },
        ReplicaSpec {
            drift: Some(DriftSpec { step_at_s: 0.0, step_factor: 2.2, ..DriftSpec::none() }),
            ..Default::default()
        },
        ReplicaSpec { gpu: Some(half_speed), drift: None },
    ];
    let ccfg = ClusterConfig {
        replicas: 4,
        router: RouterPolicy::SloSlack,
        replica_specs: specs,
        ..Default::default()
    };
    let hetero_trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 60, 7);
    let out = serve_cluster(
        bullet::baselines::System::Bullet,
        &calibrated_cfg,
        server.perf(),
        &clean,
        &hetero_trace,
        7,
        &ccfg,
    );
    assert_eq!(out.records.len(), hetero_trace.len());
    let sd = out.calibrated_slowdowns();
    let counts = out.per_replica_counts();
    let mut t = Table::new("heterogeneous fleet x4 (slo-slack router, calibration on)")
        .header(&["replica", "device", "learned slowdown", "requests"]);
    for (i, label) in ["profiled A100", "throttling", "co-tenant", "half-speed bin"]
        .iter()
        .enumerate()
    {
        t.row(&[
            i.to_string(),
            label.to_string(),
            f(sd[i], 2) + "x",
            counts[i].to_string(),
        ]);
    }
    t.print();

    let lo = sd.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = sd.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        hi > lo * 1.3,
        "per-replica calibrated ratios must diverge from the shared grid: {sd:?}"
    );
    assert!(
        sd[3] > sd[0] * 1.2,
        "the half-speed bin must calibrate slower than the profiled device: {sd:?}"
    );
    println!(
        "leg 3: per-replica slowdowns {:?} — one offline grid, four calibrated realities",
        sd.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    println!("calibration bars met: inert when off, wins under drift, heterogeneity learned");
}
