//! Timeline demo (Fig. 12 in miniature): watch Bullet's dynamic SM
//! allocation react to a request burst on the Azure-Code workload —
//! ASCII rendition of the paper's timeline view.
//!
//! ```bash
//! cargo run --release --offline --example timeline_demo
//! ```

use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::summarize;
use bullet::util::tbl::bar;
use bullet::workload::{generate_bursty_trace, Dataset};

fn main() {
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let mut server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    server.record_timeline(true);

    // 3 req/s baseline with a 12 req/s burst in the middle (Fig. 12's
    // "spikes in the bottom row").
    let trace = generate_bursty_trace(&Dataset::azure_code(), 3.0, 12.0, 30.0, 10.0, 6.0, 7);
    println!("serving {} requests (burst of 12 req/s at t=10..16s)\n", trace.len());
    let out = server.serve(&trace);

    println!("t(s)   prefill SMs (top)       waiting (bottom)      decode batch");
    for s in out.timeline.resample(0.5) {
        let frac = s.prefill_sms as f64 / cfg.gpu.num_sms as f64;
        println!(
            "{:5.1}  [{}] {:>3}   [{}] {:>3}   {:>3}",
            s.t,
            bar(frac, 24),
            s.prefill_sms,
            bar((s.waiting as f64 / 10.0).min(1.0), 12),
            s.waiting,
            s.decode_batch,
        );
    }

    let su = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
    println!(
        "\nmean TTFT {:.0} ms | P90 TTFT {:.0} ms | mean TPOT {:.1} ms | reconfigs {} | pauses {}",
        su.mean_ttft * 1e3,
        su.p90_ttft * 1e3,
        su.mean_tpot * 1e3,
        out.reconfigs,
        out.decode_pauses
    );
    println!("mean queueing delay {:.0} ms — burst absorbed without congestion collapse", su.mean_queueing * 1e3);
}
