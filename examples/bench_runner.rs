//! Recorded perf trajectory: replay a saturating azure-code trace on an
//! 8-replica cluster through BOTH simulation backends, verify bitwise
//! parity in-run (threads AND memoization), time each, and emit the
//! numbers as `BENCH_8.json` — the artifact CI's `bench` job uploads
//! and gates on.
//!
//! What gets recorded:
//! - `cluster.virtual_makespan_s` — deterministic simulated makespan
//!   (bit-identical across machines for the same code), the
//!   semantics-drift tripwire;
//! - `cluster.serial_wall_s` / `parallel_wall_s` / `speedup` — the
//!   parallel-backend wall-clock win (serial = `--sim-threads 1`,
//!   parallel = all cores);
//! - `cluster.parity` — whether the two backends produced identical
//!   records, routing and makespan bits THIS run;
//! - `cluster.memo_parity` — whether the memoization-off reference run
//!   (`ServingConfig::memo = false`) reproduced the same bits;
//! - `hotpath.*` — perf_hotpath micro-numbers: the per-arrival router
//!   decision on a 64-replica fleet, the full scheduler cycle at 512
//!   waiting (memoized and reference), simulator step throughput, and
//!   the calibrated-prediction memo;
//! - `systems.*` — the Fig. 11/13-style competitor legs against the
//!   intra-GPU P/D disaggregation baselines: per-system goodput and P90
//!   TTFT on a single-GPU azure-code trace (Bullet must match or beat
//!   every disaggregation baseline on goodput), and static vs proactive
//!   P90 TTFT under a bursty trace (the moving boundary must win).
//!
//! ```bash
//! cargo run --release --offline --example bench_runner -- \
//!     [--requests N] [--replicas N] [--rate R] [--out PATH]
//! ```
//!
//! `tools/compare_bench.py` compares a fresh run against the committed
//! baseline (skipping wall-clock comparisons when the baseline was not
//! produced by a verified runner — see the `verified` flag).

use bullet::baselines::{run_system_output, System};
use bullet::cluster::{serve_cluster, ClusterConfig, Dispatcher, ReplicaSignals, RouterPolicy};
use bullet::metrics::{goodput_req_s, summarize};
use bullet::workload::generate_bursty_trace;
use bullet::config::{CalibrationConfig, GpuSpec, ModelSpec, ServingConfig, SloSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::gpu::{KernelDesc, OpClass};
use bullet::perf::{CalibrationStats, OnlineCalibrator, PerfModel, PerfPredictor};
use bullet::resource::Partition;
use bullet::sched::{DecodeReqState, PrefillBatch, PrefillReq, SloScheduler, SystemState};
use bullet::testing::bench::{bench, black_box};
use bullet::util::cli::Args;
use bullet::util::json::Value;
use bullet::workload::{generate_n_requests, Dataset, Request};
use std::collections::BTreeMap;
use std::time::Instant;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Indented serializer for the committed artifact (the in-crate JSON
/// Display is compact single-line, which diffs poorly).
fn pretty(v: &Value, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match v {
        Value::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in o.iter().enumerate() {
                out.push_str(&format!("{pad}  {}: ", Value::Str(k.clone())));
                pretty(val, indent + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&format!("{pad}}}"));
        }
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, val) in a.iter().enumerate() {
                out.push_str(&format!("{pad}  "));
                pretty(val, indent + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&format!("{pad}]"));
        }
        other => out.push_str(&other.to_string()),
    }
}

/// Heavy scheduler state for the cycle micro-bench: 128-request decode
/// batch, an in-flight prefill, and `n_waiting` queued requests
/// (mirrors `perf_hotpath` case 8 at its largest depth).
fn loaded_state(n_waiting: u64) -> SystemState {
    let decode: Vec<DecodeReqState> = (0..128)
        .map(|i| DecodeReqState {
            id: i,
            input_len: 1024,
            ctx_len: 1024 + (i as usize * 13) % 4096,
            tokens_out: 10 + (i as usize % 50),
            output_len: 200,
            decode_elapsed: 0.5,
        })
        .collect();
    let waiting: Vec<PrefillReq> = (0..n_waiting)
        .map(|i| PrefillReq {
            id: 500 + i,
            arrival: i as f64 * 0.01,
            input_len: 512 + (i as usize * 731) % 8192,
            output_len: 128,
            ..Default::default()
        })
        .collect();
    SystemState {
        now: 5.0,
        prefill: Some(PrefillBatch {
            reqs: vec![PrefillReq {
                id: 1,
                arrival: 4.0,
                input_len: 6000,
                output_len: 100,
                ..Default::default()
            }],
            n_tokens: 6000,
            layers_done: 10,
            started_at: 4.5,
            ..Default::default()
        }),
        decode,
        waiting,
        partition: Partition::split(&GpuSpec::a100(), 72),
        total_layers: 32,
    }
}

fn main() {
    let args = Args::from_env();
    let replicas = args.get_usize("replicas", 8);
    let requests = args.get_usize("requests", 2000);
    // saturating by construction: arrivals outpace the fleet's prefill
    // capacity, so every replica stays busy between dispatch horizons
    let rate = args.get_f64("rate", 12.0 * replicas as f64);
    let out_path = args.get_or("out", "BENCH_8.json").to_string();

    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let trace = generate_n_requests(&Dataset::azure_code(), rate, requests, 42);
    let ccfg = ClusterConfig { replicas, router: RouterPolicy::LeastKv, ..Default::default() };
    let threads = ClusterConfig { sim_threads: 0, ..ccfg.clone() }.effective_sim_threads();
    println!(
        "bench_runner: {requests} azure-code reqs @ {rate:.0}/s, {replicas} replicas, \
         {threads} worker threads"
    );

    // serial reference (the legacy path), then the parallel backend
    let serial_cfg = ClusterConfig { sim_threads: 1, ..ccfg.clone() };
    let t0 = Instant::now();
    let serial = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 42, &serial_cfg);
    let serial_wall = t0.elapsed().as_secs_f64();

    let parallel_cfg = ClusterConfig { sim_threads: 0, ..ccfg.clone() };
    let t0 = Instant::now();
    let parallel = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 42, &parallel_cfg);
    let parallel_wall = t0.elapsed().as_secs_f64();

    // memoization-off reference run (parallel backend): the hot-path
    // caches must be pure accelerations — comparing against the serial
    // memoized run checks the memo AND thread axes in one leg
    let cfg_off = ServingConfig { memo: false, ..cfg.clone() };
    let memo_off = serve_cluster(System::Bullet, &cfg_off, &perf, &gt, &trace, 42, &parallel_cfg);

    // bitwise parity is part of the recorded result, not just the test
    // suite: a bench artifact from a diverging build must say so
    let parity = serial.records == parallel.records
        && serial.assignments == parallel.assignments
        && serial.virtual_duration.to_bits() == parallel.virtual_duration.to_bits();
    let memo_parity = serial.records == memo_off.records
        && serial.assignments == memo_off.assignments
        && serial.virtual_duration.to_bits() == memo_off.virtual_duration.to_bits();
    let speedup = serial_wall / parallel_wall;
    let makespan = serial.virtual_duration;
    let out_tokens: usize = serial.records.iter().map(|r| r.output_len).sum();
    println!(
        "cluster: makespan {makespan:.2} virtual s | serial {serial_wall:.2}s, \
         parallel {parallel_wall:.2}s = {speedup:.2}x | parity {parity} | \
         memo parity {memo_parity}"
    );

    // hotpath micro-numbers: the per-arrival router decision on a
    // 64-replica fleet (mirrors perf_hotpath case 7)
    let fleet: Vec<ReplicaSignals> = (0..64)
        .map(|i| ReplicaSignals {
            id: i,
            outstanding_kv_tokens: 40_000 + (i * 977) % 30_000,
            backlog_tokens: 2_000 + (i * 313) % 9_000,
            decode_batch: i % 48,
            num_sms: 108,
            n_layers: 32,
            slowdown: 1.0 + (i % 7) as f64 * 0.05,
            calib: CalibrationStats::default(),
            drained: false,
        })
        .collect();
    let eligible: Vec<usize> = (0..fleet.len()).collect();
    let route_req = Request { input_len: 2048, output_len: 128, ..Default::default() };
    let mut hotpath: Vec<(String, f64)> = Vec::new();
    for policy in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
        let mut d = Dispatcher::new(policy);
        let r = bench(&format!("router pick_among ({}, 64 replicas)", policy.label()), 2000, || {
            black_box(d.pick_among(
                black_box(&fleet),
                black_box(&eligible),
                black_box(&route_req),
                &perf,
                &cfg.slo,
            ));
        });
        println!("{}", r.report());
        let key = format!("router_pick_{}_us", policy.label().replace('-', "_"));
        hotpath.push((key, r.mean_us()));
    }

    // scheduler full cycle at 512 waiting: hoisted per-cycle aggregates
    // (memo on) vs the reference evaluator (memo off) — same decisions
    // by construction, so only the wall time differs
    let loaded = loaded_state(512);
    let mk_perf = || PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let sched_on = SloScheduler::new(cfg.clone(), mk_perf());
    let sched_off = SloScheduler::new(cfg_off.clone(), mk_perf());
    let r_on = bench("schedule() memo on (512 waiting)", 200, || {
        let mut s = loaded.clone();
        black_box(sched_on.schedule(&mut s));
    });
    let r_off = bench("schedule() memo off (512 waiting)", 200, || {
        let mut s = loaded.clone();
        black_box(sched_off.schedule(&mut s));
    });
    println!("{}", r_on.report());
    println!("{}", r_off.report());
    hotpath.push(("sched_cycle_512_us".to_string(), r_on.mean_us()));
    hotpath.push(("sched_cycle_512_speedup".to_string(), r_off.min_s / r_on.min_s));

    // simulator step throughput (2 overlapping streams, completion-driven
    // so this exercises rate-table invalidation, not just reuse)
    let t0 = Instant::now();
    let mut events = 0usize;
    let mut sim = Simulator::new(gt.clone(), 1);
    let sa = sim.create_stream(SmMask::first(72), "a");
    let sb = sim.create_stream(SmMask::last(36, 108), "b");
    for _ in 0..20_000 {
        sim.submit(sa, KernelDesc::new(OpClass::GemmMlp, 1e11, 1e8, 512));
        sim.submit(sb, KernelDesc::new(OpClass::AttnDecode, 1e9, 5e8, 64));
    }
    while sim.step() {
        events += 1;
    }
    let sim_rate = events as f64 / t0.elapsed().as_secs_f64();
    println!("simulator: {events} completions = {sim_rate:.0} events/s");
    hotpath.push(("sim_step_events_per_s".to_string(), sim_rate));

    // calibrated prediction, memoized vs cold (64-probe cycle, the shape
    // of one scheduling cycle's candidate scan)
    let mut cal = OnlineCalibrator::new(perf.clone(), CalibrationConfig::on());
    let obs_base = PerfModel::predict_prefill_layer(cal.offline(), 2048, 0, 72, true);
    for _ in 0..20 {
        cal.observe_prefill(2048, 0, 72, true, 1, obs_base * 1.4);
    }
    for (key, memo) in [("calib_predict_memo_us", true), ("calib_predict_cold_us", false)] {
        cal.set_memo(memo);
        let r = bench(&format!("calibrated predict (memo={memo}, 64 probes)"), 2000, || {
            let mut acc = 0.0;
            for i in 0..64usize {
                acc += cal.predict_prefill_layer(512 + (i * 97) % 4096, 0, 12 * (1 + i % 9), true);
            }
            black_box(acc);
        });
        println!("{}", r.report());
        hotpath.push((key.to_string(), r.mean_us()));
    }

    // Fig. 11-style competitor leg: single-GPU azure-code, Bullet vs the
    // intra-GPU P/D disaggregation family.  Goodput (SLO-attained req/s)
    // is the paper's headline axis; the adaptive spatial-temporal policy
    // must match or beat every fixed/predicted/time-sliced split.
    let fig11_trace = generate_n_requests(&Dataset::azure_code(), 6.0, 300, 42);
    let mut systems: Vec<(String, f64)> = Vec::new();
    let mut fig11_goodput: Vec<(System, f64)> = Vec::new();
    for sys in [
        System::StaticSplit,
        System::ProactiveSplit,
        System::TemporalMux,
        System::Bullet,
    ] {
        let out = run_system_output(sys, &cfg, &perf, &gt, &fig11_trace, 42);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        let gp = goodput_req_s(&out.records, &cfg.slo, out.virtual_duration);
        println!(
            "fig11 azure-code: {:<16} goodput {:.2} req/s | p90 ttft {:.0} ms",
            sys.label(),
            gp,
            s.p90_ttft * 1e3
        );
        let key = sys.label().to_lowercase().replace('-', "_");
        systems.push((format!("fig11_azure_goodput_{key}_req_s"), gp));
        systems.push((format!("fig11_azure_p90_ttft_{key}_ms"), s.p90_ttft * 1e3));
        fig11_goodput.push((sys, gp));
    }
    let bullet_goodput = fig11_goodput
        .iter()
        .find(|(s, _)| *s == System::Bullet)
        .map(|(_, g)| *g)
        .unwrap();
    for (sys, gp) in &fig11_goodput {
        assert!(
            bullet_goodput >= *gp,
            "Bullet goodput {bullet_goodput:.3} below {} at {gp:.3} — \
             spatial-temporal sharing lost to a disaggregation baseline",
            sys.label()
        );
    }

    // Fig. 13-style burst leg: a prefill surge over a steady decode
    // floor.  The proactive boundary repartitions ahead of the surge;
    // the frozen split queues it — tail TTFT is where that shows.
    let slo_share = SloSpec::sharegpt();
    let cfg_share = ServingConfig { slo: slo_share, ..ServingConfig::default() };
    let fig13_trace = generate_bursty_trace(&Dataset::sharegpt(), 3.0, 18.0, 16.0, 5.0, 4.0, 11);
    let st = run_system_output(System::StaticSplit, &cfg_share, &perf, &gt, &fig13_trace, 42);
    let pr = run_system_output(System::ProactiveSplit, &cfg_share, &perf, &gt, &fig13_trace, 42);
    let st_s = summarize(&st.records, &cfg_share.slo, Some(st.virtual_duration));
    let pr_s = summarize(&pr.records, &cfg_share.slo, Some(pr.virtual_duration));
    println!(
        "fig13 bursty: static p90 ttft {:.0} ms | proactive p90 ttft {:.0} ms",
        st_s.p90_ttft * 1e3,
        pr_s.p90_ttft * 1e3
    );
    systems.push(("fig13_bursty_p90_ttft_static_split_ms".to_string(), st_s.p90_ttft * 1e3));
    systems.push((
        "fig13_bursty_p90_ttft_proactive_split_ms".to_string(),
        pr_s.p90_ttft * 1e3,
    ));
    assert!(
        pr_s.p90_ttft < st_s.p90_ttft,
        "proactive split p90 ttft {:.1} ms did not beat static {:.1} ms under burst",
        pr_s.p90_ttft * 1e3,
        st_s.p90_ttft * 1e3
    );

    let round = |x: f64| (x * 1000.0).round() / 1000.0;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let host = obj(vec![("cores", Value::Num(cores as f64))]);
    let config = obj(vec![
        ("workload", Value::Str("azure-code".into())),
        ("replicas", Value::Num(replicas as f64)),
        ("requests", Value::Num(requests as f64)),
        ("rate_req_s", Value::Num(round(rate))),
        ("router", Value::Str("least-kv".into())),
        ("sim_threads_effective", Value::Num(threads as f64)),
    ]);
    let cluster = obj(vec![
        ("virtual_makespan_s", Value::Num(round(makespan))),
        ("serial_wall_s", Value::Num(round(serial_wall))),
        ("parallel_wall_s", Value::Num(round(parallel_wall))),
        ("speedup", Value::Num(round(speedup))),
        ("realtime_factor", Value::Num(round(makespan / parallel_wall))),
        ("throughput_tok_s", Value::Num(round(out_tokens as f64 / makespan))),
        ("parity", Value::Bool(parity)),
        ("memo_parity", Value::Bool(memo_parity)),
    ]);
    let micro = Value::Obj(
        hotpath.iter().map(|(key, v)| (key.clone(), Value::Num(round(*v)))).collect(),
    );
    let systems_obj = Value::Obj(
        systems.iter().map(|(key, v)| (key.clone(), Value::Num(round(*v)))).collect(),
    );
    let doc = obj(vec![
        ("bench_id", Value::Num(8.0)),
        // true = produced by an actual run (CI or local); the committed
        // baseline starts false (desk-estimated) and flips true once a
        // CI artifact is promoted to baseline
        ("verified", Value::Bool(true)),
        ("host", host),
        ("config", config),
        ("cluster", cluster),
        ("hotpath", micro),
        ("systems", systems_obj),
    ]);
    let mut text = String::new();
    pretty(&doc, 0, &mut text);
    text.push('\n');
    std::fs::write(&out_path, &text).expect("write bench artifact");
    println!("wrote {out_path}");
    assert!(parity, "parallel backend diverged from serial — bench artifact is invalid");
    assert!(memo_parity, "memo-off reference diverged — a hot-path cache leaked into output");
}
