//! Recorded perf trajectory: replay a saturating azure-code trace on an
//! 8-replica cluster through BOTH simulation backends, verify bitwise
//! parity in-run, time each, and emit the numbers as `BENCH_6.json` —
//! the artifact CI's `bench` job uploads and gates on.
//!
//! What gets recorded:
//! - `cluster.virtual_makespan_s` — deterministic simulated makespan
//!   (bit-identical across machines for the same code), the
//!   semantics-drift tripwire;
//! - `cluster.serial_wall_s` / `parallel_wall_s` / `speedup` — the
//!   tentpole's wall-clock win (serial = `--sim-threads 1`, parallel =
//!   all cores);
//! - `cluster.parity` — whether the two backends produced identical
//!   records, routing and makespan bits THIS run;
//! - `hotpath.*_us` — perf_hotpath micro-numbers for the per-arrival
//!   router decision on a 64-replica fleet.
//!
//! ```bash
//! cargo run --release --offline --example bench_runner -- \
//!     [--requests N] [--replicas N] [--rate R] [--out PATH]
//! ```
//!
//! `tools/compare_bench.py` compares a fresh run against the committed
//! baseline (skipping wall-clock comparisons when the baseline was not
//! produced by a verified runner — see the `verified` flag).

use bullet::baselines::System;
use bullet::cluster::{serve_cluster, ClusterConfig, Dispatcher, ReplicaSignals, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig, SloSpec};
use bullet::gpu::roofline::GroundTruth;
use bullet::perf::{CalibrationStats, PerfModel};
use bullet::testing::bench::{bench, black_box};
use bullet::util::cli::Args;
use bullet::util::json::Value;
use bullet::workload::{generate_n_requests, Dataset, Request};
use std::collections::BTreeMap;
use std::time::Instant;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Indented serializer for the committed artifact (the in-crate JSON
/// Display is compact single-line, which diffs poorly).
fn pretty(v: &Value, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match v {
        Value::Obj(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in o.iter().enumerate() {
                out.push_str(&format!("{pad}  {}: ", Value::Str(k.clone())));
                pretty(val, indent + 1, out);
                if i + 1 < o.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&format!("{pad}}}"));
        }
        Value::Arr(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, val) in a.iter().enumerate() {
                out.push_str(&format!("{pad}  "));
                pretty(val, indent + 1, out);
                if i + 1 < a.len() {
                    out.push(',');
                }
                out.push('\n');
            }
            out.push_str(&format!("{pad}]"));
        }
        other => out.push_str(&other.to_string()),
    }
}

fn main() {
    let args = Args::from_env();
    let replicas = args.get_usize("replicas", 8);
    let requests = args.get_usize("requests", 2000);
    // saturating by construction: arrivals outpace the fleet's prefill
    // capacity, so every replica stays busy between dispatch horizons
    let rate = args.get_f64("rate", 12.0 * replicas as f64);
    let out_path = args.get_or("out", "BENCH_6.json").to_string();

    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let trace = generate_n_requests(&Dataset::azure_code(), rate, requests, 42);
    let ccfg = ClusterConfig { replicas, router: RouterPolicy::LeastKv, ..Default::default() };
    let threads = ClusterConfig { sim_threads: 0, ..ccfg.clone() }.effective_sim_threads();
    println!(
        "bench_runner: {requests} azure-code reqs @ {rate:.0}/s, {replicas} replicas, \
         {threads} worker threads"
    );

    // serial reference (the legacy path), then the parallel backend
    let serial_cfg = ClusterConfig { sim_threads: 1, ..ccfg.clone() };
    let t0 = Instant::now();
    let serial = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 42, &serial_cfg);
    let serial_wall = t0.elapsed().as_secs_f64();

    let parallel_cfg = ClusterConfig { sim_threads: 0, ..ccfg.clone() };
    let t0 = Instant::now();
    let parallel = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 42, &parallel_cfg);
    let parallel_wall = t0.elapsed().as_secs_f64();

    // bitwise parity is part of the recorded result, not just the test
    // suite: a bench artifact from a diverging build must say so
    let parity = serial.records == parallel.records
        && serial.assignments == parallel.assignments
        && serial.virtual_duration.to_bits() == parallel.virtual_duration.to_bits();
    let speedup = serial_wall / parallel_wall;
    let makespan = serial.virtual_duration;
    let out_tokens: usize = serial.records.iter().map(|r| r.output_len).sum();
    println!(
        "cluster: makespan {makespan:.2} virtual s | serial {serial_wall:.2}s, \
         parallel {parallel_wall:.2}s = {speedup:.2}x | parity {parity}"
    );

    // hotpath micro-numbers: the per-arrival router decision on a
    // 64-replica fleet (mirrors perf_hotpath case 7)
    let fleet: Vec<ReplicaSignals> = (0..64)
        .map(|i| ReplicaSignals {
            id: i,
            outstanding_kv_tokens: 40_000 + (i * 977) % 30_000,
            backlog_tokens: 2_000 + (i * 313) % 9_000,
            decode_batch: i % 48,
            num_sms: 108,
            n_layers: 32,
            slowdown: 1.0 + (i % 7) as f64 * 0.05,
            calib: CalibrationStats::default(),
            drained: false,
        })
        .collect();
    let eligible: Vec<usize> = (0..fleet.len()).collect();
    let route_req = Request { input_len: 2048, output_len: 128, ..Default::default() };
    let mut hotpath = Vec::new();
    for policy in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
        let mut d = Dispatcher::new(policy);
        let r = bench(&format!("router pick_among ({}, 64 replicas)", policy.label()), 2000, || {
            black_box(d.pick_among(
                black_box(&fleet),
                black_box(&eligible),
                black_box(&route_req),
                &perf,
                &cfg.slo,
            ));
        });
        println!("{}", r.report());
        hotpath.push((policy.label(), r.mean_us()));
    }

    let round = |x: f64| (x * 1000.0).round() / 1000.0;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let host = obj(vec![("cores", Value::Num(cores as f64))]);
    let config = obj(vec![
        ("workload", Value::Str("azure-code".into())),
        ("replicas", Value::Num(replicas as f64)),
        ("requests", Value::Num(requests as f64)),
        ("rate_req_s", Value::Num(round(rate))),
        ("router", Value::Str("least-kv".into())),
        ("sim_threads_effective", Value::Num(threads as f64)),
    ]);
    let cluster = obj(vec![
        ("virtual_makespan_s", Value::Num(round(makespan))),
        ("serial_wall_s", Value::Num(round(serial_wall))),
        ("parallel_wall_s", Value::Num(round(parallel_wall))),
        ("speedup", Value::Num(round(speedup))),
        ("realtime_factor", Value::Num(round(makespan / parallel_wall))),
        ("throughput_tok_s", Value::Num(round(out_tokens as f64 / makespan))),
        ("parity", Value::Bool(parity)),
    ]);
    let micro = Value::Obj(
        hotpath
            .iter()
            .map(|(label, us)| {
                let key = format!("router_pick_{}_us", label.replace('-', "_"));
                (key, Value::Num(round(*us)))
            })
            .collect(),
    );
    let doc = obj(vec![
        ("bench_id", Value::Num(6.0)),
        // true = produced by an actual run (CI or local); the committed
        // baseline starts false (desk-estimated) and flips true once a
        // CI artifact is promoted to baseline
        ("verified", Value::Bool(true)),
        ("host", host),
        ("config", config),
        ("cluster", cluster),
        ("hotpath", micro),
    ]);
    let mut text = String::new();
    pretty(&doc, 0, &mut text);
    text.push('\n');
    std::fs::write(&out_path, &text).expect("write bench artifact");
    println!("wrote {out_path}");
    assert!(parity, "parallel backend diverged from serial — bench artifact is invalid");
}
