//! Calibration-driven cluster autoscaling under a drift storm.
//!
//! The same load ramp (ShareGPT, 5 req/s baseline surging to 32 req/s)
//! on the same drifting silicon (fleet-wide `storm` regime, plus one
//! replica hosting a brutal co-tenant — the chronic drifter), served two
//! ways:
//!
//! - **fixed fleet** — 2 replicas, the PR 3 dispatch path;
//! - **autoscaled fleet** — starts at the same 2 replicas, bounded to
//!   [2, 4]; the autoscaler reads each replica's calibrated slowdown and
//!   drift events, compares the fleet's calibrated capacity
//!   (Σ 1/slowdown × nominal) against the arrival-rate SLO envelope,
//!   and scales out / retires / re-profiles with hysteresis.
//!
//! Bars (asserted):
//! 1. the fleet actually scales — at least one scale-out event fires;
//! 2. the autoscaled fleet beats the fixed fleet on P90 TTFT AND
//!    goodput under the drift storm;
//! 3. it does so with FEWER replica-steps than static max provisioning
//!    (`max_replicas x makespan`) — elasticity, not over-provisioning.
//!
//! ```bash
//! cargo run --release --offline --example autoscale
//! ```

use bullet::baselines::System;
use bullet::cluster::{serve_cluster, AutoscaleConfig, ClusterConfig, ReplicaSpec, RouterPolicy};
use bullet::config::{CalibrationConfig, DriftSpec, ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::metrics::timeline::ScaleAction;
use bullet::metrics::{goodput_req_s, summarize};
use bullet::util::tbl::{f, Table};
use bullet::workload::{generate_bursty_trace, Dataset};

fn main() {
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    // Offline profile on the CLEAN ground truth, before deployment.
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    // Load ramp: baseline 5 req/s, surging to 32 req/s for t in [8, 20) —
    // decisively past two storm-degraded replicas' capacity, inside
    // four's, with headroom so the scale-out margin never rides the edge
    // of the hysteresis thresholds (28 req/s occasionally landed inside
    // the fixed fleet's luckier lottery draws).
    let trace = generate_bursty_trace(&Dataset::sharegpt(), 5.0, 32.0, 30.0, 8.0, 12.0, 42);
    println!(
        "trace: {} ShareGPT requests over {:.1}s (5 req/s base, 32 req/s surge in [8, 20))",
        trace.len(),
        trace.last().unwrap().arrival
    );

    // Serving-time silicon: every device rides the storm regime (its
    // per-replica lottery draws differ by seed); replica 1 additionally
    // hosts a brutal co-tenant from t=6 — the chronic drifter.
    let gt = server.ground_truth().clone().with_drift(DriftSpec::storm());
    let specs = vec![
        ReplicaSpec::default(),
        ReplicaSpec {
            drift: Some(DriftSpec { step_at_s: 6.0, step_factor: 3.0, ..DriftSpec::storm() }),
            ..Default::default()
        },
    ];

    let fixed_cfg = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::LeastKv,
        replica_specs: specs,
        ..Default::default()
    };
    let auto_cfg = ClusterConfig {
        autoscale: AutoscaleConfig {
            control_interval_s: 0.5,
            rate_window_s: 4.0,
            cooldown_out_s: 2.0,
            cooldown_in_s: 8.0,
            retire_drift_events: 1,
            retire_windows: 2,
            ..AutoscaleConfig::on(2, 4)
        },
        ..fixed_cfg.clone()
    };

    let fixed = serve_cluster(System::Bullet, &cfg, server.perf(), &gt, &trace, 7, &fixed_cfg);
    let auto_run = serve_cluster(System::Bullet, &cfg, server.perf(), &gt, &trace, 7, &auto_cfg);
    assert_eq!(fixed.records.len(), trace.len());
    assert_eq!(auto_run.records.len(), trace.len());

    let s_f = summarize(&fixed.records, &cfg.slo, Some(fixed.virtual_duration));
    let s_a = summarize(&auto_run.records, &cfg.slo, Some(auto_run.virtual_duration));
    let g_f = goodput_req_s(&fixed.records, &cfg.slo, Some(fixed.virtual_duration));
    let g_a = goodput_req_s(&auto_run.records, &cfg.slo, Some(auto_run.virtual_duration));
    let count = |a: ScaleAction| auto_run.scale_events.iter().filter(|e| e.action == a).count();
    let static_max_steps = 4.0 * auto_run.virtual_duration;

    let mut t = Table::new("fixed x2 vs autoscaled [2, 4] under a drift storm")
        .header(&["metric", "fixed", "autoscaled"]);
    t.row(&["P90 TTFT (ms)".to_string(), f(s_f.p90_ttft * 1e3, 0), f(s_a.p90_ttft * 1e3, 0)]);
    t.row(&["mean TTFT (ms)".to_string(), f(s_f.mean_ttft * 1e3, 0), f(s_a.mean_ttft * 1e3, 0)]);
    t.row(&["P90 TPOT (ms)".to_string(), f(s_f.p90_tpot * 1e3, 1), f(s_a.p90_tpot * 1e3, 1)]);
    t.row(&["goodput (req/s)".to_string(), f(g_f, 2), f(g_a, 2)]);
    t.row(&[
        "SLO attainment".to_string(),
        f(s_f.slo_attainment * 100.0, 1) + "%",
        f(s_a.slo_attainment * 100.0, 1) + "%",
    ]);
    t.row(&[
        "replica-steps (GPU·s)".to_string(),
        f(fixed.replica_steps, 1),
        f(auto_run.replica_steps, 1),
    ]);
    t.row(&[
        "scale events".to_string(),
        "-".into(),
        format!(
            "{} out / {} in / {} retire / {} reprofile",
            count(ScaleAction::ScaleOut),
            count(ScaleAction::ScaleIn),
            count(ScaleAction::Retire),
            count(ScaleAction::Reprofile)
        ),
    ]);
    t.print();
    for e in &auto_run.scale_events {
        println!(
            "  t={:6.2}s  {:?} replica {} (fleet -> {})",
            e.t, e.action, e.replica, e.fleet_after
        );
    }

    assert!(
        count(ScaleAction::ScaleOut) >= 1,
        "the surge must trigger at least one scale-out: {:?}",
        auto_run.scale_events
    );
    assert!(
        s_a.p90_ttft < s_f.p90_ttft,
        "autoscaled fleet must beat fixed on P90 TTFT under the storm: \
         {:.0} ms vs {:.0} ms",
        s_a.p90_ttft * 1e3,
        s_f.p90_ttft * 1e3
    );
    assert!(
        g_a > g_f,
        "autoscaled fleet must beat fixed on goodput under the storm: {g_a:.2} vs {g_f:.2} req/s"
    );
    assert!(
        auto_run.replica_steps < static_max_steps,
        "elasticity bar: {:.1} replica-steps must undercut static max provisioning ({:.1})",
        auto_run.replica_steps,
        static_max_steps
    );
    println!(
        "autoscaling bars met: scaled to {} replicas, P90 TTFT {:.0} vs {:.0} ms, \
         goodput {:.2} vs {:.2} req/s, {:.0} vs {:.0} static-max replica-steps",
        auto_run.per_replica.len(),
        s_a.p90_ttft * 1e3,
        s_f.p90_ttft * 1e3,
        g_a,
        g_f,
        auto_run.replica_steps,
        static_max_steps
    );
}
