//! Integration over the calibration-driven autoscaler (the feature and
//! this harness are one deliverable): scale-out under a load ramp,
//! retire-on-chronic-drift with deweighted routing, the no-flap
//! hysteresis invariant under an oscillating arrival rate, and
//! off-switch bit-parity with the PR 3 fixed-fleet path.

use bullet::baselines::System;
use bullet::cluster::{
    serve_cluster, AutoscaleConfig, ClusterConfig, ReplicaSpec, RouterPolicy,
};
use bullet::config::{CalibrationConfig, DriftSpec, GpuSpec, ModelSpec, ServingConfig};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::timeline::ScaleAction;
use bullet::perf::PerfModel;
use bullet::workload::{generate_n_requests, Dataset, Request};

fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    (cfg, perf, gt)
}

fn quick_asc(min: usize, max: usize) -> AutoscaleConfig {
    AutoscaleConfig {
        control_interval_s: 0.5,
        rate_window_s: 4.0,
        cooldown_out_s: 2.0,
        cooldown_in_s: 6.0,
        ..AutoscaleConfig::on(min, max)
    }
}

/// A saturating long-prompt ramp pushes the envelope far past one
/// replica's calibrated capacity: the fleet must grow, the spawned
/// replicas must take real traffic, and elasticity must undercut static
/// max provisioning.
#[test]
fn scales_out_under_a_load_ramp() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::azure_code(), 20.0, 60, 11);
    let ccfg = ClusterConfig {
        replicas: 1,
        router: RouterPolicy::LeastKv,
        autoscale: quick_asc(1, 3),
        ..Default::default()
    };
    let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 1, &ccfg);
    assert_eq!(out.records.len(), trace.len());
    let outs = out
        .scale_events
        .iter()
        .filter(|e| e.action == ScaleAction::ScaleOut)
        .count();
    assert!(outs >= 1, "ramp must trigger a scale-out: {:?}", out.scale_events);
    assert!(out.per_replica.len() > 1, "fleet never grew");
    for e in &out.scale_events {
        assert!(
            (1..=3).contains(&e.fleet_after),
            "fleet bound violated: {e:?}"
        );
    }
    // spawned replicas actually absorb load
    let counts = out.per_replica_counts();
    assert!(
        counts.iter().filter(|&&c| c > 0).count() >= 2,
        "spawned replicas starved: {counts:?}"
    );
    // elasticity: cheaper than holding max_replicas the whole run
    assert!(
        out.replica_steps < 3.0 * out.virtual_duration,
        "replica-steps {} vs static max {}",
        out.replica_steps,
        3.0 * out.virtual_duration
    );
    // lifecycle events ride the spawned replica's own output/timeline
    let spawn = out
        .scale_events
        .iter()
        .find(|e| e.action == ScaleAction::ScaleOut)
        .unwrap();
    assert!(out.per_replica[spawn.replica]
        .scale_events
        .iter()
        .any(|e| e.action == ScaleAction::ScaleOut));
    assert!(!out.per_replica[spawn.replica].timeline.events().is_empty());
}

/// A replica whose drift events keep firing gets deweighted and
/// retired: after the retirement instant the router never sends it
/// another request, and the trace still completes (it drains).
#[test]
fn retires_a_chronically_drifting_replica() {
    let cfg = ServingConfig {
        // drift_threshold 0.5: only the injected 3x step can trend the
        // residual that far — profiling interpolation error cannot flag
        // the healthy replica and steal the retirement
        calibration: CalibrationConfig { drift_threshold: 0.5, ..CalibrationConfig::on() },
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let ccfg = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::RoundRobin,
        replica_specs: vec![
            ReplicaSpec::default(),
            // a brutal co-tenant lands on replica 1 at t=1
            ReplicaSpec {
                drift: Some(DriftSpec { step_at_s: 1.0, step_factor: 3.0, ..DriftSpec::none() }),
                ..Default::default()
            },
        ],
        autoscale: AutoscaleConfig {
            // hair-trigger retirement; capacity actions disabled so the
            // health path is isolated
            retire_drift_events: 1,
            retire_windows: 1,
            control_interval_s: 0.5,
            cooldown_in_s: 1.0,
            cooldown_out_s: 1.0,
            scale_out_util: f64::INFINITY,
            scale_in_util: 0.0,
            reprofile_residual: f64::INFINITY,
            ..AutoscaleConfig::on(1, 3)
        },
        ..Default::default()
    };
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 60, 7);
    let out = serve_cluster(
        System::Bullet,
        &cfg,
        server.perf(),
        server.ground_truth(),
        &trace,
        3,
        &ccfg,
    );
    assert_eq!(out.records.len(), trace.len(), "retired replica must drain");
    let retire = out
        .scale_events
        .iter()
        .find(|e| e.action == ScaleAction::Retire)
        .unwrap_or_else(|| panic!("chronic drifter never retired: {:?}", out.scale_events));
    assert_eq!(retire.replica, 1, "the drifting replica is the victim");
    for (r, &(id, k)) in trace.iter().zip(&out.assignments) {
        assert_eq!(r.id, id);
        if r.arrival > retire.t {
            assert_ne!(k, 1, "request {} routed to the retired replica at t={}", id, r.arrival);
        }
    }
    // retirement is credited: the retired replica's lease ends at
    // retire-or-drain, not end-of-run (a drained core's clock freezes,
    // so billing strictly undercuts 2 x makespan)
    assert!(
        out.replica_steps < 2.0 * out.virtual_duration,
        "replica-steps {} must credit the retirement (makespan {})",
        out.replica_steps,
        out.virtual_duration
    );
}

/// Square-wave arrivals — bursts that clear the scale-out bar, lulls
/// that clear the scale-in bar — must never produce an out→in flap
/// within one scale-in cool-down window, and the fleet stays within
/// its bounds throughout.
#[test]
fn never_flaps_under_oscillating_load() {
    let (cfg, perf, gt) = setup();
    let mut trace: Vec<Request> = Vec::new();
    let mut id = 0u64;
    for cycle in 0..4 {
        let t0 = cycle as f64 * 10.0;
        // 1.5 s burst of heavy prompts...
        for i in 0..30 {
            trace.push(Request {
                id,
                arrival: t0 + i as f64 * 0.05,
                input_len: 2048,
                output_len: 16,
                ..Default::default()
            });
            id += 1;
        }
        // ...then a quiet tail
        for i in 0..4 {
            trace.push(Request {
                id,
                arrival: t0 + 2.0 + i as f64 * 2.0,
                input_len: 256,
                output_len: 16,
                ..Default::default()
            });
            id += 1;
        }
    }
    let asc = AutoscaleConfig {
        control_interval_s: 0.5,
        rate_window_s: 3.0,
        cooldown_out_s: 2.0,
        cooldown_in_s: 6.0,
        ..AutoscaleConfig::on(1, 4)
    };
    let ccfg = ClusterConfig {
        replicas: 1,
        router: RouterPolicy::LeastKv,
        autoscale: asc.clone(),
        ..Default::default()
    };
    let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 9, &ccfg);
    assert_eq!(out.records.len(), trace.len());
    assert!(
        out.scale_events.iter().any(|e| e.action == ScaleAction::ScaleOut),
        "bursts must scale the fleet out: {:?}",
        out.scale_events
    );
    let mut last_out = f64::NEG_INFINITY;
    for e in &out.scale_events {
        match e.action {
            ScaleAction::ScaleOut => last_out = e.t,
            ScaleAction::ScaleIn | ScaleAction::Retire => assert!(
                e.t - last_out >= asc.cooldown_in_s - 1e-9,
                "flap: removal at t={} only {:.2}s after a scale-out",
                e.t,
                e.t - last_out
            ),
            ScaleAction::Reprofile => {}
        }
        assert!((1..=4).contains(&e.fleet_after), "fleet bound violated: {e:?}");
    }
}

/// `--autoscale off` (the default config) is bit-identical to the PR 3
/// fixed-fleet path, and a CLAMPED autoscaler (min == max == replicas,
/// health actions disabled) routes bit-identically through the dynamic
/// path — the machinery provably adds nothing until it can act.
#[test]
fn autoscale_off_is_bit_identical_to_fixed_fleet() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 24, 5);
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastKv,
        RouterPolicy::SloSlack,
        RouterPolicy::PrefixAffinity,
    ] {
        let off = ClusterConfig { replicas: 3, router, ..Default::default() };
        let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &off);
        assert!(a.scale_events.is_empty(), "{}: off-path emitted events", router.label());
        assert!(
            (a.replica_steps - 3.0 * a.virtual_duration).abs() < 1e-9,
            "{}: fixed fleet holds every replica for the whole run",
            router.label()
        );
        let clamped = ClusterConfig {
            autoscale: AutoscaleConfig {
                retire_drift_events: u64::MAX,
                reprofile_residual: f64::INFINITY,
                ..AutoscaleConfig::on(3, 3)
            },
            ..off.clone()
        };
        let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &clamped);
        assert_eq!(a.records, b.records, "{}: records diverged", router.label());
        assert_eq!(a.assignments, b.assignments, "{}: routing diverged", router.label());
        assert!(b.scale_events.is_empty(), "{}: clamped autoscaler acted", router.label());
    }
}

/// Autoscaled runs replay bit-identically — the controller is a pure
/// function of the arrival stream and replica state.
#[test]
fn autoscaled_runs_are_deterministic() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::azure_code(), 15.0, 40, 21);
    let ccfg = ClusterConfig {
        replicas: 1,
        router: RouterPolicy::LeastKv,
        autoscale: quick_asc(1, 3),
        ..Default::default()
    };
    let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 13, &ccfg);
    let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 13, &ccfg);
    assert_eq!(a.records, b.records);
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.scale_events, b.scale_events);
    assert_eq!(a.replica_steps, b.replica_steps);
}
