//! Integration over the simulation serving stack: coordinator →
//! scheduler → engines → simulator, plus the live threaded engine when
//! artifacts are available.

use bullet::baselines::{run_system, System};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::engine::live_engine::serve_live;
use bullet::engine::sim_engine::{serve_bullet, SimEngineOptions};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::{goodput_req_s, summarize};
use bullet::perf::PerfModel;
use bullet::runtime::ModelRuntime;
use bullet::workload::{generate_n_requests, generate_sessions, Dataset, Request, SessionProfile};
use std::path::PathBuf;

/// The conversational stress trace shared by the prefix-reuse tests: 30
/// sessions arriving fast with short think times, so re-prefilled
/// context saturates a single GPU when the cache is off.
fn stress_sessions(seed: u64) -> Vec<bullet::workload::Request> {
    let profile = SessionProfile {
        think_mu: 0.7, // median ~2 s between turns
        min_turns: 3,
        max_turns: 5,
        ..SessionProfile::conversational()
    };
    generate_sessions(&profile, 12.0, 30, seed)
}

fn sim_setup() -> (PerfModel, GroundTruth) {
    (
        PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b()),
        GroundTruth::new(GpuSpec::a100()),
    )
}

#[test]
fn coordinator_end_to_end_with_profiling() {
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let out = server.serve_dataset(&Dataset::azure_code(), 4.0, 40, 17);
    assert_eq!(out.records.len(), 40);
    let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
    // sanity envelope for the simulated A100 + Llama-8B
    assert!(s.mean_ttft < 5.0, "ttft {}", s.mean_ttft);
    assert!(s.mean_tpot < 0.25, "tpot {}", s.mean_tpot);
    assert!(s.slo_attainment > 0.3, "slo {}", s.slo_attainment);
}

#[test]
fn bullet_vs_baselines_ordering_holds() {
    // The paper's qualitative result on a congested code workload:
    // Bullet's mean TTFT beats every chunked-prefill system, and its
    // SLO attainment is at least as good.
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let trace = generate_n_requests(&Dataset::azure_code(), 6.0, 60, 23);

    let bullet = summarize(
        &run_system(System::Bullet, &cfg, server.perf(), server.ground_truth(), &trace, 1),
        &cfg.slo,
        None,
    );
    for sys in [System::Vllm1024, System::Sglang1024, System::Sglang2048] {
        let base = summarize(
            &run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, 1),
            &cfg.slo,
            None,
        );
        assert!(
            bullet.mean_ttft < base.mean_ttft,
            "{}: bullet ttft {} vs {}",
            sys.label(),
            bullet.mean_ttft,
            base.mean_ttft
        );
        assert!(
            bullet.slo_attainment >= base.slo_attainment - 0.05,
            "{}: bullet slo {} vs {}",
            sys.label(),
            bullet.slo_attainment,
            base.slo_attainment
        );
    }
}

#[test]
fn ablations_are_distinct_systems() {
    let cfg = ServingConfig::default();
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 29);
    let mut results = Vec::new();
    for sys in System::ablation_set() {
        let s = summarize(
            &run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, 2),
            &cfg.slo,
            None,
        );
        results.push((sys.label(), s.mean_ttft, s.mean_tpot));
    }
    // full Bullet should not be the worst on either metric
    let bullet = results.last().unwrap().clone();
    let worst_ttft = results.iter().map(|x| x.1).fold(0.0, f64::max);
    let worst_tpot = results.iter().map(|x| x.2).fold(0.0, f64::max);
    assert!(bullet.1 < worst_ttft || bullet.2 < worst_tpot, "{results:?}");
}

/// ISSUE-2 acceptance bar: on a conversational trace with shared system
/// prompts, prefix-cache-on beats cache-off on BOTH mean TTFT and
/// goodput, with a non-zero hit rate.
#[test]
fn prefix_cache_beats_cold_serving_on_conversational_trace() {
    let (perf, gt) = sim_setup();
    let trace = stress_sessions(11);
    let serve = |prefix_cache: bool| {
        let cfg = ServingConfig {
            slo: SloSpec::sharegpt(),
            prefix_cache,
            ..ServingConfig::default()
        };
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        (out, cfg)
    };
    let (off, cfg) = serve(false);
    let (on, _) = serve(true);
    assert_eq!(off.records.len(), trace.len());
    assert_eq!(on.records.len(), trace.len());

    // the cache actually engaged
    assert!(on.prefix.hits > 0, "no prefix hits on a multi-turn trace: {:?}", on.prefix);
    assert!(on.prefix.cached_tokens > 0);
    assert_eq!(off.prefix.hits, 0, "cache-off run must not touch the index");

    let s_off = summarize(&off.records, &cfg.slo, Some(off.virtual_duration));
    let s_on = summarize(&on.records, &cfg.slo, Some(on.virtual_duration));
    assert!(
        s_on.mean_ttft < s_off.mean_ttft,
        "prefix cache must cut mean TTFT: on {} vs off {}",
        s_on.mean_ttft,
        s_off.mean_ttft
    );
    let g_off = goodput_req_s(&off.records, &cfg.slo, Some(off.virtual_duration));
    let g_on = goodput_req_s(&on.records, &cfg.slo, Some(on.virtual_duration));
    assert!(
        g_on > g_off,
        "prefix cache must raise goodput on a saturated trace: on {g_on} vs off {g_off}"
    );
}

/// Determinism extends to the prefix-cache path: identical runs produce
/// bit-identical records AND identical cache counters.
#[test]
fn prefix_cache_runs_are_deterministic() {
    let (perf, gt) = sim_setup();
    let trace = stress_sessions(23);
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        prefix_cache: true,
        ..ServingConfig::default()
    };
    let a = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    let b = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    assert_eq!(a.records, b.records);
    assert_eq!(a.prefix, b.prefix);
}

/// With no content hashes to match (single-turn datasets), turning the
/// cache on changes nothing: records are bit-identical to cache-off.
#[test]
fn prefix_cache_is_inert_on_sessionless_traffic() {
    let (perf, gt) = sim_setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 30, 5);
    let run = |prefix_cache: bool| {
        let cfg = ServingConfig { prefix_cache, ..ServingConfig::default() };
        serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default())
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.records, on.records);
    assert_eq!(on.prefix.hits, 0);
    assert_eq!(on.prefix.lookups, 0, "hash-less requests skip the index entirely");
}

/// The chunked baselines ride the same admission fast path: cache-on
/// completes the conversational trace and earns hits there too.
#[test]
fn chunked_engines_share_the_prefix_fast_path() {
    let (perf, gt) = sim_setup();
    let trace = stress_sessions(31);
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        prefix_cache: true,
        ..ServingConfig::default()
    };
    for sys in [System::Sglang1024, System::Nanoflow] {
        let recs = run_system(sys, &cfg, &perf, &gt, &trace, 9);
        assert_eq!(recs.len(), trace.len(), "{} lost records", sys.label());
        for r in recs {
            assert!(r.finish_time >= r.first_token_time, "{}: req {}", sys.label(), r.id);
        }
    }
}

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("meta.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping live test: run `make artifacts`");
        None
    }
}

#[test]
fn live_engine_serves_real_model() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).unwrap();
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| (3..(20 + i as i32 * 7)).collect())
        .collect();
    let trace: Vec<Request> = (0..6u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.01,
            input_len: prompts[i as usize].len(),
            output_len: 5 + (i as usize % 3),
            ..Default::default()
        })
        .collect();
    let (records, stats) = serve_live(rt, trace, prompts).unwrap();
    assert_eq!(records.len(), 6);
    for r in &records {
        assert!(r.first_token_time >= r.prefill_start);
        assert!(r.finish_time >= r.first_token_time);
        assert!(r.ttft() < 60.0);
    }
    assert!(stats.decode_iterations > 0);
    assert!(stats.max_batch_seen >= 1);
}

#[test]
fn live_engine_continuous_batching_overlaps_requests() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).unwrap();
    // all arrive at once with long outputs: the decode batch must grow
    // beyond 1 (continuous batching), proving concurrent membership.
    let prompts: Vec<Vec<i32>> = (0..4).map(|_| (3..30).collect()).collect();
    let trace: Vec<Request> = (0..4u64)
        .map(|i| Request {
            id: i,
            arrival: 0.0,
            input_len: 27,
            output_len: 24,
            ..Default::default()
        })
        .collect();
    let (records, stats) = serve_live(rt, trace, prompts).unwrap();
    assert_eq!(records.len(), 4);
    assert!(
        stats.max_batch_seen >= 2,
        "expected batched decode, max batch {}",
        stats.max_batch_seen
    );
}

#[test]
fn live_engine_honors_cancellation_and_deadlines() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).unwrap();
    let prompts: Vec<Vec<i32>> = (0..4).map(|_| (3..24).collect()).collect();
    // 0 completes; 1 is cancelled before it ever runs; 2 expires on a
    // deadline already in the past; 3 completes
    let trace: Vec<Request> = (0..4u64)
        .map(|i| Request {
            id: i,
            arrival: 0.0,
            input_len: 21,
            output_len: 6,
            cancel_at: (i == 1).then_some(0.0),
            deadline: (i == 2).then_some(0.0),
            ..Default::default()
        })
        .collect();
    let (records, stats) = serve_live(rt, trace, prompts).unwrap();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.expired, 1);
    assert_eq!(records.len(), 2);
    let mut ids: Vec<u64> = records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 3]);
}
