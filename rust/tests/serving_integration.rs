//! Integration over the simulation serving stack: coordinator →
//! scheduler → engines → simulator, plus the live threaded engine when
//! artifacts are available.

use bullet::baselines::{run_system, System};
use bullet::config::{ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::engine::live_engine::{serve_live, LiveRequest};
use bullet::metrics::summarize;
use bullet::runtime::ModelRuntime;
use bullet::workload::{generate_n_requests, Dataset};
use std::path::PathBuf;

#[test]
fn coordinator_end_to_end_with_profiling() {
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let out = server.serve_dataset(&Dataset::azure_code(), 4.0, 40, 17);
    assert_eq!(out.records.len(), 40);
    let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
    // sanity envelope for the simulated A100 + Llama-8B
    assert!(s.mean_ttft < 5.0, "ttft {}", s.mean_ttft);
    assert!(s.mean_tpot < 0.25, "tpot {}", s.mean_tpot);
    assert!(s.slo_attainment > 0.3, "slo {}", s.slo_attainment);
}

#[test]
fn bullet_vs_baselines_ordering_holds() {
    // The paper's qualitative result on a congested code workload:
    // Bullet's mean TTFT beats every chunked-prefill system, and its
    // SLO attainment is at least as good.
    let cfg = ServingConfig {
        slo: SloSpec::azure_code(),
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let trace = generate_n_requests(&Dataset::azure_code(), 6.0, 60, 23);

    let bullet = summarize(
        &run_system(System::Bullet, &cfg, server.perf(), server.ground_truth(), &trace, 1),
        &cfg.slo,
        None,
    );
    for sys in [System::Vllm1024, System::Sglang1024, System::Sglang2048] {
        let base = summarize(
            &run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, 1),
            &cfg.slo,
            None,
        );
        assert!(
            bullet.mean_ttft < base.mean_ttft,
            "{}: bullet ttft {} vs {}",
            sys.label(),
            bullet.mean_ttft,
            base.mean_ttft
        );
        assert!(
            bullet.slo_attainment >= base.slo_attainment - 0.05,
            "{}: bullet slo {} vs {}",
            sys.label(),
            bullet.slo_attainment,
            base.slo_attainment
        );
    }
}

#[test]
fn ablations_are_distinct_systems() {
    let cfg = ServingConfig::default();
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 29);
    let mut results = Vec::new();
    for sys in System::ablation_set() {
        let s = summarize(
            &run_system(sys, &cfg, server.perf(), server.ground_truth(), &trace, 2),
            &cfg.slo,
            None,
        );
        results.push((sys.label(), s.mean_ttft, s.mean_tpot));
    }
    // full Bullet should not be the worst on either metric
    let bullet = results.last().unwrap().clone();
    let worst_ttft = results.iter().map(|x| x.1).fold(0.0, f64::max);
    let worst_tpot = results.iter().map(|x| x.2).fold(0.0, f64::max);
    assert!(bullet.1 < worst_ttft || bullet.2 < worst_tpot, "{results:?}");
}

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("meta.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping live test: run `make artifacts`");
        None
    }
}

#[test]
fn live_engine_serves_real_model() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).unwrap();
    let trace: Vec<LiveRequest> = (0..6)
        .map(|i| LiveRequest {
            id: i,
            arrival: i as f64 * 0.01,
            prompt: (3..(20 + i as i32 * 7)).collect(),
            output_len: 5 + (i as usize % 3),
        })
        .collect();
    let (records, stats) = serve_live(rt, trace).unwrap();
    assert_eq!(records.len(), 6);
    for r in &records {
        assert!(r.first_token_time >= r.prefill_start);
        assert!(r.finish_time >= r.first_token_time);
        assert!(r.ttft() < 60.0);
    }
    assert!(stats.decode_iterations > 0);
    assert!(stats.max_batch_seen >= 1);
}

#[test]
fn live_engine_continuous_batching_overlaps_requests() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).unwrap();
    // all arrive at once with long outputs: the decode batch must grow
    // beyond 1 (continuous batching), proving concurrent membership.
    let trace: Vec<LiveRequest> = (0..4)
        .map(|i| LiveRequest {
            id: i,
            arrival: 0.0,
            prompt: (3..30).collect(),
            output_len: 24,
        })
        .collect();
    let (records, stats) = serve_live(rt, trace).unwrap();
    assert_eq!(records.len(), 4);
    assert!(
        stats.max_batch_seen >= 2,
        "expected batched decode, max batch {}",
        stats.max_batch_seen
    );
}
