//! Property-based tests over coordinator/substrate invariants, via the
//! in-tree `testing::prop` mini-framework (offline stand-in for proptest).

use bullet::cluster::{AutoscaleConfig, Autoscaler, ReplicaHealth, ScaleDecision};
use bullet::config::{CalibrationConfig, GpuSpec, ModelSpec, ServingConfig};
use bullet::engine::sim_engine::{serve_bullet, SimEngineOptions};
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::gpu::{wave_quantization_idle_ratio, KernelDesc, OpClass};
use bullet::kvcache::prefix::PrefixIndex;
use bullet::kvcache::{KvPool, BLOCK_TOKENS};
use bullet::model::phases::{decode_layer_kernels, prefill_layer_kernels, PhaseShape};
use bullet::perf::grid::{Grid2, Grid3};
use bullet::perf::{OnlineCalibrator, PerfModel, PerfPredictor};
use bullet::resource::Partition;
use bullet::sched::{DecodeReqState, PrefillBatch, PrefillReq, SloScheduler, SystemState};
use bullet::testing::content_chain;
use bullet::testing::prop::{check, forall};
use bullet::util::stats;
use bullet::workload::{annotate_lifecycle, generate_n_requests, Dataset, LifecycleProfile};

#[test]
fn prop_wave_quantization_bounds_and_alignment() {
    forall(101, 500, |g| {
        let grid = g.usize_in(1, 4096);
        let sms = g.usize_in(1, 192);
        let s = wave_quantization_idle_ratio(grid, sms);
        check((0.0..1.0).contains(&s), format!("s={s} out of [0,1)"))?;
        // aligned grids have zero idle
        let aligned = grid.div_ceil(sms) * sms;
        let s2 = wave_quantization_idle_ratio(aligned, sms);
        check(s2.abs() < 1e-12, format!("aligned grid idle {s2}"))
    });
}

#[test]
fn prop_roofline_monotone_in_sms() {
    // more SMs never makes a kernel slower (solo).
    let gt = GroundTruth::noiseless(GpuSpec::a100());
    forall(102, 300, |g| {
        let flops = g.f64_in(1e9, 1e13);
        let bytes = g.f64_in(1e6, 1e10);
        let op = *g.pick(&[
            OpClass::GemmMlp,
            OpClass::GemmQkv,
            OpClass::AttnPrefill,
            OpClass::AttnDecode,
            OpClass::Elementwise,
        ]);
        // aligned grid isolates the scaling curve from wave effects
        let sms = g.usize_in(2, 108);
        let k = KernelDesc::new(op, flops, bytes, sms * 4);
        let t_small = gt.solo_time(&k, sms);
        let k_full = KernelDesc::new(op, flops, bytes, 108 * 4);
        let t_full = gt.solo_time(&k_full, 108);
        check(
            t_full <= t_small * 1.0001,
            format!("{op:?}: full {t_full} > {sms}-SM {t_small}"),
        )
    });
}

#[test]
fn prop_simulator_work_conservation() {
    // Total FLOPs/bytes integrated by the simulator equal what was
    // submitted, regardless of stream layout and contention.
    forall(103, 60, |g| {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let mut sim = Simulator::new(gt, g.u64_in(0, u64::MAX));
        let split = g.usize_in(10, 98);
        let a = sim.create_stream(SmMask::first(split), "a");
        let b = sim.create_stream(SmMask::last(108 - split, 108), "b");
        let mut flops = 0.0;
        let mut bytes = 0.0;
        for _ in 0..g.usize_in(1, 10) {
            let f = g.f64_in(1e9, 1e12);
            let by = g.f64_in(1e6, 1e9);
            let stream = if g.bool() { a } else { b };
            let op = *g.pick(&[OpClass::GemmMlp, OpClass::AttnDecode, OpClass::Elementwise]);
            sim.submit(stream, KernelDesc::new(op, f, by, g.usize_in(1, 2048)));
            flops += f;
            bytes += by;
        }
        sim.run_until_idle();
        let u = sim.total_util();
        check(
            (u.flops - flops).abs() / flops.max(1.0) < 1e-6
                && (u.bytes - bytes).abs() / bytes.max(1.0) < 1e-6,
            format!("work lost: {} vs {flops}", u.flops),
        )
    });
}

#[test]
fn prop_kv_pool_never_leaks_or_double_books() {
    forall(104, 200, |g| {
        let blocks = g.usize_in(4, 64);
        let mut pool = KvPool::new(blocks * BLOCK_TOKENS);
        let mut live: Vec<(u64, usize)> = Vec::new();
        for step in 0..g.usize_in(5, 40) {
            if g.bool() || live.is_empty() {
                let id = step as u64;
                let tokens = g.usize_in(1, 3 * BLOCK_TOKENS);
                if pool.can_grow(id, tokens) {
                    pool.grow(id, tokens).map_err(|e| e.to_string())?;
                    live.push((id, tokens));
                }
            } else {
                let idx = g.usize_in(0, live.len() - 1);
                let (id, _) = live.remove(idx);
                pool.release(id).map_err(|e| e.to_string())?;
            }
            // invariant: used blocks == ceil-sum of live seq lens
            let expect: usize = live
                .iter()
                .map(|(_, t)| t.div_ceil(BLOCK_TOKENS))
                .sum();
            check(
                pool.used_blocks() == expect,
                format!("used {} expect {expect}", pool.used_blocks()),
            )?;
        }
        // drain
        for (id, _) in live {
            pool.release(id).map_err(|e| e.to_string())?;
        }
        check(pool.used_blocks() == 0, "pool not drained")
    });
}

/// Refcounted-sharing invariants under a randomized
/// grow / fork / release / cache-insert / adopt / evict sequence:
/// - `used_blocks + free_blocks == capacity_blocks` at every step;
/// - every block's refcount equals its holder count (sequences listing
///   it + the prefix index), so no block is ever double-owned or leaked;
/// - refcounts never underflow (`decref` panics the test if they would).
#[test]
fn prop_kv_refcount_share_invariants() {
    forall(108, 150, |g| {
        let blocks = g.usize_in(8, 64);
        let mut pool = KvPool::new(blocks * BLOCK_TOKENS);
        let mut index = PrefixIndex::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _step in 0..g.usize_in(10, 60) {
            match g.usize_in(0, 6) {
                0 | 1 => {
                    // grow a new or existing sequence
                    let id = if live.is_empty() || g.bool() {
                        next_id += 1;
                        next_id
                    } else {
                        live[g.usize_in(0, live.len() - 1)]
                    };
                    let t = g.usize_in(1, 3 * BLOCK_TOKENS);
                    if pool.can_grow(id, t) {
                        pool.grow(id, t).map_err(|e| e.to_string())?;
                        if !live.contains(&id) {
                            live.push(id);
                        }
                    }
                }
                2 => {
                    // fork a live sequence copy-on-write
                    if !live.is_empty() {
                        let src = live[g.usize_in(0, live.len() - 1)];
                        next_id += 1;
                        pool.fork(src, next_id).map_err(|e| e.to_string())?;
                        live.push(next_id);
                    }
                }
                3 => {
                    // release
                    if !live.is_empty() {
                        let idx = g.usize_in(0, live.len() - 1);
                        let id = live.remove(idx);
                        pool.release(id).map_err(|e| e.to_string())?;
                    }
                }
                4 => {
                    // publish a live sequence's full blocks to the cache
                    if !live.is_empty() {
                        let id = live[g.usize_in(0, live.len() - 1)];
                        let s = pool.get(id).unwrap();
                        let nb = s.len / BLOCK_TOKENS;
                        let seq_blocks = s.blocks[..nb].to_vec();
                        // unique content per (seq, block) → per-seq chains
                        let contents: Vec<u64> =
                            (0..nb as u64).map(|b| (id << 32) | b).collect();
                        let chain = content_chain(&contents);
                        index.insert(&mut pool, &chain, &seq_blocks);
                    }
                }
                5 => {
                    // adopt a run of cached blocks as a new sequence —
                    // the prefix-hit admission path shares, not copies
                    let cached = index.cached_block_ids();
                    if !cached.is_empty() {
                        let k = g.usize_in(1, cached.len());
                        next_id += 1;
                        pool.adopt(next_id, &cached[..k]).map_err(|e| e.to_string())?;
                        live.push(next_id);
                    }
                }
                _ => {
                    // evict under synthetic memory pressure
                    index.evict_lru(&mut pool, g.usize_in(1, 8));
                }
            }
            // accounting identity
            check(
                pool.used_blocks() + pool.free_blocks() == pool.capacity_blocks(),
                format!(
                    "identity broken: used {} + free {} != cap {}",
                    pool.used_blocks(),
                    pool.free_blocks(),
                    pool.capacity_blocks()
                ),
            )?;
            // per-block refcount == holder count
            let mut holders = vec![0u32; pool.capacity_blocks()];
            for &id in &live {
                for &b in &pool.get(id).unwrap().blocks {
                    holders[b] += 1;
                }
            }
            for b in index.cached_block_ids() {
                holders[b] += 1;
            }
            for (b, &h) in holders.iter().enumerate() {
                check(
                    pool.refcount(b) == h,
                    format!("block {b}: refcount {} != holders {h}", pool.refcount(b)),
                )?;
            }
        }
        // drain: sequences first, then the cache — pool must come back whole
        for id in live {
            pool.release(id).map_err(|e| e.to_string())?;
        }
        index.clear(&mut pool);
        check(
            pool.used_blocks() == 0 && pool.free_blocks() == pool.capacity_blocks(),
            "pool not drained",
        )
    });
}

#[test]
fn prop_scheduler_decisions_always_legal() {
    // Whatever the system state, the decision must respect granularity,
    // floors and GPU bounds — and never pause decode while TPOT is the
    // violated constraint.
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let sched = SloScheduler::new(cfg.clone(), perf);
    forall(105, 300, |g| {
        let now = g.f64_in(0.0, 100.0);
        let n_decode = g.usize_in(0, 64);
        let decode: Vec<DecodeReqState> = (0..n_decode)
            .map(|i| DecodeReqState {
                id: i as u64,
                input_len: g.usize_in(16, 4096),
                ctx_len: g.usize_in(16, 8192),
                tokens_out: g.usize_in(1, 100),
                output_len: 200,
                decode_elapsed: g.f64_in(0.0, 20.0),
            })
            .collect();
        let prefill = if g.bool() {
            Some(PrefillBatch {
                reqs: vec![PrefillReq {
                    id: 1000,
                    arrival: g.f64_in(0.0, now),
                    input_len: g.usize_in(16, 16384),
                    output_len: 64,
                    ..Default::default()
                }],
                n_tokens: g.usize_in(16, 16384),
                layers_done: g.usize_in(0, 31),
                started_at: g.f64_in(0.0, now),
                ..Default::default()
            })
        } else {
            None
        };
        let waiting: Vec<PrefillReq> = (0..g.usize_in(0, 5))
            .map(|i| PrefillReq {
                id: 2000 + i as u64,
                arrival: g.f64_in(0.0, now),
                input_len: g.usize_in(16, 8192),
                output_len: 64,
                ..Default::default()
            })
            .collect();
        let mut st = SystemState {
            now,
            prefill,
            decode,
            waiting,
            partition: Partition::split(&GpuSpec::a100(), g.usize_in(6, 102)),
            total_layers: 32,
        };
        let d = sched.schedule(&mut st);
        let p = d.partition;
        check(p.prefill_sms <= 108 && p.decode_sms <= 108, "over GPU")?;
        check(
            p.prefill_sms % 2 == 0 && p.decode_sms % 2 == 0,
            format!("granularity violated: {p:?}"),
        )?;
        if st.phases_colocated() {
            check(
                p.prefill_sms + p.decode_sms >= 108 - 12,
                format!("GPU left idle: {p:?}"),
            )?;
        }
        // waiting queue must come back sorted by slack
        let slo = cfg.slo;
        for w in st.waiting.windows(2) {
            let sa = slo.ttft_budget(w[0].input_len) - (now - w[0].arrival);
            let sb = slo.ttft_budget(w[1].input_len) - (now - w[1].arrival);
            check(sa <= sb + 1e-9, "waiting not sorted by slack")?;
        }
        Ok(())
    });
}

#[test]
fn prop_phase_costs_scale_sanely() {
    let m = ModelSpec::llama31_8b();
    forall(106, 200, |g| {
        let t1 = g.usize_in(64, 8192);
        let t2 = t1 * 2;
        let p1: f64 = prefill_layer_kernels(&m, PhaseShape { tokens: t1, context: 0 })
            .iter()
            .map(|k| k.flops)
            .sum();
        let p2: f64 = prefill_layer_kernels(&m, PhaseShape { tokens: t2, context: 0 })
            .iter()
            .map(|k| k.flops)
            .sum();
        check(p2 > p1 * 1.9, format!("prefill flops not ~linear: {p1} {p2}"))?;
        let bs = g.usize_in(1, 128);
        let cl = g.usize_in(64, 8192);
        let d: f64 = decode_layer_kernels(&m, PhaseShape { tokens: bs, context: cl })
            .iter()
            .map(|k| k.bytes)
            .sum();
        let d2: f64 = decode_layer_kernels(&m, PhaseShape { tokens: bs, context: cl * 2 })
            .iter()
            .map(|k| k.bytes)
            .sum();
        check(d2 > d, "decode bytes must grow with context")
    });
}

/// Grid2/Grid3 interpolation is clamped (never escapes the node-value
/// envelope, even for far-outside probes) and monotone between knots
/// when the node data is monotone along each axis.
#[test]
fn prop_grid_interp_clamped_and_monotone() {
    fn sorted_axis(g: &mut bullet::testing::prop::Gen, n: usize) -> Vec<f64> {
        let mut x = g.f64_in(-100.0, 100.0);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(x);
            x += g.f64_in(0.1, 50.0); // strictly increasing
        }
        out
    }
    forall(109, 200, |g| {
        let n0 = g.usize_in(1, 8);
        let n1 = g.usize_in(1, 8);
        let (ax0, ax1) = (sorted_axis(g, n0), sorted_axis(g, n1));
        let mut grid = Grid2::new(ax0.clone(), ax1.clone(), 0.0);
        // monotone node data: cumulative non-negative increments
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for i in 0..n0 {
            for j in 0..n1 {
                let v = i as f64 * 3.0 + j as f64 + g.f64_in(0.0, 0.9);
                grid.set(i, j, v);
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        // clamped: far-outside probes stay inside the node envelope
        for (x0, x1) in [(-1e9, -1e9), (1e9, 1e9), (-1e9, 1e9)] {
            let v = grid.interp(x0, x1);
            check(
                v >= lo - 1e-9 && v <= hi + 1e-9,
                format!("clamp escaped: {v} not in [{lo},{hi}]"),
            )?;
        }
        // monotone in each argument between (and beyond) knots
        let span0 = ax0[n0 - 1] - ax0[0] + 1.0;
        let x1 = g.f64_in(ax1[0] - 1.0, ax1[n1 - 1] + 1.0);
        let a = g.f64_in(ax0[0] - 1.0, ax0[n0 - 1] + 1.0);
        let b = (a + g.f64_in(0.0, span0)).min(ax0[n0 - 1] + 1.0);
        check(
            grid.interp(a, x1) <= grid.interp(b, x1) + 1e-9,
            format!("not monotone along ax0 at x1={x1}: {a} -> {b}"),
        )?;
        // Grid3: same probe through a monotone cube
        let n2 = g.usize_in(1, 5);
        let ax2 = sorted_axis(g, n2);
        let mut g3 = Grid3::new(ax0.clone(), ax1.clone(), ax2.clone(), 0.0);
        for i in 0..n0 {
            for j in 0..n1 {
                for k in 0..n2 {
                    g3.set(i, j, k, i as f64 * 9.0 + j as f64 * 3.0 + k as f64);
                }
            }
        }
        let (x1, x2) = (
            g.f64_in(ax1[0] - 1.0, ax1[n1 - 1] + 1.0),
            g.f64_in(ax2[0] - 1.0, ax2[n2 - 1] + 1.0),
        );
        check(
            g3.interp(a, x1, x2) <= g3.interp(b, x1, x2) + 1e-9,
            "Grid3 not monotone along ax0",
        )?;
        let big = g3.interp(1e12, 1e12, 1e12);
        let top = (n0 - 1) as f64 * 9.0 + (n1 - 1) as f64 * 3.0 + (n2 - 1) as f64;
        check((big - top).abs() < 1e-9, format!("Grid3 clamp: {big} vs {top}"))
    });
}

/// The EWMA calibrator converges to a synthetic constant-bias ground
/// truth within a bounded number of samples, and never emits a
/// non-finite prediction — even when fed garbage observations.
#[test]
fn prop_calibrator_converges_and_stays_finite() {
    forall(110, 60, |g| {
        let inner = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let mut cal = OnlineCalibrator::new(inner.clone(), CalibrationConfig::on());
        let bias = g.f64_in(0.4, 3.0);
        let sl = g.usize_in(64, 8192);
        let pm = g.usize_in(2, 54) * 2;
        let contended = g.bool();
        let base = PerfModel::predict_prefill_layer(&inner, sl, 0, pm, contended);
        let n = 60;
        for _ in 0..n {
            cal.observe_prefill(sl, 0, pm, contended, 1, base * bias);
        }
        let learned = PerfPredictor::predict_prefill_layer(&cal, sl, 0, pm, contended) / base;
        check(
            (learned - bias).abs() / bias < 0.15,
            format!("after {n} samples learned {learned} vs bias {bias}"),
        )?;
        // hostile observations must never break finiteness
        for obs in [0.0, -5.0, f64::NAN, f64::INFINITY, 1e300, 1e-300] {
            cal.observe_prefill(sl, 0, pm, contended, 1, obs);
            cal.observe_decode(16, 512, pm, contended, obs);
        }
        let p1 = PerfPredictor::predict_prefill_layer(&cal, sl, 0, pm, contended);
        let p2 = PerfPredictor::predict_decode_step(&cal, 16, 512, pm, contended);
        check(
            p1.is_finite() && p1 > 0.0 && p2.is_finite() && p2 > 0.0,
            format!("non-finite prediction: {p1} / {p2}"),
        )
    });
}

/// Autoscaler safety invariants under randomized arrival/drift/health
/// sequences: the fleet never leaves `[min, max]`, and a removal
/// (scale-in OR retire) never lands within one scale-in cool-down of a
/// scale-out — the no-flap hysteresis guarantee.
#[test]
fn prop_autoscaler_fleet_bounds_and_hysteresis() {
    forall(111, 120, |g| {
        let min = g.usize_in(1, 3);
        let max = min + g.usize_in(0, 4);
        let out_util = g.f64_in(0.6, 0.9);
        let cfg = AutoscaleConfig {
            control_interval_s: g.f64_in(0.2, 1.0),
            rate_window_s: g.f64_in(2.0, 6.0),
            slo_headroom: g.f64_in(1.0, 1.5),
            scale_out_util: out_util,
            scale_in_util: g.f64_in(0.1, out_util - 0.15),
            cooldown_out_s: g.f64_in(0.5, 3.0),
            cooldown_in_s: g.f64_in(3.0, 10.0),
            retire_drift_events: g.u64_in(1, 3),
            retire_windows: g.usize_in(1, 3) as u32,
            reprofile_residual: g.f64_in(0.1, 0.5),
            reprofile_min_samples: g.u64_in(10, 100),
            ..AutoscaleConfig::on(min, max)
        };
        let cooldown_in = cfg.cooldown_in_s;
        let mut asc = Autoscaler::new(cfg);
        let mut fleet: Vec<ReplicaHealth> = (0..g.usize_in(min, max))
            .map(|i| ReplicaHealth { id: i, slowdown: 1.0, calib: Default::default() })
            .collect();
        let mut next_id = fleet.len();
        let mut t = 0.0;
        let mut last_out = f64::NEG_INFINITY;
        for _ in 0..g.usize_in(20, 60) {
            t += g.f64_in(0.05, 1.5);
            for _ in 0..g.usize_in(0, 15) {
                asc.note_arrival(t, g.usize_in(16, 4096), g.usize_in(1, 512));
            }
            // hostile health churn: slowdowns jump, drift events fire,
            // residuals spike
            for h in fleet.iter_mut() {
                h.slowdown = g.f64_in(0.8, 4.0);
                if g.bool() {
                    h.calib.drift_events += g.u64_in(0, 4);
                }
                h.calib.samples += g.u64_in(0, 40);
                h.calib.recent_abs_residual = g.f64_in(0.0, 0.8);
            }
            let nominal = g.f64_in(1e3, 5e4);
            if let Some(d) = asc.evaluate(t, nominal, &fleet) {
                match d {
                    ScaleDecision::ScaleOut => {
                        fleet.push(ReplicaHealth {
                            id: next_id,
                            slowdown: 1.0,
                            calib: Default::default(),
                        });
                        next_id += 1;
                        last_out = t;
                    }
                    ScaleDecision::ScaleIn(id) | ScaleDecision::Retire(id) => {
                        let gap = t - last_out;
                        check(
                            gap >= cooldown_in - 1e-9,
                            format!("flap: removal at t={t} only {gap:.2}s after scale-out"),
                        )?;
                        let pos = fleet.iter().position(|h| h.id == id);
                        check(pos.is_some(), format!("removed unknown replica {id}"))?;
                        fleet.remove(pos.unwrap());
                    }
                    ScaleDecision::Reprofile(id) => {
                        check(
                            fleet.iter().any(|h| h.id == id),
                            format!("reprofiled unknown replica {id}"),
                        )?;
                    }
                }
            }
            check(
                fleet.len() >= min && fleet.len() <= max,
                format!("fleet {} outside [{min}, {max}]", fleet.len()),
            )?;
        }
        Ok(())
    });
}

/// The fleet capacity estimate (Σ nominal / slowdown) is monotone
/// non-increasing in every replica's slowdown, and additive in fleet
/// membership.
#[test]
fn prop_fleet_capacity_monotone_in_slowdown() {
    forall(112, 300, |g| {
        let n = g.usize_in(1, 8);
        let nominal = g.f64_in(1e3, 1e5);
        let mut fleet: Vec<ReplicaHealth> = (0..n)
            .map(|i| ReplicaHealth {
                id: i,
                slowdown: g.f64_in(0.5, 5.0),
                calib: Default::default(),
            })
            .collect();
        let c0 = Autoscaler::fleet_capacity_tokens_per_s(nominal, &fleet);
        check(c0.is_finite() && c0 > 0.0, format!("capacity {c0}"))?;
        // slowing any one replica never raises capacity
        let k = g.usize_in(0, n - 1);
        fleet[k].slowdown += g.f64_in(0.0, 3.0);
        let c1 = Autoscaler::fleet_capacity_tokens_per_s(nominal, &fleet);
        check(c1 <= c0 + 1e-9, format!("slowdown raised capacity: {c0} -> {c1}"))?;
        // removing a replica strictly reduces capacity
        let gone = fleet.pop().unwrap();
        let c2 = Autoscaler::fleet_capacity_tokens_per_s(nominal, &fleet);
        check(
            c2 < c1 || fleet.is_empty(),
            format!("removing replica {} did not reduce capacity", gone.id),
        )
    });
}

/// Engine-level lifecycle leak detector: whatever mix of cancellations
/// and deadlines a random profile stamps onto a random trace, a full
/// Bullet run (a) partitions the trace between records and outcomes and
/// (b) hands every KV block back to the pool by teardown.
#[test]
fn prop_lifecycle_runs_never_leak_kv() {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    forall(113, 14, |g| {
        let n = g.usize_in(8, 24);
        let rate = g.f64_in(4.0, 14.0);
        let seed = g.u64_in(0, 1 << 20);
        let mut trace = generate_n_requests(&Dataset::sharegpt(), rate, n, seed);
        let profile = LifecycleProfile {
            cancel_frac: g.f64_in(0.0, 0.8),
            cancel_mu: g.f64_in(-1.0, 1.0),
            cancel_sigma: g.f64_in(0.2, 1.0),
            deadline_frac: g.f64_in(0.0, 1.0),
            deadline_mu: g.f64_in(-0.5, 1.0),
            deadline_sigma: g.f64_in(0.2, 0.8),
        };
        annotate_lifecycle(&mut trace, &profile, seed ^ 0xA5A5);
        let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
        check(
            out.records.len() + out.outcomes.len() == trace.len(),
            format!(
                "ledger not total: {} records + {} outcomes != {} submitted",
                out.records.len(),
                out.outcomes.len(),
                trace.len()
            ),
        )?;
        check(
            out.final_kv_blocks == 0,
            format!("{} KV blocks leaked at teardown", out.final_kv_blocks),
        )
    });
}

#[test]
fn prop_percentile_within_minmax() {
    forall(107, 300, |g| {
        let xs = g.vec(1, 200, |g| g.f64_in(-1e6, 1e6));
        let p = g.f64_in(0.0, 100.0);
        let v = stats::percentile(&xs, p);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        check(v >= lo - 1e-9 && v <= hi + 1e-9, format!("{v} not in [{lo},{hi}]"))
    });
}
