//! Hot-path allocation audit (PR 8 invariant): once the simulator's
//! rate-table cache and scratch buffers are warm, steady-state stepping
//! — advancing time with no completions — must not allocate at all, and
//! an idle simulator must stay allocation-free through `step()` /
//! `take_completions()`.
//!
//! The audit uses a counting `#[global_allocator]` wrapper, so this
//! file intentionally holds a SINGLE test function: a second test
//! running on another thread would bleed its allocations into the
//! counter.  (Deallocations are not counted — dropping is free to
//! release; the invariant is about acquiring.)

use bullet::config::GpuSpec;
use bullet::gpu::roofline::GroundTruth;
use bullet::gpu::simulator::Simulator;
use bullet::gpu::stream::SmMask;
use bullet::gpu::{KernelDesc, OpClass};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_stepping_is_allocation_free() {
    // Two overlapping streams, kernels long enough that nothing
    // completes inside the measured window.
    let gt = GroundTruth::noiseless(GpuSpec::a100());
    let mut sim = Simulator::new(gt, 3);
    let a = sim.create_stream(SmMask::first(72), "a");
    let b = sim.create_stream(SmMask::last(54, 108), "b");
    for _ in 0..4 {
        sim.submit(a, KernelDesc::new(OpClass::GemmMlp, 5e13, 5e13 / 300.0, 1080));
        sim.submit(b, KernelDesc::new(OpClass::AttnDecode, 1e12, 1e12, 108));
    }

    // Warm up: first refresh fills the rate table and scratch buffers
    // (allocation is expected and fine here).
    sim.run_for(1e-6);

    // Steady state: many fine-grained segments against one cached rate
    // table.  Kernels above need ~1e-1 s, the window advances ~1e-3 s —
    // no completion fires, so no path may allocate.
    let before = allocs();
    for _ in 0..1000 {
        sim.run_for(1e-6);
    }
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "steady-state run_for allocated {during} times over 1000 warm segments"
    );

    // The cache must actually be doing the work we think it is.
    let c = sim.rate_memo_counters();
    assert!(c.hits >= 1000, "expected ≥1000 rate-table hits, got {c:?}");

    // Drain, collect, and let the completion buffer settle.
    sim.run_until_idle();
    let _ = sim.take_completions();

    // Idle: stepping a drained simulator and polling completions must
    // also be allocation-free (step returns false via the cached-empty
    // rate table; take_completions swaps an empty Vec).
    let before = allocs();
    for _ in 0..100 {
        assert!(!sim.step(), "drained simulator must refuse to step");
        assert!(sim.take_completions().is_empty());
        sim.run_for(1e-6); // idle fast-forward path
    }
    let during = allocs() - before;
    assert_eq!(during, 0, "idle stepping allocated {during} times");
}
