//! Parallel/serial bitwise-parity suite: the cluster simulator's
//! tentpole invariant is that `sim_threads` NEVER changes output — for
//! every engine family, router, and autoscale setting, the parallel
//! backend's `ClusterOutput` is bit-identical to the serial backend's.
//!
//! The argument (see `cluster/mod.rs` docs): replicas are share-nothing
//! between dispatch horizons, each replica's evolution is a pure
//! function of its own command sequence, and routing consumes frozen
//! signal snapshots — so thread placement cannot leak into any bit.
//! This suite is the tripwire for anything that breaks one of those
//! three legs (a hidden cross-replica read, a history-dependent clock
//! jump, a signal computed off live state).

use bullet::baselines::System;
use bullet::cluster::{
    serve_cluster, AutoscaleConfig, ClusterConfig, ClusterOutput, ReplicaSpec, RouterPolicy,
};
use bullet::config::{CalibrationConfig, DriftSpec, GpuSpec, ModelSpec, ServingConfig};
use bullet::gpu::roofline::GroundTruth;
use bullet::perf::PerfModel;
use bullet::workload::{generate_n_requests, generate_sessions, Dataset, Request, SessionProfile};

/// Full-output equality, field by field, down to float bits.  The
/// records/assignments comparison alone would pass under a broken
/// barrier that only skews timelines or per-replica accounting — so
/// compare everything a run produces.
fn assert_identical(a: &ClusterOutput, b: &ClusterOutput, label: &str) {
    assert_eq!(a.records, b.records, "{label}: records diverge");
    assert_eq!(a.assignments, b.assignments, "{label}: routing diverges");
    assert_eq!(a.scale_events, b.scale_events, "{label}: scale events diverge");
    assert_eq!(
        a.virtual_duration.to_bits(),
        b.virtual_duration.to_bits(),
        "{label}: makespan diverges ({} vs {})",
        a.virtual_duration,
        b.virtual_duration
    );
    assert_eq!(
        a.replica_steps.to_bits(),
        b.replica_steps.to_bits(),
        "{label}: replica-steps diverge"
    );
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{label}: fleet size diverges");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        let l = format!("{label}: replica {i}");
        assert_eq!(x.records, y.records, "{l}: records");
        assert_eq!(x.scale_events, y.scale_events, "{l}: scale events");
        assert_eq!(x.timeline.samples(), y.timeline.samples(), "{l}: timeline samples");
        assert_eq!(x.timeline.events(), y.timeline.events(), "{l}: timeline events");
        assert_eq!(x.virtual_duration.to_bits(), y.virtual_duration.to_bits(), "{l}: duration");
        assert_eq!(x.total_flops.to_bits(), y.total_flops.to_bits(), "{l}: flops");
        assert_eq!(x.total_bytes.to_bits(), y.total_bytes.to_bits(), "{l}: bytes");
        assert_eq!(x.peak_kv_blocks, y.peak_kv_blocks, "{l}: peak kv");
        assert_eq!(x.reconfigs, y.reconfigs, "{l}: reconfigs");
        assert_eq!(x.decode_pauses, y.decode_pauses, "{l}: decode pauses");
        assert_eq!(x.prefix, y.prefix, "{l}: prefix stats");
        assert_eq!(x.calibration, y.calibration, "{l}: calibration");
        assert_eq!(x.ledger.to_bits(), y.ledger.to_bits(), "{l}: SM-second ledger");
        assert_eq!(x.trace_events, y.trace_events, "{l}: trace events");
    }
}

fn run_cell(
    sys: System,
    cfg: &ServingConfig,
    trace: &[Request],
    seed: u64,
    ccfg: &ClusterConfig,
    threads: usize,
) -> ClusterOutput {
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let ccfg = ClusterConfig { sim_threads: threads, ..ccfg.clone() };
    serve_cluster(sys, cfg, &perf, &gt, trace, seed, &ccfg)
}

/// Every engine × router × {autoscale off, on} cell at threads {1, 4}.
#[test]
fn every_engine_router_autoscale_cell_is_thread_invariant() {
    let mut seed = 4200u64;
    for sys in [System::Bullet, System::Sglang1024, System::Nanoflow] {
        for router in RouterPolicy::all() {
            for autoscaled in [false, true] {
                seed += 1;
                let label = format!(
                    "{} x {} x autoscale={}",
                    sys.label(),
                    router.label(),
                    autoscaled
                );
                let cfg = ServingConfig {
                    // calibration feeds the autoscaler real health
                    calibration: CalibrationConfig::on(),
                    ..ServingConfig::default()
                };
                let autoscale = if autoscaled {
                    AutoscaleConfig {
                        control_interval_s: 0.5,
                        rate_window_s: 2.0,
                        cooldown_out_s: 1.0,
                        cooldown_in_s: 4.0,
                        ..AutoscaleConfig::on(1, 4)
                    }
                } else {
                    AutoscaleConfig::off()
                };
                let ccfg = ClusterConfig { replicas: 3, router, autoscale, ..Default::default() };
                // saturating enough that replicas stay busy across
                // horizons (a drained-only fleet would vacuously pass)
                let trace = generate_n_requests(&Dataset::sharegpt(), 14.0, 28, seed);
                let serial = run_cell(sys, &cfg, &trace, seed, &ccfg, 1);
                let parallel = run_cell(sys, &cfg, &trace, seed, &ccfg, 4);
                assert_identical(&serial, &parallel, &label);
                assert_eq!(serial.records.len(), trace.len(), "{label}: lost records");
            }
        }
    }
}

/// The cell with the most cross-replica state: autoscaled fleet +
/// prefix-affinity routing + session traffic + prefix caching.  Session
/// pins, re-homing on retirement, private per-replica caches and scale
/// events all have to line up bit-for-bit.
#[test]
fn autoscaled_prefix_affinity_sessions_are_thread_invariant() {
    let cfg = ServingConfig {
        prefix_cache: true,
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    let ccfg = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::PrefixAffinity,
        autoscale: AutoscaleConfig {
            control_interval_s: 0.5,
            rate_window_s: 2.0,
            cooldown_out_s: 1.0,
            cooldown_in_s: 3.0,
            ..AutoscaleConfig::on(1, 4)
        },
        ..Default::default()
    };
    let trace = generate_sessions(&SessionProfile::conversational(), 2.5, 16, 31);
    let serial = run_cell(System::Bullet, &cfg, &trace, 8, &ccfg, 1);
    for threads in [2, 3, 4, 8] {
        let parallel = run_cell(System::Bullet, &cfg, &trace, 8, &ccfg, threads);
        assert_identical(&serial, &parallel, &format!("affinity+autoscale @ {threads}t"));
    }
    // the cell must actually exercise the machinery it claims to
    assert!(serial.prefix_stats().hits > 0, "no prefix hits — cell too cold");
}

/// Heterogeneous fleet under drift: per-replica GPUs, device-lottery
/// noise and online calibration — the most state a replica can carry.
#[test]
fn heterogeneous_drifting_fleet_is_thread_invariant() {
    let cfg = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    let slow_gpu = GpuSpec {
        peak_flops: GpuSpec::a100().peak_flops * 0.5,
        peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.5,
        ..GpuSpec::a100()
    };
    let ccfg = ClusterConfig {
        replicas: 3,
        router: RouterPolicy::SloSlack,
        replica_specs: vec![
            ReplicaSpec::default(),
            ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ReplicaSpec { gpu: None, drift: Some(DriftSpec::throttle()) },
        ],
        ..Default::default()
    };
    let trace = generate_n_requests(&Dataset::azure_code(), 12.0, 24, 37);
    let serial = run_cell(System::Bullet, &cfg, &trace, 11, &ccfg, 1);
    let parallel = run_cell(System::Bullet, &cfg, &trace, 11, &ccfg, 3);
    assert_identical(&serial, &parallel, "hetero+drift");
    let sd = serial.calibrated_slowdowns();
    assert!(sd[1] > sd[0], "slow replica must calibrate apart: {sd:?}");
}

/// PR 8 invariant: the hot-path caches (simulator rate table,
/// calibrated-prediction memo, router probe memo) are pure
/// accelerations — turning them all off (`ServingConfig::memo = false`)
/// reproduces every output bit.  Runs the cells that exercise all three
/// caches at once: slo-slack routing (probe memo) + calibration
/// (prediction memo) + drift (the rate table's hardest invalidation
/// regime), then an autoscaled cell on the parallel backend so memo
/// parity composes with thread parity.
#[test]
fn memo_off_is_bit_identical_to_memo_on() {
    let base = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    let cfg_off = ServingConfig { memo: false, ..base.clone() };
    let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 24, 51);

    let drifty = ClusterConfig {
        replicas: 3,
        router: RouterPolicy::SloSlack,
        replica_specs: vec![
            ReplicaSpec::default(),
            ReplicaSpec { gpu: None, drift: Some(DriftSpec::throttle()) },
            ReplicaSpec { gpu: None, drift: Some(DriftSpec::storm()) },
        ],
        ..Default::default()
    };
    let on = run_cell(System::Bullet, &base, &trace, 17, &drifty, 1);
    let off = run_cell(System::Bullet, &cfg_off, &trace, 17, &drifty, 1);
    assert_identical(&on, &off, "memo on/off (drifting slo-slack fleet)");
    // the memoized run must actually have exercised its caches, and the
    // reference run must never have consulted them
    assert!(on.router_memo.hits > 0, "probe memo never hit: {:?}", on.router_memo);
    assert!(on.rate_memo_stats().hits > 0, "rate table never reused");
    assert!(on.predict_memo_stats().hits > 0, "prediction memo never hit");
    assert_eq!(off.router_memo.lookups(), 0, "memo-off consulted the probe memo");
    assert_eq!(off.rate_memo_stats().hits, 0, "memo-off reused the rate table");
    assert_eq!(off.predict_memo_stats().lookups(), 0, "memo-off consulted the memo");

    let scaled = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::SloSlack,
        autoscale: AutoscaleConfig {
            control_interval_s: 0.5,
            rate_window_s: 2.0,
            cooldown_out_s: 1.0,
            cooldown_in_s: 4.0,
            ..AutoscaleConfig::on(1, 4)
        },
        ..Default::default()
    };
    let on = run_cell(System::Bullet, &base, &trace, 17, &scaled, 4);
    let off = run_cell(System::Bullet, &cfg_off, &trace, 17, &scaled, 4);
    assert_identical(&on, &off, "memo on/off (autoscaled, 4 threads)");
}

/// PR 10 invariant: tracing is a pure observer.  `TraceSpec::on()` must
/// reproduce every output bit of the default trace-off run — the only
/// permitted difference is the `trace_events` stream itself, which must
/// be non-empty, deterministic, and thread-invariant when enabled.
#[test]
fn trace_on_is_bit_identical_to_trace_off() {
    use bullet::obs::TraceSpec;
    let off_cfg = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    let on_cfg = ServingConfig { trace: TraceSpec::on(), ..off_cfg.clone() };
    let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 24, 61);
    let ccfg = ClusterConfig { replicas: 3, router: RouterPolicy::SloSlack, ..Default::default() };

    let off = run_cell(System::Bullet, &off_cfg, &trace, 19, &ccfg, 1);
    let on = run_cell(System::Bullet, &on_cfg, &trace, 19, &ccfg, 1);
    // strip the one permitted difference, then demand bit equality
    let mut on_stripped = on.clone();
    for r in &mut on_stripped.per_replica {
        r.trace_events.clear();
    }
    assert_identical(&off, &on_stripped, "trace on/off");
    let events: usize = on.per_replica.iter().map(|r| r.trace_events.len()).sum();
    assert!(events > 0, "trace-on run recorded no events");
    assert!(
        off.per_replica.iter().all(|r| r.trace_events.is_empty()),
        "trace-off run recorded events"
    );

    // the enabled event stream itself is thread-invariant
    let on4 = run_cell(System::Bullet, &on_cfg, &trace, 19, &ccfg, 4);
    assert_identical(&on, &on4, "trace on @ 1 vs 4 threads");
}

/// Oversubscription and odd shard shapes: more threads than replicas,
/// threads that don't divide the fleet, and a single-replica fleet all
/// reduce to the same bits.
#[test]
fn thread_count_never_changes_output_shape() {
    let cfg = ServingConfig::default();
    let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 15, 41);
    for replicas in [1, 2, 5] {
        let ccfg =
            ClusterConfig { replicas, router: RouterPolicy::LeastKv, ..Default::default() };
        let serial = run_cell(System::Bullet, &cfg, &trace, 13, &ccfg, 1);
        for threads in [2, 3, 7, 64] {
            let parallel = run_cell(System::Bullet, &cfg, &trace, 13, &ccfg, threads);
            assert_identical(
                &serial,
                &parallel,
                &format!("{replicas} replicas @ {threads} threads"),
            );
        }
    }
}
