//! Engine-harness parity: every system ported onto the shared serving
//! core must stay deterministic (bit-identical record streams across
//! repeated runs) and preserve the paper's cross-engine ordering
//! (Bullet's goodput at least matches chunked prefill's on the default
//! workload).

use bullet::baselines::{run_system, System};
use bullet::cluster::{ClusterConfig, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::goodput_req_s;
use bullet::perf::PerfModel;
use bullet::workload::{generate_n_requests, Dataset};

fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    (cfg, perf, gt)
}

/// Every ported engine, run twice on a fixed seeded trace, must emit a
/// bit-identical `RequestRecord` stream: the harness introduces no
/// hidden nondeterminism.
#[test]
fn every_engine_is_deterministic_on_the_harness() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 25, 1234);
    for sys in [
        System::Bullet,
        System::Vllm1024,
        System::Sglang1024,
        System::Sglang2048,
        System::Nanoflow,
        System::FixedSm(84),
        System::Naive,
        System::WithPartition,
        System::WithScheduler,
    ] {
        let a = run_system(sys, &cfg, &perf, &gt, &trace, 99);
        let b = run_system(sys, &cfg, &perf, &gt, &trace, 99);
        assert_eq!(a.len(), trace.len(), "{} lost records", sys.label());
        assert_eq!(a, b, "{} is nondeterministic", sys.label());
    }
}

/// Cross-engine sanity on the default (ShareGPT) workload: Bullet's
/// goodput — SLO-meeting requests per second — must not fall below
/// chunked prefill's.  This is the paper's qualitative headline and a
/// regression tripwire for the harness port.
#[test]
fn bullet_goodput_at_least_chunked_on_default_workload() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 50, 321);
    let bullet = run_system(System::Bullet, &cfg, &perf, &gt, &trace, 3);
    let chunked = run_system(System::Sglang1024, &cfg, &perf, &gt, &trace, 3);
    let gb = goodput_req_s(&bullet, &cfg.slo, None);
    let gc = goodput_req_s(&chunked, &cfg.slo, None);
    assert!(
        gb >= gc,
        "bullet goodput {gb:.3} req/s below chunked {gc:.3} req/s"
    );
}

/// Record streams stay causally consistent through the harness for every
/// engine family (prefill_start >= arrival, first token >= prefill
/// start, finish >= first token).
#[test]
fn records_causally_consistent_across_engines() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::azure_code(), 5.0, 20, 77);
    for sys in [System::Bullet, System::Sglang1024, System::Nanoflow] {
        for r in run_system(sys, &cfg, &perf, &gt, &trace, 5) {
            assert!(r.prefill_start >= r.arrival - 1e-9, "{}: req {}", sys.label(), r.id);
            assert!(r.first_token_time >= r.prefill_start, "{}: req {}", sys.label(), r.id);
            assert!(r.finish_time >= r.first_token_time, "{}: req {}", sys.label(), r.id);
        }
    }
}

/// The cluster layer preserves determinism end-to-end (dispatcher +
/// replicas), and the acceptance-bar scenario holds: 4 replicas deliver
/// >= 3x the trace throughput of 1 replica under saturation.
#[test]
fn cluster_scaling_hits_the_acceptance_bar() {
    let cfg = ServingConfig::default();
    let server = BulletServer::build(cfg.clone(), BuildOptions::default());
    // Azure-Code saturates a single GPU on serial compute-bound prefills
    // (decode, being weight-read-dominated, would let one GPU co-host the
    // whole batch and mask the scaling).
    let trace = generate_n_requests(&Dataset::azure_code(), 80.0, 120, 42);
    let one = server.serve_cluster(
        &trace,
        &ClusterConfig { replicas: 1, router: RouterPolicy::RoundRobin, ..Default::default() },
    );
    let four = server.serve_cluster(
        &trace,
        &ClusterConfig { replicas: 4, router: RouterPolicy::LeastKv, ..Default::default() },
    );
    assert_eq!(one.records.len(), trace.len());
    assert_eq!(four.records.len(), trace.len());
    // Same tokens served in a fraction of the time.  The demo-grade 3x
    // bar is asserted by examples/cluster_scaling.rs on its larger
    // trace; here the suite enforces a margin below it so perf-model
    // constant tweaks don't flake the default test run.
    let speedup = one.virtual_duration / four.virtual_duration;
    assert!(
        speedup >= 2.5,
        "4-replica speedup {speedup:.2}x below the 2.5x tripwire \
         (makespans: 1x {:.1}s, 4x {:.1}s)",
        one.virtual_duration,
        four.virtual_duration
    );

    let again = server.serve_cluster(
        &trace,
        &ClusterConfig { replicas: 4, router: RouterPolicy::LeastKv, ..Default::default() },
    );
    assert_eq!(four.records, again.records);
}
