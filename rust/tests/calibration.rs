//! Integration over the online-calibration subsystem: drift-off parity
//! (the new machinery is provably absent when disabled), drift efficacy,
//! calibrated-vs-frozen ordering, and heterogeneous-fleet determinism.

use bullet::baselines::{run_system, System};
use bullet::cluster::{serve_cluster, ClusterConfig, ReplicaSpec, RouterPolicy};
use bullet::config::{CalibrationConfig, DriftSpec, GpuSpec, ModelSpec, ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer};
use bullet::engine::sim_engine::{serve_bullet, SimEngineOptions};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::{goodput_req_s, summarize};
use bullet::perf::PerfModel;
use bullet::workload::{generate_n_requests, Dataset};

fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    (cfg, perf, gt)
}

/// The acceptance bar's parity half: with calibration off (the default)
/// and no drift regime, every system's run is bit-identical whether the
/// drift machinery is left at its default or explicitly disabled — the
/// subsystem adds no observable behavior until switched on.  (Together
/// with the bitwise pass-through unit tests on the disabled calibrator,
/// this pins the legacy outputs.)
#[test]
fn drift_off_runs_are_bit_identical_for_every_system() {
    let (cfg, perf, gt) = setup();
    let explicit = gt.clone().with_drift(DriftSpec::none());
    let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 1234);
    for sys in [
        System::Bullet,
        System::Sglang1024,
        System::Nanoflow,
        System::FixedSm(84),
    ] {
        let a = run_system(sys, &cfg, &perf, &gt, &trace, 99);
        let b = run_system(sys, &cfg, &perf, &explicit, &trace, 99);
        assert_eq!(a, b, "{} perturbed by inert drift machinery", sys.label());
    }
}

/// Drift regimes actually bite: a drifted run differs from the clean
/// run, and the drifted GPU serves strictly slower.
#[test]
fn drift_regimes_change_outcomes() {
    let (cfg, perf, gt) = setup();
    let drifted = gt.clone().with_drift(DriftSpec {
        step_at_s: 0.0,
        step_factor: 2.0,
        ..DriftSpec::none()
    });
    let trace = generate_n_requests(&Dataset::azure_code(), 4.0, 20, 17);
    let clean_out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    let drift_out = serve_bullet(&cfg, &perf, &drifted, &trace, &SimEngineOptions::default());
    assert_ne!(clean_out.records, drift_out.records);
    let s_clean = summarize(&clean_out.records, &cfg.slo, None);
    let s_drift = summarize(&drift_out.records, &cfg.slo, None);
    assert!(
        s_drift.mean_ttft > s_clean.mean_ttft,
        "a 2x SM co-tenant must slow prefill: {} vs {}",
        s_drift.mean_ttft,
        s_clean.mean_ttft
    );
}

/// Tripwire for the example's headline (examples/online_calibration.rs
/// asserts the strict demo-grade bars on its larger trace): under a
/// mid-run drift regime, calibrated Bullet's goodput must not fall
/// below frozen Bullet's, and its P90 TTFT must not be meaningfully
/// worse.
#[test]
fn calibrated_at_least_matches_frozen_under_drift() {
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        kv_capacity_tokens: 150_000,
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    let drifted = server.ground_truth().clone().with_drift(DriftSpec {
        step_at_s: 3.0,
        step_factor: 2.5,
        throttle_floor: 0.8,
        throttle_ramp_s: 20.0,
        lottery_sigma: 0.15,
    });
    let trace = generate_n_requests(&Dataset::sharegpt(), 9.0, 80, 42);
    let frozen = serve_bullet(
        &cfg,
        server.perf(),
        &drifted,
        &trace,
        &SimEngineOptions::default(),
    );
    let calibrated_cfg = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..cfg.clone()
    };
    let calibrated = serve_bullet(
        &calibrated_cfg,
        server.perf(),
        &drifted,
        &trace,
        &SimEngineOptions::default(),
    );
    assert_eq!(frozen.records.len(), 80);
    assert_eq!(calibrated.records.len(), 80);
    assert!(calibrated.calibration.samples > 50, "{:?}", calibrated.calibration);
    assert!(
        calibrated.calibration.slowdown > 1.1,
        "the calibrator must learn the drifted device: {:?}",
        calibrated.calibration
    );

    let s_f = summarize(&frozen.records, &cfg.slo, Some(frozen.virtual_duration));
    let s_c = summarize(&calibrated.records, &cfg.slo, Some(calibrated.virtual_duration));
    let g_f = goodput_req_s(&frozen.records, &cfg.slo, Some(frozen.virtual_duration));
    let g_c = goodput_req_s(&calibrated.records, &cfg.slo, Some(calibrated.virtual_duration));
    assert!(
        g_c >= g_f,
        "calibration must not lose goodput under drift: {g_c:.3} vs {g_f:.3}"
    );
    assert!(
        s_c.p90_ttft <= s_f.p90_ttft * 1.05,
        "calibration must not degrade P90 TTFT under drift: {} vs {}",
        s_c.p90_ttft,
        s_f.p90_ttft
    );
}

/// Heterogeneous clusters (per-replica GpuSpec/DriftSpec) stay fully
/// deterministic end-to-end, including calibration counters.
#[test]
fn heterogeneous_cluster_runs_are_deterministic() {
    let (mut cfg, perf, gt) = setup();
    cfg.calibration = CalibrationConfig::on();
    let slow = GpuSpec {
        peak_flops: GpuSpec::a100().peak_flops * 0.6,
        peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.6,
        ..GpuSpec::a100()
    };
    let ccfg = ClusterConfig {
        replicas: 3,
        router: RouterPolicy::SloSlack,
        replica_specs: vec![
            ReplicaSpec::default(),
            ReplicaSpec {
                drift: Some(DriftSpec { step_at_s: 0.0, step_factor: 1.8, ..DriftSpec::none() }),
                ..Default::default()
            },
            ReplicaSpec { gpu: Some(slow), drift: None },
        ],
        ..Default::default()
    };
    let trace = generate_n_requests(&Dataset::sharegpt(), 9.0, 18, 3);
    let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
    let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
    assert_eq!(a.records, b.records);
    assert_eq!(a.assignments, b.assignments);
    assert_eq!(a.calibrated_slowdowns(), b.calibrated_slowdowns());
    assert_eq!(a.records.len(), 18);
}

/// Pins the DECODE-BINDING regime the online-calibration example's
/// strict leg-2 bars (P90 TTFT + goodput, calibrated > frozen) depend
/// on: ShareGPT at 9 req/s on a KV-tight 150k-token pool under
/// compute-side drift must keep decode the binding phase — the KV
/// high-water near capacity and observed TPOT burning a large share of
/// its budget.  If this test starts failing after a perf-model or
/// workload tweak, restore the regime (widen `step_factor` / tighten
/// `kv_capacity_tokens`) rather than weakening the example's asserts —
/// that is the documented anti-flake lever from PR 3.
#[test]
fn leg2_regime_stays_decode_binding() {
    use bullet::kvcache::BLOCK_TOKENS;
    let cfg = ServingConfig {
        slo: SloSpec::sharegpt(),
        kv_capacity_tokens: 150_000,
        ..ServingConfig::default()
    };
    let server = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
    // the example's leg-2 drift regime
    let drifted = server.ground_truth().clone().with_drift(DriftSpec {
        step_at_s: 4.0,
        step_factor: 2.5,
        throttle_floor: 0.8,
        throttle_ramp_s: 30.0,
        lottery_sigma: 0.15,
    });
    // the example's exact leg-2 trace
    let trace = generate_n_requests(&Dataset::sharegpt(), 9.0, 150, 42);
    let frozen = serve_bullet(
        &cfg,
        server.perf(),
        &drifted,
        &trace,
        &SimEngineOptions::default(),
    );
    assert_eq!(frozen.records.len(), trace.len());
    let s = summarize(&frozen.records, &cfg.slo, Some(frozen.virtual_duration));
    // KV-tight: drift stalls decode, so most of the trace ends up
    // co-resident and the pool's high-water crowds its capacity (the
    // derived-default ~440k pool would sit under 25% here)
    let peak_tokens = frozen.peak_kv_blocks * BLOCK_TOKENS;
    assert!(
        peak_tokens * 2 >= cfg.kv_capacity_tokens,
        "regime drifted: peak KV {} tokens is below 50% of the {}-token pool — \
         no longer KV-tight",
        peak_tokens,
        cfg.kv_capacity_tokens
    );
    // decode-binding: observed TPOT burns a large share of its budget
    assert!(
        s.p90_tpot > 0.4 * cfg.slo.tpot_budget(),
        "regime drifted: P90 TPOT {:.1} ms is below 40% of the {:.0} ms budget — \
         decode is no longer binding",
        s.p90_tpot * 1e3,
        cfg.slo.tpot_budget() * 1e3
    );
}

/// The calibration counters ride the timeline when recording is on.
#[test]
fn timeline_carries_calibration_counters() {
    let cfg = ServingConfig {
        calibration: CalibrationConfig::on(),
        ..ServingConfig::default()
    };
    let (_, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 10, 8);
    let opts = SimEngineOptions {
        record_timeline: true,
        ..Default::default()
    };
    let out = serve_bullet(&cfg, &perf, &gt, &trace, &opts);
    let last = out.timeline.samples().last().unwrap();
    assert!(last.calib_samples > 0, "timeline must surface calibration progress");
    assert!(last.calib_residual.is_finite());
}
