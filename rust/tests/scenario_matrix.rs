//! Cluster-wide scenario regression matrix: every engine family ×
//! workload × router smoke-runs deterministically with fixed seeds, so
//! the autoscaler (or any future cluster change) cannot silently break
//! a shipped serving scenario.
//!
//! Each cell asserts: the trace completes (non-empty, no lost records),
//! every summary metric is finite, two identical runs are bitwise
//! identical (records AND routing decisions), and the parallel
//! simulation backend (`sim_threads = 4`) reproduces the serial
//! backend (`sim_threads = 1`) bit-for-bit.
//!
//! The matrix is `#[ignore]`d in the default test run and executed by
//! CI's dedicated `scenario-matrix` job (`cargo test --release --test
//! scenario_matrix -- --ignored`), so matrix failures are distinguishable
//! from unit failures.  Run it locally the same way.

use bullet::baselines::System;
use bullet::cluster::{serve_cluster, ClusterConfig, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::summarize;
use bullet::perf::PerfModel;
use bullet::workload::{generate_bursty_trace, trace_by_name, Dataset, Request};

const WORKLOADS: [&str; 4] = ["sharegpt", "azure-code", "conversational", "bursty"];

fn workload(name: &str, seed: u64) -> Vec<Request> {
    match name {
        // short burst shape: ~2 req/s with a 12 req/s spike in [1.5, 2.5)
        "bursty" => generate_bursty_trace(&Dataset::sharegpt(), 2.0, 12.0, 4.0, 1.5, 1.0, seed),
        other => trace_by_name(other, 6.0, 10, seed).expect("cataloged workload"),
    }
}

fn run_matrix(engines: &[System]) {
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let mut seed = 9000u64;
    for &sys in engines {
        for wl in WORKLOADS {
            for router in RouterPolicy::all() {
                seed += 1;
                let label = format!("{} x {} x {}", sys.label(), wl, router.label());
                let cfg = ServingConfig {
                    // sessions carry content hashes; the cache must ride
                    prefix_cache: wl == "conversational",
                    ..ServingConfig::default()
                };
                let trace = workload(wl, seed);
                assert!(!trace.is_empty(), "{label}: empty trace");
                let ccfg =
                    ClusterConfig { replicas: 2, router, sim_threads: 1, ..Default::default() };
                let a = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
                let b = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
                let par = ClusterConfig { sim_threads: 4, ..ccfg.clone() };
                let c = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &par);

                // non-empty completions, nothing lost
                assert_eq!(a.records.len(), trace.len(), "{label}: lost records");
                for r in &a.records {
                    assert!(r.finish_time >= r.first_token_time, "{label}: req {}", r.id);
                    assert!(r.first_token_time >= r.arrival, "{label}: req {}", r.id);
                }
                // bitwise determinism across two runs
                assert_eq!(a.records, b.records, "{label}: nondeterministic records");
                assert_eq!(a.assignments, b.assignments, "{label}: nondeterministic routing");
                // parallel/serial bitwise parity (sim_threads ∈ {1, 4})
                assert_eq!(a.records, c.records, "{label}: parallel records diverge");
                assert_eq!(a.assignments, c.assignments, "{label}: parallel routing diverges");
                assert_eq!(
                    a.virtual_duration.to_bits(),
                    c.virtual_duration.to_bits(),
                    "{label}: parallel makespan diverges"
                );
                assert!(c.scale_events.is_empty(), "{label}: fixed fleet scaled");

                // finite metrics
                let s = summarize(&a.records, &cfg.slo, Some(a.virtual_duration));
                for (name, v) in [
                    ("mean_ttft", s.mean_ttft),
                    ("p90_ttft", s.p90_ttft),
                    ("mean_tpot", s.mean_tpot),
                    ("p90_tpot", s.p90_tpot),
                    ("throughput_tok_s", s.throughput_tok_s),
                    ("goodput_frac", s.slo_attainment),
                    ("mean_e2e", s.mean_e2e),
                    ("duration", s.duration),
                ] {
                    assert!(
                        v.is_finite() && v >= 0.0,
                        "{label}: non-finite {name} = {v}"
                    );
                }
                assert!(s.throughput_tok_s > 0.0, "{label}: zero throughput");
            }
        }
    }
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_bullet() {
    run_matrix(&[System::Bullet]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_chunked() {
    run_matrix(&[System::Sglang1024]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_nanoflow() {
    run_matrix(&[System::Nanoflow]);
}
