//! Cluster-wide scenario regression matrix: every engine family ×
//! workload × router smoke-runs deterministically with fixed seeds, so
//! the autoscaler (or any future cluster change) cannot silently break
//! a shipped serving scenario.
//!
//! Each cell asserts: the trace completes (non-empty, no lost records),
//! every summary metric is finite, two identical runs are bitwise
//! identical (records AND routing decisions), the parallel simulation
//! backend (`sim_threads = 4`) reproduces the serial backend
//! (`sim_threads = 1`) bit-for-bit, the memoization-off reference
//! paths (`ServingConfig::memo = false`) reproduce the memoized run
//! bit-for-bit, tracing on (`TraceSpec::on()`) reproduces the trace-off
//! run bit-for-bit, and every replica's SM-second ledger conserves GPU
//! time exactly (categories sum to `num_sms × makespan`).
//!
//! The matrix is `#[ignore]`d in the default test run and executed by
//! CI's dedicated `scenario-matrix` job (`cargo test --release --test
//! scenario_matrix -- --ignored`), so matrix failures are distinguishable
//! from unit failures.  Run it locally the same way.

use bullet::baselines::System;
use bullet::cluster::{serve_cluster, ClusterConfig, FailureSpec, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::summarize;
use bullet::perf::PerfModel;
use bullet::workload::{
    annotate_lifecycle, generate_bursty_trace, trace_by_name, Dataset, LifecycleProfile, Request,
};

const WORKLOADS: [&str; 4] = ["sharegpt", "azure-code", "conversational", "bursty"];

fn workload(name: &str, seed: u64) -> Vec<Request> {
    match name {
        // short burst shape: ~2 req/s with a 12 req/s spike in [1.5, 2.5)
        "bursty" => generate_bursty_trace(&Dataset::sharegpt(), 2.0, 12.0, 4.0, 1.5, 1.0, seed),
        other => trace_by_name(other, 6.0, 10, seed).expect("cataloged workload"),
    }
}

fn run_matrix(engines: &[System]) {
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let mut seed = 9000u64;
    for &sys in engines {
        for wl in WORKLOADS {
            for router in RouterPolicy::all() {
                seed += 1;
                let label = format!("{} x {} x {}", sys.label(), wl, router.label());
                let cfg = ServingConfig {
                    // sessions carry content hashes; the cache must ride
                    prefix_cache: wl == "conversational",
                    ..ServingConfig::default()
                };
                let trace = workload(wl, seed);
                assert!(!trace.is_empty(), "{label}: empty trace");
                let ccfg =
                    ClusterConfig { replicas: 2, router, sim_threads: 1, ..Default::default() };
                let a = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
                let b = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
                let par = ClusterConfig { sim_threads: 4, ..ccfg.clone() };
                let c = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &par);
                // leg d: reference (memoization-off) paths — the hot-path
                // caches must be invisible in every output bit
                let cfg_off = ServingConfig { memo: false, ..cfg.clone() };
                let d = serve_cluster(sys, &cfg_off, &perf, &gt, &trace, seed, &ccfg);
                // leg e: tracing on — recording must be a pure observer
                let cfg_trace = ServingConfig {
                    trace: bullet::obs::TraceSpec::on(),
                    ..cfg.clone()
                };
                let e = serve_cluster(sys, &cfg_trace, &perf, &gt, &trace, seed, &ccfg);

                // non-empty completions, nothing lost
                assert_eq!(a.records.len(), trace.len(), "{label}: lost records");
                for r in &a.records {
                    assert!(r.finish_time >= r.first_token_time, "{label}: req {}", r.id);
                    assert!(r.first_token_time >= r.arrival, "{label}: req {}", r.id);
                }
                // bitwise determinism across two runs
                assert_eq!(a.records, b.records, "{label}: nondeterministic records");
                assert_eq!(a.assignments, b.assignments, "{label}: nondeterministic routing");
                // parallel/serial bitwise parity (sim_threads ∈ {1, 4})
                assert_eq!(a.records, c.records, "{label}: parallel records diverge");
                assert_eq!(a.assignments, c.assignments, "{label}: parallel routing diverges");
                assert_eq!(
                    a.virtual_duration.to_bits(),
                    c.virtual_duration.to_bits(),
                    "{label}: parallel makespan diverges"
                );
                assert!(c.scale_events.is_empty(), "{label}: fixed fleet scaled");
                // memo-on/off bitwise parity
                assert_eq!(a.records, d.records, "{label}: memo-off records diverge");
                assert_eq!(a.assignments, d.assignments, "{label}: memo-off routing diverges");
                assert_eq!(
                    a.virtual_duration.to_bits(),
                    d.virtual_duration.to_bits(),
                    "{label}: memo-off makespan diverges"
                );
                // trace-on bitwise parity
                assert_eq!(a.records, e.records, "{label}: trace-on records diverge");
                assert_eq!(a.assignments, e.assignments, "{label}: trace-on routing diverges");
                assert_eq!(
                    a.virtual_duration.to_bits(),
                    e.virtual_duration.to_bits(),
                    "{label}: trace-on makespan diverges"
                );

                // SM-second ledger conservation: every replica's
                // categories sum exactly to num_sms × makespan
                for (i, o) in a.per_replica.iter().enumerate() {
                    let l = &o.ledger;
                    let expect = cfg.gpu.num_sms as f64 * o.virtual_duration;
                    assert_eq!(
                        l.total.to_bits(),
                        expect.to_bits(),
                        "{label}: replica {i} ledger total {} != {}",
                        l.total,
                        expect
                    );
                    assert!(
                        l.conserved(1e-9),
                        "{label}: replica {i} ledger leaks: sum {} vs total {}",
                        l.sum(),
                        l.total
                    );
                }

                // finite metrics
                let s = summarize(&a.records, &cfg.slo, Some(a.virtual_duration));
                for (name, v) in [
                    ("mean_ttft", s.mean_ttft),
                    ("p90_ttft", s.p90_ttft),
                    ("mean_tpot", s.mean_tpot),
                    ("p90_tpot", s.p90_tpot),
                    ("throughput_tok_s", s.throughput_tok_s),
                    ("goodput_frac", s.slo_attainment),
                    ("mean_e2e", s.mean_e2e),
                    ("duration", s.duration),
                ] {
                    assert!(
                        v.is_finite() && v >= 0.0,
                        "{label}: non-finite {name} = {v}"
                    );
                }
                assert!(s.throughput_tok_s > 0.0, "{label}: zero throughput");
            }
        }
    }
}

/// The request-lifecycle axis: each engine family runs a
/// cancellation-heavy cell, a deadline-tight cell, and a mid-run
/// replica-crash cell.  Every cell asserts the same bar as the base
/// matrix — bitwise determinism across runs AND across `sim_threads`
/// 1 vs 4 — plus lifecycle totality (`completed + cancelled + expired +
/// lost == submitted`) and a leak-free KV pool on every replica.
fn run_lifecycle_matrix(engines: &[System]) {
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    let cfg = ServingConfig::default();
    let mut seed = 9500u64;
    for &sys in engines {
        for cell in ["cancellation-heavy", "deadline-tight", "crash"] {
            seed += 1;
            let label = format!("{} x {}", sys.label(), cell);
            // heavier than the base matrix: enough queueing that the
            // annotated cancels and deadlines actually fire mid-run
            let mut trace = trace_by_name("sharegpt", 10.0, 24, seed).expect("cataloged workload");
            let mut failures = Vec::new();
            match cell {
                "cancellation-heavy" => {
                    annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), seed)
                }
                "deadline-tight" => {
                    annotate_lifecycle(&mut trace, &LifecycleProfile::deadline_tight(), seed)
                }
                _ => failures.push(FailureSpec {
                    replica: 0,
                    at: trace[trace.len() / 2].arrival,
                }),
            }
            let ccfg = ClusterConfig {
                replicas: 2,
                router: RouterPolicy::LeastKv,
                sim_threads: 1,
                failures,
                ..Default::default()
            };
            let a = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
            let b = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &ccfg);
            let par = ClusterConfig { sim_threads: 4, ..ccfg.clone() };
            let c = serve_cluster(sys, &cfg, &perf, &gt, &trace, seed, &par);

            // lifecycle ledger is total, and the cell exercises its path
            let lc = a.lifecycle_stats();
            assert_eq!(lc.submitted(), trace.len(), "{label}: ledger not total: {lc:?}");
            match cell {
                "cancellation-heavy" => {
                    assert!(lc.cancelled > 0, "{label}: nothing cancelled: {lc:?}")
                }
                "deadline-tight" => assert!(lc.expired > 0, "{label}: nothing expired: {lc:?}"),
                _ => assert_eq!(
                    a.scale_events.len(),
                    1,
                    "{label}: crash event missing: {:?}",
                    a.scale_events
                ),
            }
            for (i, o) in a.per_replica.iter().enumerate() {
                assert_eq!(o.final_kv_blocks, 0, "{label}: replica {i} leaked KV");
            }

            // bitwise determinism across two runs
            assert_eq!(a.records, b.records, "{label}: nondeterministic records");
            assert_eq!(a.outcomes, b.outcomes, "{label}: nondeterministic outcomes");
            assert_eq!(a.assignments, b.assignments, "{label}: nondeterministic routing");
            // parallel/serial bitwise parity (sim_threads ∈ {1, 4})
            assert_eq!(a.records, c.records, "{label}: parallel records diverge");
            assert_eq!(a.outcomes, c.outcomes, "{label}: parallel outcomes diverge");
            assert_eq!(a.assignments, c.assignments, "{label}: parallel routing diverges");
            assert_eq!(
                a.virtual_duration.to_bits(),
                c.virtual_duration.to_bits(),
                "{label}: parallel makespan diverges"
            );
        }
    }
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_bullet() {
    run_matrix(&[System::Bullet]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_chunked() {
    run_matrix(&[System::Sglang1024]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_nanoflow() {
    run_matrix(&[System::Nanoflow]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_static_split() {
    run_matrix(&[System::StaticSplit]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_proactive_split() {
    run_matrix(&[System::ProactiveSplit]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn matrix_temporal_mux() {
    run_matrix(&[System::TemporalMux]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn lifecycle_bullet() {
    run_lifecycle_matrix(&[System::Bullet]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn lifecycle_chunked() {
    run_lifecycle_matrix(&[System::Sglang1024]);
}

#[test]
#[ignore = "scenario matrix: run via CI's scenario-matrix job (cargo test --test scenario_matrix -- --ignored)"]
fn lifecycle_nanoflow() {
    run_lifecycle_matrix(&[System::Nanoflow]);
}
