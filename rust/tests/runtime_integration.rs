//! Integration: the full AOT bridge — HLO-text artifacts → PJRT compile →
//! prefill/decode execution with the paged host KV store.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are missing
//! so `cargo test` stays green on a fresh checkout.

use bullet::coordinator::tokenizer::Tokenizer;
use bullet::runtime::{ModelMeta, ModelRuntime};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("meta.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn loads_and_compiles_all_buckets() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir, 7).expect("load runtime");
    assert_eq!(rt.max_prompt(), 128);
    assert_eq!(rt.max_batch(), 8);
}

#[test]
fn prefill_then_decode_generates_deterministically() {
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();
    let prompt: Vec<i32> = (3..20).collect();
    let a = rt.generate(1, &prompt, 8).unwrap();
    rt.release(1).unwrap();
    let b = rt.generate(2, &prompt, 8).unwrap();
    rt.release(2).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), 8);
    // all ids in vocab
    assert!(a.iter().all(|&t| (0..2048).contains(&t)));
}

#[test]
fn weight_seed_changes_output() {
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<i32> = (3..40).collect();
    let mut rt1 = ModelRuntime::load(&dir, 7).unwrap();
    let a = rt1.generate(1, &prompt, 6).unwrap();
    drop(rt1);
    let mut rt2 = ModelRuntime::load(&dir, 8).unwrap();
    let b = rt2.generate(1, &prompt, 6).unwrap();
    assert_ne!(a, b, "different weights must generate differently");
}

#[test]
fn batched_decode_matches_solo_decode() {
    // The decisive batching-correctness check: a sequence's tokens must
    // not depend on which other sequences share its decode batch.
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();

    let p1: Vec<i32> = (3..30).collect();
    let p2: Vec<i32> = (100..160).rev().collect();

    // solo generation for p1
    let solo = rt.generate(10, &p1, 6).unwrap();
    rt.release(10).unwrap();

    // batched: p1 and p2 decode together
    let f1 = rt.prefill(21, &p1).unwrap();
    let f2 = rt.prefill(22, &p2).unwrap();
    let mut t1 = f1;
    let mut t2 = f2;
    let mut got = vec![f1];
    for _ in 1..6 {
        let next = rt.decode(&[21, 22], &[t1, t2]).unwrap();
        t1 = next[0];
        t2 = next[1];
        got.push(t1);
    }
    assert_eq!(solo, got, "batching changed sequence 1's tokens");
}

#[test]
fn bucket_padding_invariance() {
    // Same prompt served via different padding buckets → same tokens.
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();
    let p: Vec<i32> = (5..19).collect(); // fits bucket 16
    let a = rt.generate(1, &p, 4).unwrap();
    rt.release(1).unwrap();
    // the same tokens via generate on a fresh runtime: decode path uses
    // ctx_lens, not the bucket, so this exercises pad-token invariance.
    let mut rt2 = ModelRuntime::load(&dir, 7).unwrap();
    let b = rt2.generate(9, &p, 4).unwrap();
    assert_eq!(a, b);
}

#[test]
fn kv_pool_accounting_tracks_tokens() {
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();
    let p: Vec<i32> = (3..50).collect();
    rt.prefill(1, &p).unwrap();
    let before = rt.pool.cached_tokens();
    assert_eq!(before, p.len());
    rt.decode(&[1], &[42]).unwrap();
    assert_eq!(rt.pool.cached_tokens(), p.len() + 1);
    rt.release(1).unwrap();
    assert_eq!(rt.pool.cached_tokens(), 0);
}

#[test]
fn serve_text_roundtrip() {
    // Text in, text out through the tokenizer + runtime.
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();
    let tok = Tokenizer::new(rt.engine.meta.vocab_size);
    let ids = tok.encode("What is the answer?");
    let out = rt.generate(1, &ids, 12).unwrap();
    let text = tok.decode(&out);
    // random weights → arbitrary text; just verify the pipe produced
    // *some* decodable byte string of the right token count.
    assert_eq!(out.len(), 12);
    let _ = text;
}

#[test]
fn rejects_oversized_prompt_and_unknown_seq() {
    let Some(dir) = artifacts() else { return };
    let mut rt = ModelRuntime::load(&dir, 7).unwrap();
    let too_long: Vec<i32> = vec![5; 500];
    assert!(rt.prefill(1, &too_long).is_err());
    assert!(rt.decode(&[99], &[1]).is_err());
}

#[test]
fn meta_matches_python_config() {
    let Some(dir) = artifacts() else { return };
    let m = ModelMeta::load(&dir).unwrap();
    // ABI invariants the python side guarantees (ModelConfig defaults)
    assert_eq!(m.vocab_size, 2048);
    assert_eq!(m.d_model, 256);
    assert_eq!(m.n_heads, 8);
    assert_eq!(m.n_kv_heads, 4);
    assert_eq!(m.max_ctx, 192);
    assert_eq!(m.weights.len(), 39);
}
