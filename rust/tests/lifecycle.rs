//! Request-lifecycle integration: cancellation, deadlines, and failure
//! injection hold the same ledger across every serving layer — single
//! engine, cluster, and the live gateway — and never leak KV.

use bullet::baselines::System;
use bullet::cluster::{serve_cluster, ClusterConfig, FailureSpec, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::engine::sim_engine::{serve_bullet, SimEngineOptions};
use bullet::gateway::{serve_gateway, GatewayConfig, VirtualClock};
use bullet::gpu::roofline::GroundTruth;
use bullet::metrics::RequestOutcome;
use bullet::perf::PerfModel;
use bullet::workload::{
    annotate_lifecycle, generate_n_requests, Dataset, LifecycleProfile, Request,
};

fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
    (
        ServingConfig::default(),
        PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b()),
        GroundTruth::new(GpuSpec::a100()),
    )
}

/// Ids in `records` ∪ `outcomes` must be exactly the trace's ids, each
/// appearing once — the ledger is a partition, not just a count match.
fn assert_partition(
    trace: &[Request],
    records: &[bullet::metrics::RequestRecord],
    outcomes: &[bullet::metrics::OutcomeRecord],
) {
    let mut ids: Vec<u64> = records.iter().map(|r| r.id).collect();
    ids.extend(outcomes.iter().map(|o| o.id));
    ids.sort_unstable();
    let mut expect: Vec<u64> = trace.iter().map(|r| r.id).collect();
    expect.sort_unstable();
    assert_eq!(ids, expect, "records+outcomes must partition the trace");
}

/// Annotations that can never fire (cancel/deadline eons after arrival)
/// must leave the run bit-identical to the un-annotated trace: the
/// lifecycle sweep is pure bookkeeping until an instant actually passes.
#[test]
fn never_firing_annotations_are_inert() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 30, 61);
    let mut annotated = trace.clone();
    for r in annotated.iter_mut() {
        r.cancel_at = Some(r.arrival + 1e9);
        r.deadline = Some(r.arrival + 1e9);
    }
    let plain = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    let noted = serve_bullet(&cfg, &perf, &gt, &annotated, &SimEngineOptions::default());
    assert_eq!(plain.records, noted.records);
    assert!(noted.outcomes.is_empty());
    assert_eq!(
        plain.virtual_duration.to_bits(),
        noted.virtual_duration.to_bits()
    );
}

/// Cancellation mid-run releases KV: the pool drains to zero and the
/// ledger partitions the trace between completions and cancel outcomes.
#[test]
fn cancellation_releases_kv_and_partitions_the_trace() {
    let (cfg, perf, gt) = setup();
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 40, 67);
    annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), 67);
    let out = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    assert_partition(&trace, &out.records, &out.outcomes);
    let cancelled = out
        .outcomes
        .iter()
        .filter(|o| o.outcome == RequestOutcome::Cancelled)
        .count();
    assert!(cancelled > 0, "cancellation-heavy trace cancelled nothing");
    assert_eq!(out.final_kv_blocks, 0, "cancelled KV never returned to the pool");
    for o in &out.outcomes {
        let r = trace.iter().find(|r| r.id == o.id).unwrap();
        assert!(o.t >= r.arrival, "outcome for {} precedes its arrival", o.id);
        assert!(
            o.tokens_out < r.output_len,
            "cancelled request {} decoded to completion anyway",
            o.id
        );
    }
}

/// Tight deadlines expire requests without leaks, on the Bullet engine
/// and both chunked-prefill baselines (they share the core's lifecycle
/// sweep through `waiting_locked`).
#[test]
fn deadline_expiry_is_leak_free_across_systems() {
    let (cfg, perf, gt) = setup();
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 30, 71);
    annotate_lifecycle(&mut trace, &LifecycleProfile::deadline_tight(), 71);
    for sys in [System::Bullet, System::Sglang1024, System::Nanoflow] {
        let ccfg = ClusterConfig {
            replicas: 1,
            sim_threads: 1,
            ..Default::default()
        };
        let out = serve_cluster(sys, &cfg, &perf, &gt, &trace, 13, &ccfg);
        assert_partition(&trace, &out.records, &out.outcomes);
        let lc = out.lifecycle_stats();
        assert!(
            lc.expired > 0,
            "{}: tight deadlines expired nothing: {lc:?}",
            sys.label()
        );
        for o in &out.per_replica {
            assert_eq!(o.final_kv_blocks, 0, "{} leaked KV blocks", sys.label());
        }
    }
}

/// The same annotated trace flows through the single engine, the cluster
/// dispatch loop, and the live gateway; every layer closes the same total
/// ledger, and the gateway agrees with the serial cluster bit-for-bit.
#[test]
fn ledger_is_total_across_engine_cluster_and_gateway() {
    let (cfg, perf, gt) = setup();
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 36, 73);
    annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), 73);
    annotate_lifecycle(&mut trace, &LifecycleProfile::deadline_tight(), 79);

    let single = serve_bullet(&cfg, &perf, &gt, &trace, &SimEngineOptions::default());
    assert_partition(&trace, &single.records, &single.outcomes);

    let ccfg = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::LeastKv,
        sim_threads: 1,
        ..Default::default()
    };
    let cluster = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 17, &ccfg);
    assert_partition(&trace, &cluster.records, &cluster.outcomes);

    let gw = GatewayConfig {
        replicas: 2,
        router: RouterPolicy::LeastKv,
        ..Default::default()
    };
    let mut clock = VirtualClock::new();
    let live = serve_gateway(System::Bullet, &cfg, &perf, &gt, &trace, 17, &gw, &mut clock);
    assert_partition(&trace, &live.records, &live.outcomes);

    // same fleet, same seed, same router: the gateway IS the serial
    // dispatch loop plus streaming, so lifecycle outcomes match exactly
    assert_eq!(live.records, cluster.records);
    assert_eq!(live.outcomes, cluster.outcomes);
    assert_eq!(live.assignments, cluster.assignments);
}

/// Failure injection composes with lifecycle annotations: a mid-trace
/// crash adds `lost` to the ledger without disturbing its totality, and
/// the crashed replica tears down every KV block.
#[test]
fn crash_composes_with_lifecycle_annotations() {
    let (cfg, perf, gt) = setup();
    let mut trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 36, 83);
    annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), 83);
    let at = trace[trace.len() / 2].arrival;
    let ccfg = ClusterConfig {
        replicas: 2,
        router: RouterPolicy::LeastKv,
        sim_threads: 1,
        failures: vec![FailureSpec { replica: 0, at }],
        ..Default::default()
    };
    let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 19, &ccfg);
    assert_partition(&trace, &out.records, &out.outcomes);
    let lc = out.lifecycle_stats();
    assert_eq!(lc.submitted(), trace.len(), "{lc:?}");
    for o in &out.per_replica {
        assert_eq!(o.final_kv_blocks, 0, "crash path leaked KV blocks");
    }
}
