//! Observability suite: the SM-second attribution ledger and the
//! Chrome trace exporter.
//!
//! The ledger's contract is conservation — every simulated SM-second
//! lands in exactly one category and the seven categories sum to
//! `num_sms × makespan` — for EVERY system, because all systems run on
//! the shared serving core and the ledger accrues inside the simulator
//! they all share.  The exporter's contract is byte determinism: the
//! trace file is a pure function of the run output, so repeated runs
//! and any `sim_threads` setting produce identical bytes.

use bullet::baselines::{run_system_output, System};
use bullet::cluster::{serve_cluster, ClusterConfig, RouterPolicy};
use bullet::config::{GpuSpec, ModelSpec, ServingConfig};
use bullet::gpu::roofline::GroundTruth;
use bullet::obs::export::chrome_trace;
use bullet::obs::TraceSpec;
use bullet::perf::PerfModel;
use bullet::workload::{generate_n_requests, Dataset};

fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
    let cfg = ServingConfig::default();
    let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
    let gt = GroundTruth::new(GpuSpec::a100());
    (cfg, perf, gt)
}

/// Conservation holds for every cataloged system — baselines included —
/// on a single engine, exactly (total is bit-equal to `num_sms ×
/// makespan`) and category-complete (sum within 1e-9 relative).
#[test]
fn ledger_conserves_for_every_system() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 16, 71);
    for sys in System::evaluation_set()
        .into_iter()
        .chain(System::ablation_set())
        .chain([System::FixedSm(84)])
    {
        let out = run_system_output(sys, &cfg, &perf, &gt, &trace, 3);
        let l = &out.ledger;
        let expect = cfg.gpu.num_sms as f64 * out.virtual_duration;
        assert_eq!(
            l.total.to_bits(),
            expect.to_bits(),
            "{}: ledger total {} != num_sms × makespan {}",
            sys.label(),
            l.total,
            expect
        );
        assert!(
            l.conserved(1e-9),
            "{}: categories leak: sum {} vs total {}",
            sys.label(),
            l.sum(),
            l.total
        );
        // a served trace did real work: busy categories are non-empty
        // and idle is a residual, never the whole budget
        assert!(l.accrued() > 0.0, "{}: no busy time accrued", sys.label());
        assert!(l.idle < l.total, "{}: everything idle", sys.label());
        assert!(l.decode > 0.0, "{}: no decode time", sys.label());
    }
}

/// The ledger actually discriminates between systems: temporal mux
/// serializes phases (no co-scheduling), so its idle share must exceed
/// Bullet's on the same trace — the Fig. 2 story in ledger form.
#[test]
fn ledger_tells_bullet_apart_from_temporal_mux() {
    let (cfg, perf, gt) = setup();
    let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 24, 73);
    let idle_share = |sys: System| {
        let out = run_system_output(sys, &cfg, &perf, &gt, &trace, 5);
        out.ledger.idle / out.ledger.total
    };
    let bullet = idle_share(System::Bullet);
    let mux = idle_share(System::TemporalMux);
    assert!(
        mux > bullet,
        "temporal mux should idle more than Bullet: mux {mux} vs bullet {bullet}"
    );
}

/// Satellite 3: the exported Chrome trace JSON is byte-identical across
/// repeated identical runs and across `sim_threads` 1 vs 4.
#[test]
fn exported_trace_is_byte_identical_across_runs_and_threads() {
    let (base, perf, gt) = setup();
    let cfg = ServingConfig { trace: TraceSpec::on(), ..base };
    let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 20, 77);
    let export = |threads: usize| {
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::LeastKv,
            sim_threads: threads,
            ..Default::default()
        };
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 9, &ccfg);
        chrome_trace("determinism", &out.per_replica).to_string()
    };
    let a = export(1);
    let b = export(1);
    let c = export(4);
    assert_eq!(a, b, "repeated runs must export identical bytes");
    assert_eq!(a, c, "sim_threads must not leak into exported bytes");
    assert!(a.contains("\"launch\""), "trace-on export should contain launch instants");
}

/// The single-engine export path (what `--trace` does without
/// `--replicas`): a one-element slice produces a well-formed document
/// whose embedded ledger matches the run's.
#[test]
fn single_engine_export_embeds_the_run_ledger() {
    let (base, perf, gt) = setup();
    let cfg = ServingConfig { trace: TraceSpec::on(), ..base };
    let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 12, 79);
    let out = run_system_output(System::Bullet, &cfg, &perf, &gt, &trace, 13);
    let doc = chrome_trace("single", std::slice::from_ref(&out));
    let total = doc
        .path(&["bullet", "ledger", "total"])
        .and_then(bullet::util::json::Value::as_f64)
        .expect("aggregate ledger total");
    assert_eq!(total.to_bits(), out.ledger.total.to_bits());
    let n = doc
        .path(&["bullet", "replicas"])
        .and_then(bullet::util::json::Value::as_arr)
        .map(|r| r.len());
    assert_eq!(n, Some(1));
}
