//! Computational resource manager (§3.4): SM partitioning via
//! pre-configured masked streams with instant switching.
//!
//! The paper layers an SM-mask API (`libsmctrl_set_stream_mask`) on top of
//! MPS: a palette of CUDA streams is created up front, each masked to a
//! different SM subset (2-SM granularity), and re-configuration is just
//! launching onto a different pre-built stream — a few microseconds
//! (Table 3) instead of an MPS context update.
//!
//! Here the palette maps one-to-one onto simulator streams: the prefill
//! engine owns streams masked to SM prefixes `[0, pm)`, the decode engine
//! owns suffixes `[M-dm, M)`.  Choosing `pm + dm > M` intentionally
//! overlaps the middle SMs (non-strict isolation, §3.4.2).

use crate::config::GpuSpec;
use crate::gpu::simulator::{Simulator, StreamPhase};
use crate::gpu::stream::{SmMask, StreamId};

/// An SM partition decision: (prefill SMs, decode SMs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    pub prefill_sms: usize,
    pub decode_sms: usize,
}

impl Partition {
    /// Disjoint split of the whole GPU at `prefill_sms`.
    pub fn split(gpu: &GpuSpec, prefill_sms: usize) -> Partition {
        let p = gpu.quantize_sms(prefill_sms);
        Partition {
            prefill_sms: p,
            decode_sms: gpu.num_sms - p,
        }
    }

    /// Both phases see the full GPU (the "Naive" ablation / MPS default).
    pub fn full_overlap(gpu: &GpuSpec) -> Partition {
        Partition {
            prefill_sms: gpu.num_sms,
            decode_sms: gpu.num_sms,
        }
    }

    pub fn overlap_sms(&self, gpu: &GpuSpec) -> usize {
        (self.prefill_sms + self.decode_sms).saturating_sub(gpu.num_sms)
    }
}

/// Pre-configured stream palette + switch bookkeeping.
pub struct ResourceManager {
    gpu: GpuSpec,
    /// prefill stream for each SM count (index = sms / granularity; 0 unused).
    prefill_streams: Vec<StreamId>,
    /// decode stream for each SM count.
    decode_streams: Vec<StreamId>,
    /// Current partition.
    current: Partition,
    /// Number of re-configurations performed (Table 3 bookkeeping).
    reconfig_count: u64,
}

impl ResourceManager {
    /// Build the palette inside `sim`: one stream per SM count per phase.
    pub fn new(sim: &mut Simulator, gpu: &GpuSpec) -> ResourceManager {
        let g = gpu.sm_granularity;
        let steps = gpu.num_sms / g;
        let mut prefill_streams = Vec::with_capacity(steps + 1);
        let mut decode_streams = Vec::with_capacity(steps + 1);
        // Phase-tag every palette stream so the simulator's SM-second
        // ledger attributes its kernels without inspecting op classes
        // (decode launches include elementwise ops too).
        let tag = |sim: &mut Simulator, id: StreamId, phase: StreamPhase| {
            sim.set_stream_phase(id, phase);
            id
        };
        // index 0 = a 0-SM placeholder (never launched on); keep indices aligned.
        let id = sim.create_stream(SmMask::empty(), "prefill-0sm");
        prefill_streams.push(tag(sim, id, StreamPhase::Prefill));
        let id = sim.create_stream(SmMask::empty(), "decode-0sm");
        decode_streams.push(tag(sim, id, StreamPhase::Decode));
        for i in 1..=steps {
            let sms = i * g;
            let id = sim.create_stream(SmMask::first(sms), &format!("prefill-{sms}sm"));
            prefill_streams.push(tag(sim, id, StreamPhase::Prefill));
            let id = sim.create_stream(SmMask::last(sms, gpu.num_sms), &format!("decode-{sms}sm"));
            decode_streams.push(tag(sim, id, StreamPhase::Decode));
        }
        ResourceManager {
            gpu: gpu.clone(),
            prefill_streams,
            decode_streams,
            current: Partition::split(gpu, gpu.num_sms / 2),
            reconfig_count: 0,
        }
    }

    pub fn gpu(&self) -> &GpuSpec {
        &self.gpu
    }

    pub fn partition(&self) -> Partition {
        self.current
    }

    pub fn reconfig_count(&self) -> u64 {
        self.reconfig_count
    }

    /// Number of pre-configured SM steps per phase.
    pub fn palette_size(&self) -> usize {
        self.prefill_streams.len() - 1
    }

    /// Switch the active partition — O(1): just records which pre-built
    /// streams subsequent launches use.
    pub fn reconfigure(&mut self, p: Partition) {
        let q = Partition {
            prefill_sms: self.gpu.quantize_sms(p.prefill_sms),
            decode_sms: self.gpu.quantize_sms(p.decode_sms),
        };
        if q != self.current {
            self.current = q;
            self.reconfig_count += 1;
        }
    }

    /// Stream to launch prefill kernels on under the current partition.
    pub fn prefill_stream(&self) -> StreamId {
        self.prefill_streams[self.current.prefill_sms / self.gpu.sm_granularity]
    }

    /// Stream to launch decode kernels on under the current partition.
    pub fn decode_stream(&self) -> StreamId {
        self.decode_streams[self.current.decode_sms / self.gpu.sm_granularity]
    }

    /// Stream for an explicit SM count (baselines, probes).
    pub fn prefill_stream_for(&self, sms: usize) -> StreamId {
        self.prefill_streams[self.gpu.quantize_sms(sms) / self.gpu.sm_granularity]
    }

    pub fn decode_stream_for(&self, sms: usize) -> StreamId {
        self.decode_streams[self.gpu.quantize_sms(sms) / self.gpu.sm_granularity]
    }

    /// Which phase owns a stream from this palette?
    pub fn is_prefill_stream(&self, id: StreamId) -> bool {
        self.prefill_streams.contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::roofline::GroundTruth;

    fn setup() -> (Simulator, ResourceManager) {
        let gpu = GpuSpec::a100();
        let mut sim = Simulator::new(GroundTruth::noiseless(gpu.clone()), 0);
        let rm = ResourceManager::new(&mut sim, &gpu);
        (sim, rm)
    }

    #[test]
    fn palette_covers_all_steps() {
        let (_, rm) = setup();
        assert_eq!(rm.palette_size(), 54); // 108 / 2
    }

    #[test]
    fn partition_split_quantizes() {
        let gpu = GpuSpec::a100();
        let p = Partition::split(&gpu, 55);
        assert_eq!(p.prefill_sms, 54);
        assert_eq!(p.decode_sms, 54);
        assert_eq!(p.overlap_sms(&gpu), 0);
    }

    #[test]
    fn full_overlap_partition() {
        let gpu = GpuSpec::a100();
        let p = Partition::full_overlap(&gpu);
        assert_eq!(p.overlap_sms(&gpu), 108);
    }

    #[test]
    fn streams_have_expected_masks() {
        let (sim, rm) = setup();
        let ps = rm.prefill_stream_for(30);
        let ds = rm.decode_stream_for(30);
        let pmask = sim.stream_mask(ps);
        let dmask = sim.stream_mask(ds);
        assert_eq!(pmask.count(), 30);
        assert_eq!(dmask.count(), 30);
        assert!(pmask.contains(0) && !pmask.contains(30));
        assert!(dmask.contains(107) && !dmask.contains(77));
        assert_eq!(pmask.overlap(&dmask), 0);
    }

    #[test]
    fn complementary_partitions_disjoint_overlapping_share() {
        let (sim, mut rm) = setup();
        rm.reconfigure(Partition { prefill_sms: 60, decode_sms: 48 });
        let pm = sim.stream_mask(rm.prefill_stream());
        let dm = sim.stream_mask(rm.decode_stream());
        assert_eq!(pm.overlap(&dm), 0);
        rm.reconfigure(Partition { prefill_sms: 80, decode_sms: 48 });
        let pm = sim.stream_mask(rm.prefill_stream());
        let dm = sim.stream_mask(rm.decode_stream());
        assert_eq!(pm.overlap(&dm), 20); // intentional non-strict isolation
    }

    #[test]
    fn reconfigure_counts_only_changes() {
        let (_, mut rm) = setup();
        let p = Partition { prefill_sms: 60, decode_sms: 48 };
        rm.reconfigure(p);
        rm.reconfigure(p);
        rm.reconfigure(Partition { prefill_sms: 54, decode_sms: 54 });
        assert_eq!(rm.reconfig_count(), 2);
    }

    #[test]
    fn reconfigure_is_fast() {
        // Table 3: re-config must be O(1) pointer swap, ~microseconds.
        let (_, mut rm) = setup();
        let t0 = std::time::Instant::now();
        for i in 0..10_000u64 {
            let sms = 6 + (i as usize % 50) * 2;
            rm.reconfigure(Partition { prefill_sms: sms, decode_sms: 108 - sms });
        }
        let per = t0.elapsed().as_secs_f64() / 10_000.0;
        assert!(per < 5e-6, "reconfig {per}s");
    }
}
