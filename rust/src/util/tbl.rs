//! ASCII table rendering for the bench binaries — every paper table and
//! figure is regenerated as aligned text rows.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: ToString>(mut self, cols: &[S]) -> Table {
        self.header = cols.iter().map(|c| c.to_string()).collect();
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Table {
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        let measure = |row: &[String], widths: &mut Vec<usize>| {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        };
        measure(&self.header, &mut widths);
        for r in &self.rows {
            measure(r, &mut widths);
        }
        let fmt_row = |row: &[String]| -> String {
            let cells: Vec<String> = (0..ncols)
                .map(|i| {
                    let cell = row.get(i).map(|s| s.as_str()).unwrap_or("");
                    let pad = widths[i] - cell.chars().count();
                    format!("{}{}", cell, " ".repeat(pad))
                })
                .collect();
            format!("| {} |", cells.join(" | "))
        };
        let sep = format!(
            "+{}+",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("+")
        );
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with `digits` significant decimals.
pub fn f(x: f64, digits: usize) -> String {
    format!("{:.*}", digits, x)
}

/// Format milliseconds from seconds.
pub fn ms(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e3)
}

/// Render a sparkline-esque horizontal bar of `frac` in [0,1].
pub fn bar(frac: f64, width: usize) -> String {
    let frac = frac.clamp(0.0, 1.0);
    let filled = (frac * width as f64).round() as usize;
    format!("{}{}", "#".repeat(filled), ".".repeat(width - filled))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["a", "long-column"]);
        t.row(&["1", "2"]);
        t.row(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("| a   | long-column |"), "{s}");
        assert!(s.contains("| 333 | 4           |"), "{s}");
    }

    #[test]
    fn ragged_rows_ok() {
        let mut t = Table::new("").header(&["x"]);
        t.row(&["1", "2", "3"]);
        let s = t.render();
        assert!(s.contains("| 1 | 2 | 3 |"));
    }

    #[test]
    fn bar_bounds() {
        assert_eq!(bar(0.0, 10), "..........");
        assert_eq!(bar(1.0, 10), "##########");
        assert_eq!(bar(0.5, 10), "#####.....");
        assert_eq!(bar(2.0, 4), "####"); // clamped
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(ms(0.0215), "21.50");
    }
}
