//! Descriptive statistics used throughout the metrics and calibration
//! code: percentiles, means, linear least squares, and a streaming
//! Welford accumulator.

/// Percentile of a sample (linear interpolation, like numpy's default).
/// `p` is clamped into [0, 100] (p < 0 reads the minimum, p > 100 the
/// maximum). Returns NaN on an empty slice.  The sort is `total_cmp`,
/// so NaN-bearing input ranks NaNs at the top instead of panicking —
/// a NaN then only surfaces in the result when the requested rank
/// actually touches one.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice (`p` clamped like
/// [`percentile`]).
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Percentile by in-place selection instead of a full sort: O(n)
/// expected versus O(n log n), and no allocation — the caller's scratch
/// buffer is reordered in place.  Bit-identical to [`percentile`] for
/// input without negative zeros (NaN included): both read the same two
/// order statistics under the `total_cmp` total order and apply the
/// same linear interpolation, and equal non-zero f64 values are bitwise
/// equal.  `p` is clamped like [`percentile`].
pub fn percentile_select(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let (_, lo_v, rest) = xs.select_nth_unstable_by(lo, f64::total_cmp);
    let lo_v = *lo_v;
    if lo == hi {
        return lo_v;
    }
    // hi == lo + 1, so sorted v[hi] is the suffix minimum — under the
    // same total order as the sort (a NaN-skipping f64::min here would
    // disagree with the sorted path on NaN-bearing input).
    let hi_v = rest
        .iter()
        .copied()
        .min_by(|a, b| a.total_cmp(b))
        .expect("hi < len, so the suffix is non-empty");
    let frac = rank - lo as f64;
    lo_v * (1.0 - frac) + hi_v * frac
}

/// Arithmetic mean (NaN on empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (NaN for < 2 points).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Maximum (NaN on empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NAN, f64::max)
}

/// Minimum (NaN on empty).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NAN, f64::min)
}

/// Ordinary least squares for y = a*x + b. Returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let n = xs.len() as f64;
    let sx = xs.iter().sum::<f64>();
    let sy = ys.iter().sum::<f64>();
    let sxx = xs.iter().map(|x| x * x).sum::<f64>();
    let sxy = xs.iter().zip(ys).map(|(x, y)| x * y).sum::<f64>();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (0.0, sy / n);
    }
    let a = (n * sxy - sx * sy) / denom;
    let b = (sy - a * sx) / n;
    (a, b)
}

/// Best single scale factor s minimizing sum (s*x - y)^2.
pub fn scale_fit(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let num: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let den: f64 = xs.iter().map(|x| x * x).sum();
    if den.abs() < 1e-12 {
        1.0
    } else {
        num / den
    }
}

/// Mean relative error |pred - actual| / actual (actual==0 terms skipped).
pub fn mean_relative_error(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (p, a) in pred.iter().zip(actual) {
        if a.abs() > 1e-12 {
            total += ((p - a) / a).abs();
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        total / n as f64
    }
}

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-9);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 25.0), 7.0);
    }

    #[test]
    fn percentile_empty_nan() {
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_select_is_bit_identical_to_percentile() {
        // awkward sizes, duplicates, irrational-ish values, and the
        // exact percentiles the scheduler asks for
        let mut xs: Vec<f64> = (0..257)
            .map(|i| ((i * 7919 % 257) as f64).sqrt() * 0.3127 + (i % 5) as f64)
            .collect();
        xs.push(xs[13]); // force duplicates
        xs.push(xs[13]);
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let want = percentile(&xs, p);
            let mut scratch = xs.clone();
            let got = percentile_select(&mut scratch, p);
            assert_eq!(got.to_bits(), want.to_bits(), "p={p}");
        }
        let mut one = [7.25];
        assert_eq!(percentile_select(&mut one, 90.0).to_bits(), 7.25f64.to_bits());
        assert!(percentile_select(&mut [], 50.0).is_nan());
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // regression: a NaN-bearing sample used to panic the
        // `partial_cmp().unwrap()` sort (same class as the
        // `SloScheduler::reorder_waiting` fix).  total_cmp ranks NaN at
        // the top, so low/mid percentiles of mostly-finite data stay
        // finite and nothing panics.
        assert_eq!(percentile(&[1.0, f64::NAN], 0.0), 1.0);
        let _ = percentile(&[1.0, f64::NAN], 90.0); // must not panic
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        let _ = percentile(&[f64::NAN, f64::NAN], 50.0); // must not panic
    }

    #[test]
    fn percentile_select_agrees_with_percentile_on_nan_input() {
        // the select path must use the SAME total order as the sort
        // path, including the suffix-min step (a NaN-skipping f64::min
        // there would diverge).
        let xs = [5.0, f64::NAN, 1.0, 4.0, f64::NAN, 2.0, 3.0];
        for p in [0.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let want = percentile(&xs, p);
            let mut scratch = xs.to_vec();
            let got = percentile_select(&mut scratch, p);
            assert_eq!(got.to_bits(), want.to_bits(), "p={p}");
        }
    }

    #[test]
    fn percentile_clamps_out_of_range_p_consistently() {
        // shared edge-case pin: p < 0 clamps to the minimum, p > 100 to
        // the maximum, across all three entry points; single-element
        // and all-equal inputs are rank-independent.
        let xs = [4.0, 1.0, 3.0, 2.0];
        let sorted = [1.0, 2.0, 3.0, 4.0];
        for (p, want) in [(-10.0, 1.0), (-0.0001, 1.0), (100.0001, 4.0), (250.0, 4.0)] {
            assert_eq!(percentile(&xs, p), want, "percentile p={p}");
            assert_eq!(percentile_sorted(&sorted, p), want, "sorted p={p}");
            let mut scratch = xs.to_vec();
            assert_eq!(percentile_select(&mut scratch, p), want, "select p={p}");
        }
        for p in [-50.0, 0.0, 37.5, 100.0, 400.0] {
            assert_eq!(percentile(&[7.0], p), 7.0, "single p={p}");
            let all_equal = [2.5; 6];
            assert_eq!(percentile(&all_equal, p), 2.5, "all-equal p={p}");
            let mut scratch = all_equal.to_vec();
            assert_eq!(percentile_select(&mut scratch, p), 2.5, "all-equal select p={p}");
        }
    }

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scale_fit_exact() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.5, 5.0, 7.5];
        assert!((scale_fit(&xs, &ys) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn mre_basic() {
        let pred = [1.1, 2.2];
        let act = [1.0, 2.0];
        assert!((mean_relative_error(&pred, &act) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.5, 4.25, 8.0, -1.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -1.0);
        assert_eq!(w.max(), 8.0);
        assert_eq!(w.count(), 6);
    }
}
