//! Deterministic pseudo-random number generation.
//!
//! The serving benches must be reproducible run-to-run (the paper's
//! figures are regenerated deterministically), so everything that needs
//! randomness — workload arrival times, sequence lengths, simulator noise,
//! weight initialization — draws from an explicitly-seeded [`Rng`].
//!
//! Implementation: splitmix64 for seeding, xoshiro256++ for the stream
//! (public-domain reference constants).

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free enough for non-crypto use.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        lo + self.below(span + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let mut u = self.f64();
        if u >= 1.0 {
            u = 1.0 - 1e-16;
        }
        -(1.0 - u).ln() / lambda
    }

    /// Sample an index proportional to the given non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy_index() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 5 * counts[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
