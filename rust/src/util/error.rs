//! Minimal `anyhow`-compatible error type so the crate builds with zero
//! external dependencies (the container has no crates.io access).
//!
//! Supports the subset the runtime layer uses: `anyhow!(...)`,
//! `Result<T>`, `.context(..)` / `.with_context(..)`, and the `{e:#}`
//! alternate formatting that prints the full context chain
//! (`outer: inner: root`).

use std::fmt;

/// A string-chained error: `msgs[0]` is the outermost context.
#[derive(Debug, Clone)]
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msgs: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message (innermost messages keep order).
    pub fn wrap(mut self, outer: impl fmt::Display) -> Error {
        self.msgs.insert(0, outer.to_string());
        self
    }

    /// The full chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.msgs
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs[0])
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` stand-in for `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        // `{:#}` captures an existing chain in full (our Error's
        // alternate form); for foreign errors it is the plain message.
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(f()))
    }
}

/// `anyhow!`-style constructor.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

// Allow `use crate::util::error::anyhow;` like the real crate.
pub use crate::anyhow;

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("root cause {}", 42))
    }

    #[test]
    fn message_and_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause 42");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| format!("reading {}", "x.json")).unwrap_err();
        assert!(format!("{e:#}").starts_with("reading x.json: "));
    }

    #[test]
    fn nested_context_keeps_the_root_cause() {
        let e = fails()
            .context("parsing meta")
            .context("loading artifacts")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading artifacts");
        assert_eq!(
            format!("{e:#}"),
            "loading artifacts: parsing meta: root cause 42"
        );
    }

    #[test]
    fn question_mark_compat() {
        fn inner() -> Result<()> {
            fails()?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
