//! Minimal JSON parser/serializer (no serde in the offline crate set).
//!
//! Supports the full JSON grammar minus exotic escapes (`\u` surrogate
//! pairs are handled), preserving object key order.  Used to read
//! `artifacts/meta.json` and the config files, and to dump bench results.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are stored in a sorted map plus an
/// insertion-order list is unnecessary for our use (config lookup only).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `v.get("config")`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path access: `v.path(&["config", "n_layers"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> String {
        format!("{} at byte {}", msg, self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate; expect \uXXXX low surrogate
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad UTF-8 lead byte")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("bad UTF-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_value(self, &mut s);
        f.write_str(&s)
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A 😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,"x"],"n":null,"obj":{"k":true}}"#;
        let v = parse(text).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn meta_json_shape() {
        // Mirror of the artifacts/meta.json structure the runtime reads.
        let text = r#"{
            "config": {"n_layers": 4, "rope_theta": 10000.0},
            "weights": [{"name": "embed", "shape": [2048, 256]}],
            "prefill_buckets": [16, 32]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.path(&["config", "n_layers"]).unwrap().as_usize(), Some(4));
        let w = &v.get("weights").unwrap().as_arr().unwrap()[0];
        assert_eq!(w.get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(
            w.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(256)
        );
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(parse("3.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("3").unwrap().as_u64(), Some(3));
    }
}
