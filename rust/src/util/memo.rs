//! Hit/miss/invalidation counters shared by the hot-path memo layers
//! (simulator rate table, calibrated-prediction memo, router probe
//! memo).  Counters are observability only: they are **excluded** from
//! every bitwise-parity comparison, because the memo-on and memo-off
//! legs of a parity run legitimately differ in hit counts while
//! producing bit-identical physics.

/// Cache-effectiveness counters for one memoized hot path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoCounters {
    /// Lookups served from the cache (no recomputation performed).
    pub hits: u64,
    /// Lookups that recomputed and (re)filled the cache.  With memo
    /// disabled every lookup counts as a miss, so `hits + misses` is
    /// the total lookup volume either way.
    pub misses: u64,
    /// Times the cache was discarded while it held a valid entry.
    pub invalidations: u64,
}

impl MemoCounters {
    /// Fold another counter set into this one (for cluster roll-ups).
    pub fn merge(&mut self, other: &MemoCounters) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.invalidations += other.invalidations;
    }

    /// Hits as a fraction of all lookups; 0.0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Total lookups observed.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_empty_and_partial() {
        let mut c = MemoCounters::default();
        assert_eq!(c.hit_rate(), 0.0);
        c.hits = 3;
        c.misses = 1;
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(c.lookups(), 4);
    }

    #[test]
    fn merge_accumulates_all_fields() {
        let mut a = MemoCounters { hits: 1, misses: 2, invalidations: 3 };
        let b = MemoCounters { hits: 10, misses: 20, invalidations: 30 };
        a.merge(&b);
        assert_eq!(a, MemoCounters { hits: 11, misses: 22, invalidations: 33 });
    }
}
