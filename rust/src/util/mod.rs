//! Offline-environment substrates: JSON, PRNG, statistics, CLI parsing,
//! table rendering and error chaining.  The default build has **zero**
//! external dependencies; only the optional `pjrt` feature expects a
//! vendored `xla` crate (see `runtime::pjrt`).

pub mod cli;
pub mod error;
pub mod json;
pub mod memo;
pub mod rng;
pub mod stats;
pub mod tbl;
