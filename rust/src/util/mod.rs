//! Offline-environment substrates: JSON, PRNG, statistics, CLI parsing
//! and table rendering.  Only `xla` and `anyhow` resolve from the vendored
//! crate set, so everything else the system needs is implemented here.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod tbl;
