//! Tiny argv parser (no clap in the offline crate set).
//!
//! Grammar: `bullet <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's real command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("serve trace.json extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["trace.json", "extra"]);
    }

    #[test]
    fn key_value_both_syntaxes() {
        let a = parse("bench --rate 5.0 --workload=azure-code");
        assert_eq!(a.get("rate"), Some("5.0"));
        assert_eq!(a.get("workload"), Some("azure-code"));
        assert_eq!(a.get_f64("rate", 0.0), 5.0);
    }

    #[test]
    fn bare_flag_at_end() {
        let a = parse("serve --verbose");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --fast --slow");
        assert!(a.flag("fast") && a.flag("slow"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_u64("n", 9), 9);
    }
}
