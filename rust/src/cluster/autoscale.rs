//! Calibration-driven cluster autoscaling: the fleet grows, shrinks and
//! self-heals from the signals PR 3's online calibrators already emit.
//!
//! The control loop (evaluated at arrival-driven control intervals on
//! the global virtual timeline):
//!
//! 1. **Envelope** — the observed arrival rate over a sliding window,
//!    in tokens/s, times an SLO headroom factor.  This is the demand
//!    side of the paper's "adaptively provisioned resources under
//!    latency targets", lifted from SMs-within-a-GPU to
//!    replicas-within-a-fleet.
//! 2. **Capacity** — Σ over active replicas of
//!    `nominal_tokens_per_s / calibrated_slowdown`: the fleet's
//!    *calibrated* capacity, where `nominal` comes from
//!    [`crate::sched::policy::service_capacity_tokens_per_s`] (the same
//!    predictor Algorithm 1 schedules with) and each replica's slowdown
//!    from its own [`crate::perf::OnlineCalibrator`].  A throttling or
//!    co-tenanted device genuinely shrinks the fleet.
//! 3. **Actions** — scale OUT (spawn a replica with the cluster's
//!    inherited `GpuSpec`) when the envelope outruns capacity; scale IN
//!    (drain the slowest replica) when a sustained surplus remains even
//!    without it; RETIRE (deweight-and-drain) a replica whose drift
//!    events keep firing; RE-PROFILE (offline-grid refresh in place) a
//!    replica whose converged calibrator keeps reporting high residuals.
//!
//! **Hysteresis — the no-flap argument.**  Three separations make an
//! out→in oscillation impossible within one window:
//! - threshold separation: scale-out needs `envelope > out_util ×
//!   capacity`, scale-in needs `envelope < in_util × capacity-without-
//!   the-victim`, and `in_util < out_util` (clamped at construction);
//! - cool-downs: any removal (ScaleIn *or* Retire) is refused until
//!   `cooldown_in_s` has passed since the last action in EITHER
//!   direction, and any scale-out until `cooldown_out_s` has passed —
//!   so a scale-out is never followed by a scale-in within one
//!   scale-in cool-down window (the property `tests/properties.rs`
//!   fuzzes);
//! - fleet clamps: `[min_replicas, max_replicas]` bound every action.
//!
//! Determinism: the controller is a pure function of the arrival stream
//! and the replica health snapshots (BTreeMap state, no wall clock), so
//! autoscaled cluster runs replay bit-identically.

use crate::metrics::timeline::ScaleAction;
use crate::perf::CalibrationStats;
use std::collections::{BTreeMap, VecDeque};

/// Autoscaler knobs.  `enabled: false` (the default) removes the
/// subsystem entirely: `serve_cluster` then runs the fixed-fleet path
/// bit-identically to pre-autoscaler behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    pub enabled: bool,
    /// Fleet bounds (both inclusive; min is also the starting floor).
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Seconds of virtual time between control evaluations.
    pub control_interval_s: f64,
    /// Sliding window for the arrival-rate estimate.
    pub rate_window_s: f64,
    /// Envelope multiplier on the observed token arrival rate (>1:
    /// provision ahead of raw demand so queues keep SLO slack).
    pub slo_headroom: f64,
    /// Scale OUT when envelope > this fraction of calibrated capacity.
    pub scale_out_util: f64,
    /// Scale IN only when envelope < this fraction of the capacity that
    /// would REMAIN after the removal.  Clamped below `scale_out_util`.
    pub scale_in_util: f64,
    /// Minimum gap after any action before the next scale-out.
    pub cooldown_out_s: f64,
    /// Minimum gap after any action before the next removal (scale-in
    /// or retire).  The no-flap window.
    pub cooldown_in_s: f64,
    /// Drift events per control window that mark a replica "storming".
    pub retire_drift_events: u64,
    /// Consecutive storming windows before the replica is retired.
    pub retire_windows: u32,
    /// Recent |residual| at-or-above which a CONVERGED replica gets its
    /// offline grid refreshed.
    pub reprofile_residual: f64,
    /// Samples a calibrator needs before its residuals are trusted.
    pub reprofile_min_samples: u64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig::off()
    }
}

impl AutoscaleConfig {
    /// Autoscaling absent (the default): `serve_cluster` takes the
    /// fixed-fleet path untouched.
    pub fn off() -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: false,
            min_replicas: 1,
            max_replicas: 4,
            control_interval_s: 1.0,
            rate_window_s: 8.0,
            slo_headroom: 1.25,
            scale_out_util: 0.85,
            scale_in_util: 0.45,
            cooldown_out_s: 3.0,
            cooldown_in_s: 10.0,
            retire_drift_events: 2,
            retire_windows: 3,
            reprofile_residual: 0.25,
            reprofile_min_samples: 64,
        }
    }

    /// Autoscaling on with default gains and a `[min, max]` fleet.
    pub fn on(min_replicas: usize, max_replicas: usize) -> AutoscaleConfig {
        let min = min_replicas.max(1);
        AutoscaleConfig {
            enabled: true,
            min_replicas: min,
            max_replicas: max_replicas.max(min),
            ..AutoscaleConfig::off()
        }
    }
}

/// A replica's health snapshot, as the controller sees it: the live
/// routing/health signals read through `ServingPolicy::predictor()`.
#[derive(Debug, Clone)]
pub struct ReplicaHealth {
    /// Replica id (stable across the run; retired ids are never reused).
    pub id: usize,
    /// Calibrated observed/nominal slowdown (1.0 when uncalibrated).
    pub slowdown: f64,
    /// The replica's calibration counters (drift events, samples,
    /// recent residual — identity for calibration-free policies).
    pub calib: CalibrationStats,
}

/// One control decision.  At most one is emitted per evaluation; the
/// cool-downs pace the fleet no matter how noisy the inputs are.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Spawn one replica (the cluster layer assigns the new id).
    ScaleOut,
    /// Drain and release this replica (capacity surplus).
    ScaleIn(usize),
    /// Deweight-and-drain this replica (chronic drift).
    Retire(usize),
    /// Refresh this replica's offline grid in place.
    Reprofile(usize),
}

impl ScaleDecision {
    pub fn action(&self) -> ScaleAction {
        match self {
            ScaleDecision::ScaleOut => ScaleAction::ScaleOut,
            ScaleDecision::ScaleIn(_) => ScaleAction::ScaleIn,
            ScaleDecision::Retire(_) => ScaleAction::Retire,
            ScaleDecision::Reprofile(_) => ScaleAction::Reprofile,
        }
    }
}

/// The fleet controller (see module docs).
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    /// (arrival t, prefill tokens, total tokens) inside the rate window.
    window: VecDeque<(f64, f64, f64)>,
    /// First arrival ever seen — bounds the effective averaging span
    /// before a full window of history exists.
    first_arrival: f64,
    last_eval: f64,
    /// Time of the last scale-out / last removal (either kind).
    last_out: f64,
    last_in: f64,
    /// Per-replica drift-event count at the previous evaluation.
    drift_seen: BTreeMap<usize, u64>,
    /// Consecutive storming control windows per replica.
    storm_streak: BTreeMap<usize, u32>,
    /// Last re-profile instant per replica.
    reprofiled: BTreeMap<usize, f64>,
}

impl Autoscaler {
    pub fn new(mut cfg: AutoscaleConfig) -> Autoscaler {
        // threshold separation is part of the no-flap argument — enforce
        // it rather than trusting every caller
        if cfg.scale_in_util >= cfg.scale_out_util || cfg.scale_in_util.is_nan() {
            cfg.scale_in_util = cfg.scale_out_util * 0.5;
        }
        Autoscaler {
            cfg,
            window: VecDeque::new(),
            first_arrival: f64::NAN,
            last_eval: f64::NEG_INFINITY,
            last_out: f64::NEG_INFINITY,
            last_in: f64::NEG_INFINITY,
            drift_seen: BTreeMap::new(),
            storm_streak: BTreeMap::new(),
            reprofiled: BTreeMap::new(),
        }
    }

    pub fn cfg(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Feed one arrival into the demand estimator.
    pub fn note_arrival(&mut self, t: f64, input_len: usize, output_len: usize) {
        if self.first_arrival.is_nan() {
            self.first_arrival = t;
        }
        self.window
            .push_back((t, input_len as f64, (input_len + output_len) as f64));
        let horizon = t - self.cfg.rate_window_s;
        while self.window.front().map(|w| w.0 < horizon).unwrap_or(false) {
            self.window.pop_front();
        }
    }

    /// Observed token arrival rate over the sliding window (tokens/s).
    /// Before a full window of history exists, the divisor is the
    /// elapsed span (floored at a quarter-window) — otherwise a surge
    /// in the first seconds of a run is under-read by up to the
    /// window/elapsed ratio and scale-out lags exactly when it matters.
    pub fn demand_tokens_per_s(&self, now: f64) -> f64 {
        let horizon = now - self.cfg.rate_window_s;
        let total: f64 = self
            .window
            .iter()
            .filter(|w| w.0 >= horizon)
            .map(|w| w.2)
            .sum();
        let window = self.cfg.rate_window_s.max(1e-9);
        let elapsed = if self.first_arrival.is_nan() {
            window
        } else {
            (now - self.first_arrival).clamp(window * 0.25, window)
        };
        total / elapsed
    }

    /// Whether a call to [`Autoscaler::evaluate`] at `now` would run a
    /// control evaluation — callers can skip building fleet snapshots
    /// otherwise (evaluate re-checks, so this is purely an optimization).
    pub fn due(&self, now: f64) -> bool {
        self.cfg.enabled && now - self.last_eval >= self.cfg.control_interval_s
    }

    /// Fraction of windowed arrival tokens that are prefill (prompt)
    /// tokens — the mix the capacity model prices.  0.7 before data.
    pub fn prefill_frac(&self) -> f64 {
        let (p, t) = self
            .window
            .iter()
            .fold((0.0, 0.0), |(p, t), w| (p + w.1, t + w.2));
        if t <= 0.0 {
            0.7
        } else {
            p / t
        }
    }

    /// The fleet's calibrated capacity: Σ nominal / slowdown.  Monotone
    /// non-increasing in every replica's slowdown (property-tested).
    pub fn fleet_capacity_tokens_per_s(nominal_per_replica: f64, fleet: &[ReplicaHealth]) -> f64 {
        fleet
            .iter()
            .map(|h| nominal_per_replica / h.slowdown.max(1e-6))
            .sum()
    }

    /// Run one control evaluation at virtual time `now` over the ACTIVE
    /// (non-draining) fleet.  `nominal_per_replica` is the homogeneous
    /// per-replica capacity unit (see
    /// [`crate::sched::policy::service_capacity_tokens_per_s`]).
    pub fn evaluate(
        &mut self,
        now: f64,
        nominal_per_replica: f64,
        fleet: &[ReplicaHealth],
    ) -> Option<ScaleDecision> {
        if !self.cfg.enabled || fleet.is_empty() {
            return None;
        }
        if now - self.last_eval < self.cfg.control_interval_s {
            return None;
        }
        self.last_eval = now;

        // Health bookkeeping: drift-event deltas per control window.
        for h in fleet {
            let seen = self.drift_seen.insert(h.id, h.calib.drift_events).unwrap_or(0);
            let delta = h.calib.drift_events.saturating_sub(seen);
            let streak = self.storm_streak.entry(h.id).or_insert(0);
            if delta >= self.cfg.retire_drift_events {
                *streak += 1;
            } else {
                *streak = 0;
            }
        }

        let n = fleet.len();
        let removable = n > self.cfg.min_replicas;
        let removal_cooled = now - self.last_out >= self.cfg.cooldown_in_s
            && now - self.last_in >= self.cfg.cooldown_in_s;

        // 1. Retire a chronically drifting replica (health removal).
        if removable && removal_cooled {
            let victim = fleet
                .iter()
                .filter(|h| {
                    self.storm_streak.get(&h.id).copied().unwrap_or(0) >= self.cfg.retire_windows
                })
                .max_by(|a, b| {
                    let sa = self.storm_streak.get(&a.id).copied().unwrap_or(0);
                    let sb = self.storm_streak.get(&b.id).copied().unwrap_or(0);
                    sa.cmp(&sb)
                        .then(a.slowdown.total_cmp(&b.slowdown))
                        .then(a.id.cmp(&b.id))
                });
            if let Some(v) = victim {
                self.last_in = now;
                self.storm_streak.insert(v.id, 0);
                return Some(ScaleDecision::Retire(v.id));
            }
        }

        // 2. Re-profile a converged replica whose residual stays high.
        for h in fleet {
            let since = now - self.reprofiled.get(&h.id).copied().unwrap_or(f64::NEG_INFINITY);
            if h.calib.samples >= self.cfg.reprofile_min_samples
                && h.calib.recent_abs_residual >= self.cfg.reprofile_residual
                && since >= self.cfg.cooldown_in_s
            {
                self.reprofiled.insert(h.id, now);
                return Some(ScaleDecision::Reprofile(h.id));
            }
        }

        // 3. Capacity loop: calibrated capacity vs the SLO envelope.
        let envelope = self.demand_tokens_per_s(now) * self.cfg.slo_headroom;
        let capacity = Self::fleet_capacity_tokens_per_s(nominal_per_replica, fleet);
        if n < self.cfg.max_replicas
            && envelope > self.cfg.scale_out_util * capacity
            && now - self.last_out >= self.cfg.cooldown_out_s
            && now - self.last_in >= self.cfg.cooldown_out_s
        {
            self.last_out = now;
            return Some(ScaleDecision::ScaleOut);
        }
        if removable && removal_cooled {
            // shed the slowest replica only if the remainder still
            // clears the envelope with margin
            let victim = fleet
                .iter()
                .max_by(|a, b| a.slowdown.total_cmp(&b.slowdown).then(a.id.cmp(&b.id)))
                .expect("non-empty fleet");
            let remaining = capacity - nominal_per_replica / victim.slowdown.max(1e-6);
            if envelope < self.cfg.scale_in_util * remaining {
                self.last_in = now;
                return Some(ScaleDecision::ScaleIn(victim.id));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health(id: usize, slowdown: f64) -> ReplicaHealth {
        ReplicaHealth { id, slowdown, calib: CalibrationStats::default() }
    }

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            control_interval_s: 0.5,
            rate_window_s: 4.0,
            cooldown_out_s: 2.0,
            cooldown_in_s: 6.0,
            ..AutoscaleConfig::on(1, 4)
        }
    }

    /// Push `rate` tokens/s worth of arrivals across [t0, t1).
    fn drive(a: &mut Autoscaler, t0: f64, t1: f64, tokens_per_s: f64) {
        let step = 0.25;
        let mut t = t0;
        while t < t1 {
            let (input, output) = (tokens_per_s * step * 0.9, tokens_per_s * step * 0.1);
            a.note_arrival(t, input as usize, output as usize);
            t += step;
        }
    }

    #[test]
    fn scales_out_when_envelope_exceeds_capacity() {
        let mut a = Autoscaler::new(cfg());
        // 20k tok/s demand against one 10k-nominal replica
        drive(&mut a, 0.0, 5.0, 20_000.0);
        let d = a.evaluate(5.0, 10_000.0, &[health(0, 1.0)]);
        assert_eq!(d, Some(ScaleDecision::ScaleOut));
        // within the out-cool-down, nothing more happens
        drive(&mut a, 5.0, 6.0, 20_000.0);
        assert_eq!(a.evaluate(6.0, 10_000.0, &[health(0, 1.0), health(1, 1.0)]), None);
    }

    #[test]
    fn calibrated_slowdown_shrinks_capacity_and_triggers_scale_out() {
        // demand a single HEALTHY replica could carry — but this fleet's
        // devices learned a 3x slowdown, so capacity is a third
        let mut a = Autoscaler::new(cfg());
        drive(&mut a, 0.0, 5.0, 6_000.0);
        let healthy = a.evaluate(5.0, 10_000.0, &[health(0, 1.0)]);
        assert_eq!(healthy, None, "healthy capacity covers the envelope");
        let mut b = Autoscaler::new(cfg());
        drive(&mut b, 0.0, 5.0, 6_000.0);
        let slowed = b.evaluate(5.0, 10_000.0, &[health(0, 3.0)]);
        assert_eq!(slowed, Some(ScaleDecision::ScaleOut));
    }

    #[test]
    fn scales_in_the_slowest_replica_after_sustained_lull() {
        let mut a = Autoscaler::new(cfg());
        drive(&mut a, 0.0, 10.0, 500.0);
        let fleet = [health(0, 1.0), health(1, 2.0), health(2, 1.1)];
        let d = a.evaluate(10.0, 10_000.0, &fleet);
        assert_eq!(d, Some(ScaleDecision::ScaleIn(1)), "slowest replica sheds first");
        // and the removal opens its own cool-down
        assert_eq!(a.evaluate(11.0, 10_000.0, &fleet[..2]), None);
    }

    #[test]
    fn retires_on_chronic_drift_and_resets_the_streak() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            retire_drift_events: 2,
            retire_windows: 2,
            ..cfg()
        });
        let mut sick = health(1, 1.5);
        let well = health(0, 1.0);
        // keep demand mid-band so neither capacity action can fire
        drive(&mut a, 0.0, 10.0, 6_000.0);
        // window 1: 2 fresh drift events -> streak 1
        sick.calib.drift_events = 2;
        assert_eq!(a.evaluate(7.0, 10_000.0, &[well.clone(), sick.clone()]), None);
        // window 2: 2 more -> streak 2 -> retire
        sick.calib.drift_events = 4;
        let d = a.evaluate(8.0, 10_000.0, &[well.clone(), sick.clone()]);
        assert_eq!(d, Some(ScaleDecision::Retire(1)));
        // a quiet replica never accrues a streak
        assert_eq!(a.evaluate(20.0, 10_000.0, &[well]), None);
    }

    #[test]
    fn reprofiles_converged_high_residual_replicas_once_per_window() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            reprofile_min_samples: 50,
            reprofile_residual: 0.2,
            ..cfg()
        });
        drive(&mut a, 0.0, 10.0, 6_000.0);
        let mut h = health(0, 2.0);
        h.calib.samples = 100;
        h.calib.recent_abs_residual = 0.5;
        let fleet = [h.clone(), health(1, 1.0)];
        let d = a.evaluate(7.0, 10_000.0, &fleet);
        assert_eq!(d, Some(ScaleDecision::Reprofile(0)));
        // not again within the cool-down, even though the snapshot
        // still reports a high residual
        assert_eq!(a.evaluate(8.0, 10_000.0, &fleet), None);
        // a cold calibrator is never re-profiled (min fleet blocks the
        // capacity fallbacks so the gate itself is what's tested)
        let mut b = Autoscaler::new(AutoscaleConfig {
            reprofile_min_samples: 50,
            reprofile_residual: 0.2,
            control_interval_s: 0.5,
            ..AutoscaleConfig::on(2, 4)
        });
        let mut cold = health(0, 2.0);
        cold.calib.samples = 10;
        cold.calib.recent_abs_residual = 0.9;
        assert_eq!(b.evaluate(1.0, 10_000.0, &[cold, health(1, 1.0)]), None);
    }

    #[test]
    fn fleet_bounds_are_hard() {
        let mut a = Autoscaler::new(AutoscaleConfig {
            cooldown_out_s: 0.0,
            cooldown_in_s: 0.0,
            ..cfg()
        });
        // overload: never scales past max
        drive(&mut a, 0.0, 5.0, 1e9);
        let four: Vec<ReplicaHealth> = (0..4).map(|i| health(i, 1.0)).collect();
        assert_eq!(a.evaluate(5.0, 10_000.0, &four), None, "at max_replicas");
        // idle: never shrinks below min
        let mut b = Autoscaler::new(AutoscaleConfig {
            cooldown_out_s: 0.0,
            cooldown_in_s: 0.0,
            ..AutoscaleConfig::on(2, 4)
        });
        drive(&mut b, 0.0, 5.0, 1.0);
        let two: Vec<ReplicaHealth> = (0..2).map(|i| health(i, 1.0)).collect();
        assert_eq!(b.evaluate(5.0, 10_000.0, &two), None, "at min_replicas");
    }

    #[test]
    fn threshold_separation_is_enforced() {
        let a = Autoscaler::new(AutoscaleConfig {
            scale_out_util: 0.5,
            scale_in_util: 0.9, // inverted on purpose
            ..AutoscaleConfig::on(1, 4)
        });
        assert!(a.cfg().scale_in_util < a.cfg().scale_out_util);
    }

    #[test]
    fn demand_window_slides() {
        let mut a = Autoscaler::new(cfg()); // 4 s window, 1 s floor
        a.note_arrival(0.0, 900, 100);
        a.note_arrival(1.0, 900, 100);
        // warm-up: only 1 s has elapsed, so the divisor is the elapsed
        // span (not the full window) — an early surge reads at full rate
        assert!((a.demand_tokens_per_s(1.0) - 2000.0).abs() < 1e-9);
        // both arrivals age out of the window; divisor is the window
        a.note_arrival(10.0, 90, 10);
        assert!((a.demand_tokens_per_s(10.0) - 25.0).abs() < 1e-9);
        assert!((a.prefill_frac() - 0.9).abs() < 1e-9);
        // the elapsed-span floor damps a single instantaneous arrival
        let mut b = Autoscaler::new(cfg());
        b.note_arrival(0.0, 4000, 0);
        assert!((b.demand_tokens_per_s(0.0) - 4000.0).abs() < 1e-9, "floored at window/4 = 1 s");
    }
}
