//! Request routing across replicas.
//!
//! Four policies, in increasing awareness:
//! - **round-robin** — stateless rotation, the classic front-door;
//! - **least-kv** — route to the replica with the fewest outstanding KV
//!   tokens (reserved pool + queued reservations), a memory-pressure
//!   signal that tracks decode-heavy load;
//! - **slo-slack** — route to the replica whose estimated TTFT for this
//!   request leaves the most SLO slack, using the §3.2 performance
//!   estimator over the replica's prefill backlog (a compute-pressure
//!   signal that tracks prefill-heavy load);
//! - **prefix-affinity** — pin each conversation to one replica so its
//!   later turns land where the session's KV prefix is already cached
//!   (a session's first turn, and sessionless traffic, falls back to
//!   least-kv).  Replica prefix caches are private, so spreading a
//!   session across replicas forfeits every hit after the first turn —
//!   stickiness IS the locality policy.

use crate::cluster::Replica;
use crate::config::SloSpec;
use crate::perf::PerfModel;
use crate::workload::Request;
use std::collections::BTreeMap;

/// Cluster routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastKv,
    SloSlack,
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn by_name(name: &str) -> Option<RouterPolicy> {
        match name {
            "round-robin" => Some(RouterPolicy::RoundRobin),
            "least-kv" => Some(RouterPolicy::LeastKv),
            "slo-slack" => Some(RouterPolicy::SloSlack),
            "prefix-affinity" => Some(RouterPolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastKv => "least-kv",
            RouterPolicy::SloSlack => "slo-slack",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn all() -> [RouterPolicy; 4] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastKv,
            RouterPolicy::SloSlack,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// The dispatcher: picks a replica for each arrival.  Deterministic
/// given the replica states, so cluster runs are reproducible.
pub struct Dispatcher {
    policy: RouterPolicy,
    rr_next: usize,
    /// prefix-affinity stickiness: session id → replica.
    session_map: BTreeMap<u64, usize>,
}

impl Dispatcher {
    pub fn new(policy: RouterPolicy) -> Dispatcher {
        Dispatcher {
            policy,
            rr_next: 0,
            session_map: BTreeMap::new(),
        }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Sessions currently pinned (prefix-affinity only).
    pub fn pinned_sessions(&self) -> usize {
        self.session_map.len()
    }

    /// Choose the replica for `req`.  Replica clocks have been advanced
    /// to the arrival time, so state queries are current.
    pub fn pick(
        &mut self,
        replicas: &[Replica],
        req: &Request,
        perf: &PerfModel,
        slo: &SloSpec,
    ) -> usize {
        assert!(!replicas.is_empty());
        match self.policy {
            RouterPolicy::RoundRobin => {
                let k = self.rr_next % replicas.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                k
            }
            RouterPolicy::LeastKv => argmin_by(replicas, |r| r.outstanding_kv_tokens() as f64),
            RouterPolicy::SloSlack => {
                // max slack == min estimated TTFT for a single request,
                // but keep the slack form: it is what a multi-model
                // front-door would compare across heterogeneous SLOs.
                argmin_by(replicas, |r| {
                    let est = r.estimated_ttft(req, perf);
                    -(slo.ttft_budget(req.input_len) - est)
                })
            }
            RouterPolicy::PrefixAffinity => {
                let Some(sid) = req.session_id else {
                    // sessionless traffic: no prefix to chase
                    return argmin_by(replicas, |r| r.outstanding_kv_tokens() as f64);
                };
                if let Some(&k) = self.session_map.get(&sid) {
                    return k;
                }
                // first turn: balance by memory pressure, then stick
                let k = argmin_by(replicas, |r| r.outstanding_kv_tokens() as f64);
                self.session_map.insert(sid, k);
                k
            }
        }
    }
}

/// Index of the replica minimizing `key` (first wins ties; `total_cmp`
/// keeps degenerate estimates from panicking the dispatcher).
fn argmin_by(replicas: &[Replica], key: impl Fn(&Replica) -> f64) -> usize {
    let mut best = 0usize;
    let mut best_key = key(&replicas[0]);
    for (i, r) in replicas.iter().enumerate().skip(1) {
        let k = key(r);
        if k.total_cmp(&best_key) == std::cmp::Ordering::Less {
            best = i;
            best_key = k;
        }
    }
    best
}
