//! Request routing across replicas.
//!
//! Four policies, in increasing awareness:
//! - **round-robin** — stateless rotation, the classic front-door;
//! - **least-kv** — route to the replica with the fewest outstanding KV
//!   tokens (reserved pool + queued reservations), a memory-pressure
//!   signal that tracks decode-heavy load;
//! - **slo-slack** — route to the replica whose estimated TTFT for this
//!   request leaves the most SLO slack, using the §3.2 performance
//!   estimator over the replica's prefill backlog (a compute-pressure
//!   signal that tracks prefill-heavy load);
//! - **prefix-affinity** — pin each conversation to one replica so its
//!   later turns land where the session's KV prefix is already cached
//!   (a session's first turn, and sessionless traffic, falls back to
//!   least-kv).  Replica prefix caches are private, so spreading a
//!   session across replicas forfeits every hit after the first turn —
//!   stickiness IS the locality policy.
//!
//! Routing consumes [`ReplicaSignals`] snapshots — per-replica state
//! frozen at the dispatch-horizon barrier — never live replicas.  That
//! keeps the dispatcher a pure function of the snapshot vector (the
//! cluster layer's determinism argument) and lets replicas live on
//! simulation worker threads while routing stays serial on main.

use crate::cluster::ReplicaSignals;
use crate::config::SloSpec;
use crate::perf::PerfModel;
use crate::util::memo::MemoCounters;
use crate::workload::Request;
use std::collections::BTreeMap;

/// Cluster routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    LeastKv,
    SloSlack,
    PrefixAffinity,
}

impl RouterPolicy {
    pub fn by_name(name: &str) -> Option<RouterPolicy> {
        match name {
            "round-robin" => Some(RouterPolicy::RoundRobin),
            "least-kv" => Some(RouterPolicy::LeastKv),
            "slo-slack" => Some(RouterPolicy::SloSlack),
            "prefix-affinity" => Some(RouterPolicy::PrefixAffinity),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastKv => "least-kv",
            RouterPolicy::SloSlack => "slo-slack",
            RouterPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    pub fn all() -> [RouterPolicy; 4] {
        [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastKv,
            RouterPolicy::SloSlack,
            RouterPolicy::PrefixAffinity,
        ]
    }
}

/// The dispatcher: picks a replica for each arrival.  Deterministic
/// given the signal snapshots, so cluster runs are reproducible.
pub struct Dispatcher {
    policy: RouterPolicy,
    rr_next: usize,
    /// prefix-affinity stickiness: session id → replica.
    session_map: BTreeMap<u64, usize>,
    /// Hot-path memoization toggle ([`crate::config::ServingConfig::memo`]).
    memo: bool,
    /// slo-slack probe memo: the per-prompt-token probe depends only on
    /// `(num_sms, contended)` against the FROZEN offline model every
    /// call site passes, so one probe per distinct key serves the whole
    /// run — no invalidation needed.  (A caller that swapped `perf`
    /// between calls would have to toggle the memo off.)
    probe_memo: BTreeMap<(usize, bool), f64>,
    probe_counters: MemoCounters,
}

impl Dispatcher {
    pub fn new(policy: RouterPolicy) -> Dispatcher {
        Dispatcher {
            policy,
            rr_next: 0,
            session_map: BTreeMap::new(),
            memo: true,
            probe_memo: BTreeMap::new(),
            probe_counters: MemoCounters::default(),
        }
    }

    /// Toggle probe memoization (reference path when off; bit-identical
    /// by construction — a hit replays the stored probe value, which is
    /// the exact f64 the reference path computes).
    pub fn set_memo(&mut self, on: bool) {
        self.memo = on;
        self.probe_memo.clear();
    }

    /// Hit/miss counters for the slo-slack probe memo.
    pub fn probe_memo_counters(&self) -> MemoCounters {
        self.probe_counters
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Sessions currently pinned (prefix-affinity only).
    pub fn pinned_sessions(&self) -> usize {
        self.session_map.len()
    }

    /// Choose the replica for `req` among `eligible` indices into
    /// `signals` (the autoscaled path routes over the active,
    /// non-draining subset; the fixed fleet passes every index).
    /// Snapshots were taken at this arrival's horizon barrier and
    /// already fold in same-instant pushes, so state queries are
    /// current.  A prefix-affinity session pinned to a now-ineligible
    /// replica is RE-HOMED: the pin is dropped and the session
    /// re-sticks to the least-loaded eligible replica (its cached
    /// prefix is forfeited — retirement drains the KV with the
    /// replica).
    pub fn pick_among(
        &mut self,
        signals: &[ReplicaSignals],
        eligible: &[usize],
        req: &Request,
        perf: &PerfModel,
        slo: &SloSpec,
    ) -> usize {
        assert!(!eligible.is_empty(), "no active replica to route to");
        let least_kv = |s: &[ReplicaSignals], e: &[usize]| {
            argmin_among(s, e, |r| r.outstanding_kv_tokens as f64)
        };
        match self.policy {
            RouterPolicy::RoundRobin => {
                let k = eligible[self.rr_next % eligible.len()];
                self.rr_next = self.rr_next.wrapping_add(1);
                k
            }
            RouterPolicy::LeastKv => least_kv(signals, eligible),
            RouterPolicy::SloSlack => {
                // max slack == min estimated TTFT for a single request,
                // but keep the slack form: it is what a multi-model
                // front-door would compare across heterogeneous SLOs.
                let memo = self.memo;
                let probe_memo = &mut self.probe_memo;
                let counters = &mut self.probe_counters;
                argmin_among(signals, eligible, |r| {
                    let per_token = if memo {
                        let key = (r.num_sms, r.decode_batch > 0);
                        match probe_memo.get(&key) {
                            Some(&v) => {
                                counters.hits += 1;
                                v
                            }
                            None => {
                                counters.misses += 1;
                                let v = r.probe_per_token(perf);
                                probe_memo.insert(key, v);
                                v
                            }
                        }
                    } else {
                        r.probe_per_token(perf)
                    };
                    let est = r.estimated_ttft_with(per_token, req);
                    -(slo.ttft_budget(req.input_len) - est)
                })
            }
            RouterPolicy::PrefixAffinity => {
                let Some(sid) = req.session_id else {
                    // sessionless traffic: no prefix to chase
                    return least_kv(signals, eligible);
                };
                if let Some(&k) = self.session_map.get(&sid) {
                    if eligible.contains(&k) {
                        return k;
                    }
                    // pinned replica is draining: re-home the session
                    self.session_map.remove(&sid);
                }
                // first (or re-homed) turn: balance by memory pressure,
                // then stick
                let k = least_kv(signals, eligible);
                self.session_map.insert(sid, k);
                k
            }
        }
    }

    /// Drop every session pinned to replica `k` (called when the
    /// autoscaler retires it); their next turns re-home via
    /// [`Dispatcher::pick_among`].  Returns how many were unpinned.
    pub fn unpin_replica(&mut self, k: usize) -> usize {
        let before = self.session_map.len();
        self.session_map.retain(|_, v| *v != k);
        before - self.session_map.len()
    }
}

/// Eligible index minimizing `key` (first wins ties; `total_cmp` keeps
/// degenerate estimates from panicking the dispatcher).  `FnMut` so
/// memoizing keys can update their cache as they scan.
fn argmin_among(
    signals: &[ReplicaSignals],
    eligible: &[usize],
    mut key: impl FnMut(&ReplicaSignals) -> f64,
) -> usize {
    let mut best = eligible[0];
    let mut best_key = key(&signals[best]);
    for &i in &eligible[1..] {
        let k = key(&signals[i]);
        if k.total_cmp(&best_key) == std::cmp::Ordering::Less {
            best = i;
            best_key = k;
        }
    }
    best
}
