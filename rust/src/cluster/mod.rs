//! Multi-replica scale-out: N independent simulated-GPU engine instances
//! behind a dispatcher.
//!
//! Each [`Replica`] is one [`EngineCore`] plus one boxed
//! [`ServingPolicy`] — the same pairing as single-GPU serving, which is
//! the point: once every system is a policy over the shared core, the
//! cluster layer can scale *any* of them (Bullet, chunked, NanoFlow,
//! MuxServe-style fixed quotas) without touching engine code.
//!
//! Co-simulation model: replicas share the global virtual timeline.  The
//! dispatcher walks the trace in arrival order; before routing a request
//! it advances every replica's clock to the arrival instant
//! ([`EngineCore::run_until`]), so state-aware routers (least-kv,
//! slo-slack) observe live queue depths, KV pressure and backlogs — not
//! a static pre-partition of the trace.  A replica mid-kernel may
//! overshoot the instant by one completion; routing signals are
//! heuristics, so this bounded skew is acceptable and keeps the replicas
//! lock-step-free.  Determinism: replica seeds derive from the run seed,
//! and the dispatcher is a pure function of replica state.

pub mod router;

pub use router::{Dispatcher, RouterPolicy};

use crate::baselines::System;
use crate::config::{derive_kv_capacity, DriftSpec, GpuSpec, ServingConfig};
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::kvcache::prefix::PrefixStats;
use crate::metrics::{merge_records, RequestRecord};
use crate::perf::{CalibrationStats, PerfModel, PerfPredictor};
use crate::workload::Request;

/// Per-replica hardware overrides for a heterogeneous fleet.  `None`
/// fields inherit the cluster-wide config / ground truth, so an
/// all-default spec is exactly a homogeneous replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaSpec {
    /// This replica's GPU (KV capacity is re-derived from it).
    pub gpu: Option<GpuSpec>,
    /// This replica's drift regime (throttling, co-tenant, lottery).
    pub drift: Option<DriftSpec>,
}

/// Cluster shape: replica count + routing policy (+ optional
/// heterogeneous per-replica hardware).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub replicas: usize,
    pub router: RouterPolicy,
    /// Entry `i` overrides replica `i`'s hardware; replicas beyond the
    /// list (or an empty list — the default) are homogeneous.  A shared
    /// offline perf model is wrong for such a fleet by construction;
    /// per-replica online calibration (`ServingConfig::calibration`) is
    /// how routing signals stay truthful.
    pub replica_specs: Vec<ReplicaSpec>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            replica_specs: Vec::new(),
        }
    }
}

/// One simulated GPU running one serving policy.
pub struct Replica {
    pub id: usize,
    core: EngineCore,
    policy: Box<dyn ServingPolicy>,
}

impl Replica {
    pub fn new(
        id: usize,
        system: System,
        cfg: &ServingConfig,
        perf: &PerfModel,
        gt: &GroundTruth,
        seed: u64,
        max_virtual_time: f64,
    ) -> Replica {
        let opts = CoreOptions {
            seed,
            max_virtual_time,
            ..CoreOptions::default()
        };
        Replica {
            id,
            core: EngineCore::new(cfg.clone(), gt.clone(), Vec::new(), &opts),
            policy: system.policy(cfg, perf),
        }
    }

    pub fn label(&self) -> String {
        self.policy.label()
    }

    pub fn now(&self) -> f64 {
        self.core.now()
    }

    /// Requests routed to this replica so far.
    pub fn assigned(&self) -> usize {
        self.core.trace_len()
    }

    /// Routing signal: KV tokens reserved + queued reservations.
    pub fn outstanding_kv_tokens(&self) -> usize {
        self.core.outstanding_kv_tokens()
    }

    /// Routing signal: prompt tokens awaiting prefill (queue + active
    /// batch remainder).
    pub fn backlog_tokens(&self) -> usize {
        self.core.queued_prefill_tokens() + self.policy.private_backlog_tokens()
    }

    pub fn decode_batch(&self) -> usize {
        self.core.decode.len()
    }

    /// Estimated TTFT were `req` routed here now: the prefill backlog
    /// plus the request's own prompt, at the estimator's per-token rate
    /// (contended if a decode batch is resident), scaled by the
    /// replica's learned slowdown — so on a heterogeneous or drifting
    /// fleet the slo-slack router ranks replicas by their *calibrated*
    /// speed, not the shared offline grid.  The slowdown (not a cell
    /// lookup at this probe's shape) is used deliberately: calibration
    /// cells are shape-local and the fixed probe shape may never have
    /// been launched, while the slowdown aggregates every observed
    /// cell.  Exactly 1.0 for calibration-free or unobserved replicas.
    pub fn estimated_ttft(&self, req: &Request, perf: &PerfModel) -> f64 {
        let cfg = &self.core.cfg;
        let contended = !self.core.decode.is_empty();
        let reference = 2048usize;
        let per_token =
            perf.predict_prefill_layer(reference, 0, cfg.gpu.num_sms, contended) / reference as f64;
        let tokens = (self.backlog_tokens() + req.input_len) as f64;
        tokens * per_token * cfg.model.n_layers as f64 * self.calibrated_slowdown()
    }

    /// The replica's learned observed/nominal slowdown (1.0 until its
    /// calibrator has samples, or for calibration-free policies).
    pub fn calibrated_slowdown(&self) -> f64 {
        self.policy
            .predictor()
            .map(|p| p.calibrated_slowdown())
            .unwrap_or(1.0)
    }

    fn advance_to(&mut self, t: f64) {
        self.core.run_until(self.policy.as_mut(), t);
    }

    fn push(&mut self, r: Request) {
        self.core.push_request(r);
    }

    fn finish(mut self) -> EngineOutput {
        self.core.run(self.policy.as_mut());
        self.core.into_output()
    }
}

/// Everything a cluster run produces.
#[derive(Debug, Clone)]
pub struct ClusterOutput {
    /// All records, id-ordered (directly comparable with single-GPU runs).
    pub records: Vec<RequestRecord>,
    /// Per-replica engine outputs (replica index = vec index).
    pub per_replica: Vec<EngineOutput>,
    /// (request id, replica index) routing decisions, in arrival order.
    pub assignments: Vec<(u64, usize)>,
    /// Global makespan: the latest replica finish time.
    pub virtual_duration: f64,
}

impl ClusterOutput {
    /// Requests routed to each replica.
    pub fn per_replica_counts(&self) -> Vec<usize> {
        let n = self.per_replica.len();
        let mut counts = vec![0usize; n];
        for &(_, k) in &self.assignments {
            counts[k] += 1;
        }
        counts
    }

    /// Cluster-wide prefix-cache counters (summed over replicas; all
    /// zero with the cache off).  Replica caches are private, so the
    /// aggregate hit rate is what the routing policy actually earned.
    pub fn prefix_stats(&self) -> PrefixStats {
        let mut total = PrefixStats::default();
        for o in &self.per_replica {
            total.merge(&o.prefix);
        }
        total
    }

    /// Cluster-wide calibration counters (sample-weighted merge).
    pub fn calibration_stats(&self) -> CalibrationStats {
        let mut total = CalibrationStats::default();
        for o in &self.per_replica {
            total.merge(&o.calibration);
        }
        total
    }

    /// Each replica's learned slowdown — the heterogeneity fingerprint
    /// (all 1.0 with calibration off).
    pub fn calibrated_slowdowns(&self) -> Vec<f64> {
        self.per_replica.iter().map(|o| o.calibration.slowdown).collect()
    }
}

/// Serve `trace` on `cluster.replicas` instances of `system` behind the
/// configured router.
pub fn serve_cluster(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
    cluster: &ClusterConfig,
) -> ClusterOutput {
    let n = cluster.replicas.max(1);
    // Wedge guard that scales with the trace horizon: long-duration
    // traces must not trip the single-GPU default cap.
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let max_virtual_time = CoreOptions::default().max_virtual_time.max(4.0 * horizon);
    let mut replicas: Vec<Replica> = (0..n)
        .map(|i| {
            // distinct per-replica seeds decorrelate simulator noise
            // (and draw distinct device-lottery factors under drift)
            let rseed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1));
            // heterogeneous fleet: apply this replica's hardware spec
            match cluster.replica_specs.get(i) {
                None => Replica::new(i, system, cfg, perf, gt, rseed, max_virtual_time),
                Some(spec) => {
                    let mut rcfg = cfg.clone();
                    let mut rgt = gt.clone();
                    if let Some(gpu) = &spec.gpu {
                        // re-derive KV capacity for the new device ONLY
                        // when the operator left it at the derived
                        // default — an explicitly pinned capacity (e.g.
                        // a KV-tight experiment) must survive per-
                        // replica compute overrides
                        let was_derived = rcfg.kv_capacity_tokens
                            == derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                        rcfg.gpu = gpu.clone();
                        if was_derived {
                            rcfg.kv_capacity_tokens =
                                derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                        }
                        rgt.gpu = gpu.clone();
                    }
                    if let Some(drift) = &spec.drift {
                        rgt.drift = drift.clone();
                    }
                    Replica::new(i, system, &rcfg, perf, &rgt, rseed, max_virtual_time)
                }
            }
        })
        .collect();
    let mut dispatcher = Dispatcher::new(cluster.router);
    let mut assignments = Vec::with_capacity(trace.len());

    for r in trace {
        for rep in replicas.iter_mut() {
            rep.advance_to(r.arrival);
        }
        let k = dispatcher.pick(&replicas, r, perf, &cfg.slo);
        assignments.push((r.id, k));
        replicas[k].push(r.clone());
    }

    let per_replica: Vec<EngineOutput> = replicas.into_iter().map(Replica::finish).collect();
    let records = merge_records(per_replica.iter().map(|o| o.records.as_slice()));
    let virtual_duration = per_replica
        .iter()
        .map(|o| o.virtual_duration)
        .fold(0.0, f64::max);
    ClusterOutput {
        records,
        per_replica,
        assignments,
        virtual_duration,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        (cfg, perf, gt)
    }

    #[test]
    fn round_robin_splits_evenly_and_completes() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 12, 7);
        let ccfg =
            ClusterConfig { replicas: 3, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 1, &ccfg);
        assert_eq!(out.records.len(), 12);
        assert_eq!(out.per_replica_counts(), vec![4, 4, 4]);
        // merged records id-ordered and unique
        for (i, r) in out.records.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn state_aware_routers_complete_the_trace() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 16, 11);
        for router in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
            let ccfg = ClusterConfig { replicas: 2, router, ..Default::default() };
            let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 2, &ccfg);
            assert_eq!(out.records.len(), 16, "{}", router.label());
            let counts = out.per_replica_counts();
            // a state-aware router must not starve a replica under load
            assert!(counts.iter().all(|&c| c > 0), "{:?}", counts);
        }
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 3);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::LeastKv, ..Default::default() };
        let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        assert_eq!(a.records, b.records);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn replicas_cut_makespan_under_saturation() {
        let (cfg, perf, gt) = setup();
        // heavily saturating: compute-bound prefills arrive far faster
        // than one GPU can drain them
        let trace = generate_n_requests(&Dataset::azure_code(), 40.0, 40, 13);
        let one = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 1, router: RouterPolicy::RoundRobin, ..Default::default() },
        );
        let four = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 4, router: RouterPolicy::LeastKv, ..Default::default() },
        );
        assert_eq!(four.records.len(), 40);
        assert!(
            four.virtual_duration < one.virtual_duration * 0.55,
            "1 replica {}s vs 4 replicas {}s",
            one.virtual_duration,
            four.virtual_duration
        );
    }

    #[test]
    fn prefix_affinity_pins_sessions_and_earns_hits() {
        use crate::workload::{generate_sessions, SessionProfile};
        let cfg = ServingConfig { prefix_cache: true, ..ServingConfig::default() };
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        let trace = generate_sessions(&SessionProfile::conversational(), 1.5, 12, 19);
        let run = |router| {
            serve_cluster(
                System::Bullet,
                &cfg,
                &perf,
                &gt,
                &trace,
                4,
                &ClusterConfig { replicas: 3, router, ..Default::default() },
            )
        };
        let aff = run(RouterPolicy::PrefixAffinity);
        assert_eq!(aff.records.len(), trace.len());
        // stickiness: every turn of a session lands on one replica
        let mut session_replica = std::collections::BTreeMap::new();
        for (r, &(id, k)) in trace.iter().zip(&aff.assignments) {
            assert_eq!(r.id, id);
            let sid = r.session_id.unwrap();
            assert_eq!(*session_replica.entry(sid).or_insert(k), k, "session {sid} split");
        }
        // and that locality converts later turns into prefix hits
        let s = aff.prefix_stats();
        assert!(s.hits > 0, "affinity routing must earn hits: {s:?}");
        // round-robin scatters turns across private caches — it cannot
        // beat stickiness on hit rate
        let rr = run(RouterPolicy::RoundRobin);
        assert!(
            s.hit_rate() >= rr.prefix_stats().hit_rate(),
            "affinity {:.2} < round-robin {:.2}",
            s.hit_rate(),
            rr.prefix_stats().hit_rate()
        );
    }

    #[test]
    fn heterogeneous_replicas_calibrate_apart() {
        use crate::config::CalibrationConfig;
        // Replica 1 is a half-speed device; the shared offline model is
        // profiled for the full-speed one.  Per-replica calibration must
        // learn the difference: replica 1's slowdown diverges from
        // replica 0's.
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.5,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.5,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::RoundRobin,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
        };
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 21);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 20);
        let sd = out.calibrated_slowdowns();
        assert!(
            sd[1] > sd[0] * 1.3,
            "half-speed replica must learn a ~2x larger slowdown: {sd:?}"
        );
        let cs = out.calibration_stats();
        assert!(cs.samples > 0);
    }

    #[test]
    fn slo_slack_router_sheds_load_off_the_slow_replica() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.4,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.4,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::SloSlack,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
        };
        let trace = generate_n_requests(&Dataset::azure_code(), 10.0, 30, 5);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 7, &ccfg);
        assert_eq!(out.records.len(), 30);
        let counts = out.per_replica_counts();
        assert!(
            counts[1] < counts[0],
            "router must shed load off the slow replica: {counts:?}"
        );
    }

    #[test]
    fn cluster_scales_chunked_systems_too() {
        // the whole point of the shared core: baselines scale out with
        // zero engine changes.
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 17);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Sglang1024, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 10);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        assert!(s.throughput_tok_s > 0.0);
    }
}
