//! Multi-replica scale-out: N independent simulated-GPU engine instances
//! behind a dispatcher.
//!
//! Each [`Replica`] is one [`EngineCore`] plus one boxed
//! [`ServingPolicy`] — the same pairing as single-GPU serving, which is
//! the point: once every system is a policy over the shared core, the
//! cluster layer can scale *any* of them (Bullet, chunked, NanoFlow,
//! MuxServe-style fixed quotas) without touching engine code.
//!
//! Co-simulation model: replicas share the global virtual timeline.  The
//! dispatcher walks the trace in arrival order; before routing a request
//! it advances every replica's clock to the arrival instant
//! ([`EngineCore::run_until`]), so state-aware routers (least-kv,
//! slo-slack) observe live queue depths, KV pressure and backlogs — not
//! a static pre-partition of the trace.  A replica mid-kernel may
//! overshoot the instant by one completion; routing signals are
//! heuristics, so this bounded skew is acceptable and keeps the replicas
//! lock-step-free.
//!
//! Parallel execution: replicas are share-nothing BETWEEN dispatch
//! horizons — between two consecutive arrivals no information flows
//! across replicas — so each horizon is a barrier: all replicas advance
//! to the arrival instant concurrently (a [`std::thread::scope`] worker
//! pool, `ClusterConfig::sim_threads` wide), then the router and
//! autoscaler run serially on main over per-replica [`ReplicaSignals`]
//! snapshots taken at the barrier.  A replica's evolution is a pure
//! function of its own command sequence (advance / push / reprofile),
//! and the snapshots are pure functions of replica state, so the
//! parallel path is BIT-IDENTICAL to `sim_threads = 1` — an invariant
//! the test suite asserts per engine × router × autoscale cell.
//!
//! Idle fast-forward: a drained replica (no queued, in-flight, or
//! private work) cannot change state until its next push, and thanks to
//! the engine's absolute idle jumps ([`Simulator::advance_idle_to`])
//! skipping its `advance_to` calls lands it on bitwise-identical
//! timestamps once work arrives.  Both backends therefore skip drained
//! replicas entirely, making the per-arrival sweep O(busy replicas) —
//! this is the per-replica next-event-time scheme in its exact form:
//! a drained replica's next event IS its next push, and a busy replica
//! must be advanced anyway.
//!
//! Determinism: replica seeds derive from the run seed, and the
//! dispatcher is a pure function of the signal snapshots.
//!
//! [`Simulator::advance_idle_to`]: crate::gpu::simulator::Simulator::advance_idle_to

pub mod autoscale;
pub mod router;

pub use autoscale::{AutoscaleConfig, Autoscaler, ReplicaHealth, ScaleDecision};
pub use router::{Dispatcher, RouterPolicy};

use crate::baselines::System;
use crate::config::{derive_kv_capacity, DriftSpec, GpuSpec, ServingConfig};
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::gateway::stream::StreamChunk;
use crate::kvcache::prefix::PrefixStats;
use crate::metrics::timeline::{ScaleAction, ScaleEvent};
use crate::metrics::{merge_outcomes, merge_records, LifecycleStats, OutcomeRecord, RequestRecord};
use crate::perf::{CalibrationStats, PerfModel, PerfPredictor};
use crate::sched::policy::service_capacity_tokens_per_s;
use crate::util::memo::MemoCounters;
use crate::workload::Request;
use std::sync::mpsc;
use std::thread;

/// Per-replica hardware overrides for a heterogeneous fleet.  `None`
/// fields inherit the cluster-wide config / ground truth, so an
/// all-default spec is exactly a homogeneous replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaSpec {
    /// This replica's GPU (KV capacity is re-derived from it).
    pub gpu: Option<GpuSpec>,
    /// This replica's drift regime (throttling, co-tenant, lottery).
    pub drift: Option<DriftSpec>,
}

/// A scheduled replica crash: replica `replica` is killed the first time
/// the global dispatch clock reaches `at` — at the next arrival horizon,
/// or after the last arrival if `at` lies beyond the trace.  The crash
/// rides the retire machinery (no more traffic, prefix-affinity sessions
/// re-home) but skips the drain: in-flight work is orphaned, re-queued
/// where its prefill never started and counted `Lost` otherwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureSpec {
    pub replica: usize,
    pub at: f64,
}

/// Cluster shape: replica count + routing policy (+ optional
/// heterogeneous per-replica hardware, + the optional autoscaler,
/// + the simulation thread budget, + optional failure injection).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub replicas: usize,
    pub router: RouterPolicy,
    /// Entry `i` overrides replica `i`'s hardware; replicas beyond the
    /// list (or an empty list — the default) are homogeneous.  A shared
    /// offline perf model is wrong for such a fleet by construction;
    /// per-replica online calibration (`ServingConfig::calibration`) is
    /// how routing signals stay truthful.  Autoscaler-spawned replicas
    /// inherit entry `i` for their id too (ids past the list get the
    /// cluster default — the "inherited `GpuSpec`" of a scale-out).
    pub replica_specs: Vec<ReplicaSpec>,
    /// Calibration-driven fleet control (disabled by default: the
    /// fixed-fleet dispatch path runs bit-identically to pre-autoscaler
    /// behavior).  With `enabled`, `replicas` (clamped into
    /// `[min_replicas, max_replicas]`) is the starting fleet.
    pub autoscale: AutoscaleConfig,
    /// Simulation worker threads for the between-horizon replica
    /// advances: `0` (the default) uses every available core, `1`
    /// forces the serial backend.  Any value produces bit-identical
    /// output — this knob trades wall-clock only.
    pub sim_threads: usize,
    /// Scheduled replica crashes (empty by default: the failure-free
    /// dispatch path runs bit-identically to pre-injection behavior).
    /// Processed in `(at, replica)` order; a failure naming an already
    /// retired or crashed replica is a no-op, and killing the last live
    /// replica is a configuration error (panics).
    pub failures: Vec<FailureSpec>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            replica_specs: Vec::new(),
            autoscale: AutoscaleConfig::off(),
            sim_threads: 0,
            failures: Vec::new(),
        }
    }
}

impl ClusterConfig {
    /// Worker threads the dispatch loop will actually use: the
    /// requested `sim_threads` (0 ⇒ all available cores) capped by the
    /// largest fleet this run can reach — more workers than replicas
    /// could never be productive.
    pub fn effective_sim_threads(&self) -> usize {
        let requested = if self.sim_threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.sim_threads
        };
        let fleet_bound = if self.autoscale.enabled {
            self.autoscale.max_replicas.max(1)
        } else {
            self.replicas.max(1)
        };
        requested.clamp(1, fleet_bound)
    }
}

/// One simulated GPU running one serving policy.
pub struct Replica {
    pub id: usize,
    core: EngineCore,
    policy: Box<dyn ServingPolicy>,
    /// No queued, in-flight, or policy-private work: `advance_to` is a
    /// pure clock jump until the next push, so backends skip it (see
    /// module docs).  Maintained here — set by `advance_to`, cleared by
    /// `push` — so the serial and parallel backends cannot disagree.
    /// Crate-visible: the gateway's event loop skips drained replicas
    /// the same way the backends do.
    pub(crate) drained: bool,
}

impl Replica {
    pub fn new(
        id: usize,
        system: System,
        cfg: &ServingConfig,
        perf: &PerfModel,
        gt: &GroundTruth,
        seed: u64,
        max_virtual_time: f64,
    ) -> Replica {
        let opts = CoreOptions {
            seed,
            max_virtual_time,
            ..CoreOptions::default()
        };
        Replica {
            id,
            core: EngineCore::new(cfg.clone(), gt.clone(), Vec::new(), &opts),
            policy: system.policy(cfg, perf),
            // a fresh replica holds no work until its first push
            drained: true,
        }
    }

    pub fn label(&self) -> String {
        self.policy.label()
    }

    pub fn now(&self) -> f64 {
        self.core.now()
    }

    /// Requests routed to this replica so far.
    pub fn assigned(&self) -> usize {
        self.core.trace_len()
    }

    /// Routing signal: KV tokens reserved + queued reservations.
    pub fn outstanding_kv_tokens(&self) -> usize {
        self.core.outstanding_kv_tokens()
    }

    /// Routing signal: prompt tokens awaiting prefill (queue + active
    /// batch remainder).
    pub fn backlog_tokens(&self) -> usize {
        self.core.queued_prefill_tokens() + self.policy.private_backlog_tokens()
    }

    pub fn decode_batch(&self) -> usize {
        self.core.decode.len()
    }

    /// The replica's learned observed/nominal slowdown (1.0 until its
    /// calibrator has samples, or for calibration-free policies).
    pub fn calibrated_slowdown(&self) -> f64 {
        self.policy
            .predictor()
            .map(|p| p.calibrated_slowdown())
            .unwrap_or(1.0)
    }

    /// The replica's live calibration counters (identity for
    /// calibration-free policies) — the autoscaler's health snapshot.
    pub fn calibration(&self) -> CalibrationStats {
        self.policy
            .predictor()
            .map(|p| p.calibration())
            .unwrap_or_default()
    }

    /// Refresh this replica's offline perf grid in place (autoscaler
    /// re-profiling action).  Calibration-free policies decline.
    pub fn reprofile(&mut self) -> bool {
        self.policy.reprofile()
    }

    /// Snapshot every dispatcher-visible signal.  Taken at each horizon
    /// barrier so routing and autoscaling read frozen, thread-free state.
    /// `num_sms` is the SM count the prefill probe prices against: the
    /// policy's pinned prefill partition when it keeps one
    /// ([`ServingPolicy::probe_prefill_sms`] — the P/D disaggregation
    /// baselines), else the replica's full GPU.
    pub fn signals(&self) -> ReplicaSignals {
        ReplicaSignals {
            id: self.id,
            outstanding_kv_tokens: self.outstanding_kv_tokens(),
            backlog_tokens: self.backlog_tokens(),
            decode_batch: self.decode_batch(),
            num_sms: self
                .policy
                .probe_prefill_sms()
                .unwrap_or(self.core.cfg.gpu.num_sms)
                .min(self.core.cfg.gpu.num_sms),
            n_layers: self.core.cfg.model.n_layers,
            slowdown: self.calibrated_slowdown(),
            calib: self.calibration(),
            drained: self.drained,
        }
    }

    pub(crate) fn advance_to(&mut self, t: f64) {
        self.core.run_until(self.policy.as_mut(), t);
        self.drained = self.core.drained() && !self.policy.has_private_work();
    }

    pub(crate) fn push(&mut self, r: Request) {
        self.drained = false;
        self.core.push_request(r);
    }

    /// Attach a token-streaming sink for a request routed here (gateway
    /// admission, and sink re-attachment when an orphan re-homes).
    pub(crate) fn attach_stream(&mut self, id: u64, tx: mpsc::Sender<StreamChunk>) {
        self.core.attach_stream(id, tx);
    }

    /// Kill this replica at `t` (see [`EngineCore::crash`]): returns the
    /// orphaned requests that can re-queue elsewhere.  The replica is
    /// drained afterwards — `finish` returns immediately and `advance_to`
    /// reduces to nothing.
    pub(crate) fn crash(&mut self, t: f64) -> Vec<Request> {
        let orphans = self.core.crash(t);
        self.drained = true;
        orphans
    }

    pub(crate) fn finish(mut self) -> EngineOutput {
        self.core.run(self.policy.as_mut());
        self.core.into_output()
    }
}

/// Replica `i`'s derived seed: distinct per-replica streams decorrelate
/// simulator noise (and draw distinct device-lottery factors under
/// drift).  Shared by the cluster fleet and the gateway so a request
/// served through either front door lands on a bit-identical replica.
pub(crate) fn replica_seed(seed: u64, i: usize) -> u64 {
    seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1))
}

/// A replica's dispatcher-visible state, frozen at a horizon barrier.
/// Everything the router and autoscaler consult lives here, so the
/// serial decision code never touches a `Replica` that may be owned by
/// a worker thread — and both backends route from literally the same
/// data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSignals {
    pub id: usize,
    /// KV tokens reserved + queued and injected reservations.
    pub outstanding_kv_tokens: usize,
    /// Prompt tokens awaiting prefill (queue + private batches +
    /// injected tail).
    pub backlog_tokens: usize,
    /// Resident decode batch size.
    pub decode_batch: usize,
    /// The replica's SM count (heterogeneous fleets differ).
    pub num_sms: usize,
    pub n_layers: usize,
    /// Learned observed/nominal slowdown (1.0 uncalibrated).
    pub slowdown: f64,
    /// Live calibration counters (the autoscaler's health input).
    pub calib: CalibrationStats,
    /// Whether the replica was drained at the snapshot (no work
    /// anywhere) — backends use this to skip its next advances.
    pub drained: bool,
}

impl ReplicaSignals {
    /// Estimated TTFT were `req` routed here now: the prefill backlog
    /// plus the request's own prompt, at the estimator's per-token rate
    /// (contended if a decode batch is resident), scaled by the
    /// replica's learned slowdown — so on a heterogeneous or drifting
    /// fleet the slo-slack router ranks replicas by their *calibrated*
    /// speed, not the shared offline grid.  The slowdown (not a cell
    /// lookup at this probe's shape) is used deliberately: calibration
    /// cells are shape-local and the fixed probe shape may never have
    /// been launched, while the slowdown aggregates every observed
    /// cell.  Exactly 1.0 for calibration-free or unobserved replicas.
    pub fn estimated_ttft(&self, req: &Request, perf: &PerfModel) -> f64 {
        self.estimated_ttft_with(self.probe_per_token(perf), req)
    }

    /// The slo-slack router's per-prompt-token probe: one fixed-shape
    /// prefill-layer prediction, normalized per token.  Depends only on
    /// `(num_sms, decode_batch > 0)`, which is exactly what the
    /// [`crate::cluster::router::Dispatcher`] memoizes across arrivals.
    pub fn probe_per_token(&self, perf: &PerfModel) -> f64 {
        let contended = self.decode_batch > 0;
        let reference = 2048usize;
        perf.predict_prefill_layer(reference, 0, self.num_sms, contended) / reference as f64
    }

    /// [`ReplicaSignals::estimated_ttft`] with the probe already in hand
    /// (the dispatcher's memoized path).  Same arithmetic, same order.
    pub fn estimated_ttft_with(&self, per_token: f64, req: &Request) -> f64 {
        let tokens = (self.backlog_tokens + req.input_len) as f64;
        tokens * per_token * self.n_layers as f64 * self.slowdown
    }

    /// The autoscaler's view of this replica.
    pub fn health(&self) -> ReplicaHealth {
        ReplicaHealth { id: self.id, slowdown: self.slowdown, calib: self.calib }
    }

    /// Fold a just-routed request into the snapshot: exactly the
    /// injected-but-unadmitted contribution a live state read would see
    /// ([`EngineCore::outstanding_kv_tokens`] / `queued_prefill_tokens`),
    /// so same-instant arrivals observe prior routing decisions without
    /// another barrier.
    pub(crate) fn note_push(&mut self, r: &Request) {
        self.outstanding_kv_tokens += r.input_len + r.output_len;
        self.backlog_tokens += r.input_len;
    }
}

/// Everything a cluster run produces.
#[derive(Debug, Clone)]
pub struct ClusterOutput {
    /// All records, id-ordered (directly comparable with single-GPU runs).
    pub records: Vec<RequestRecord>,
    /// Terminal events for requests that did not complete (cancelled,
    /// expired, lost to a crash), id-ordered.  Empty for lifecycle-free
    /// traces without failure injection.
    pub outcomes: Vec<OutcomeRecord>,
    /// Per-replica engine outputs (replica index = vec index; with
    /// autoscaling, every replica ever spawned — retired ones included).
    pub per_replica: Vec<EngineOutput>,
    /// (request id, replica index) routing decisions, in arrival order.
    pub assignments: Vec<(u64, usize)>,
    /// Global makespan: the latest replica finish time.
    pub virtual_duration: f64,
    /// Autoscaler decisions on the global timeline (empty with the
    /// autoscaler off).  Each also rides the targeted replica's
    /// `EngineOutput::scale_events` / timeline.
    pub scale_events: Vec<ScaleEvent>,
    /// Replica-steps consumed: Σ over replicas of seconds held (spawn →
    /// retirement-or-end-of-run, drain included).  A fixed fleet spends
    /// `replicas × virtual_duration`; the autoscaler's provisioning bar
    /// is beating `max_replicas × virtual_duration` while also beating
    /// the fixed fleet's latency.
    pub replica_steps: f64,
    /// slo-slack probe-memo counters (observability only — never part
    /// of any bit-parity comparison; all zero for other routers).
    pub router_memo: MemoCounters,
}

impl ClusterOutput {
    /// Per-outcome counters; `submitted()` equals the trace length.
    pub fn lifecycle_stats(&self) -> LifecycleStats {
        LifecycleStats::from_parts(&self.records, &self.outcomes)
    }

    /// Requests routed to each replica.
    pub fn per_replica_counts(&self) -> Vec<usize> {
        let n = self.per_replica.len();
        let mut counts = vec![0usize; n];
        for &(_, k) in &self.assignments {
            counts[k] += 1;
        }
        counts
    }

    /// Cluster-wide prefix-cache counters (summed over replicas; all
    /// zero with the cache off).  Replica caches are private, so the
    /// aggregate hit rate is what the routing policy actually earned.
    pub fn prefix_stats(&self) -> PrefixStats {
        let mut total = PrefixStats::default();
        for o in &self.per_replica {
            total.merge(&o.prefix);
        }
        total
    }

    /// Cluster-wide calibration counters (sample-weighted merge).
    pub fn calibration_stats(&self) -> CalibrationStats {
        let mut total = CalibrationStats::default();
        for o in &self.per_replica {
            total.merge(&o.calibration);
        }
        total
    }

    /// Cluster-wide simulator rate-table memo counters (summed).
    pub fn rate_memo_stats(&self) -> MemoCounters {
        let mut total = MemoCounters::default();
        for o in &self.per_replica {
            total.merge(&o.rate_memo);
        }
        total
    }

    /// Cluster-wide calibrated-prediction memo counters (summed).
    pub fn predict_memo_stats(&self) -> MemoCounters {
        let mut total = MemoCounters::default();
        for o in &self.per_replica {
            total.merge(&o.predict_memo);
        }
        total
    }

    /// Each replica's learned slowdown — the heterogeneity fingerprint
    /// (all 1.0 with calibration off).
    pub fn calibrated_slowdowns(&self) -> Vec<f64> {
        self.per_replica.iter().map(|o| o.calibration.slowdown).collect()
    }

    /// Cluster-wide SM-second attribution ledger (summed over replicas;
    /// each per-replica ledger is already finalized, so the aggregate
    /// stays conserved: categories sum to Σ num_sms × makespan).
    pub fn ledger(&self) -> crate::obs::SmLedger {
        let mut total = crate::obs::SmLedger::default();
        for o in &self.per_replica {
            total.merge(&o.ledger);
        }
        total
    }
}

/// Everything replica construction needs — shared by the fixed-fleet
/// path and the autoscaler's spawn action, so a scaled-out replica is
/// constructed exactly like a boot-time one.
struct FleetCtx<'a> {
    system: System,
    cfg: &'a ServingConfig,
    perf: &'a PerfModel,
    gt: &'a GroundTruth,
    seed: u64,
    max_virtual_time: f64,
    cluster: &'a ClusterConfig,
}

impl FleetCtx<'_> {
    /// Build replica `i` with its derived seed and (optional)
    /// per-replica hardware spec.
    fn build_replica(&self, i: usize) -> Replica {
        let (system, cfg, perf, gt) = (self.system, self.cfg, self.perf, self.gt);
        let rseed = replica_seed(self.seed, i);
        // heterogeneous fleet: apply this replica's hardware spec
        match self.cluster.replica_specs.get(i) {
            None => Replica::new(i, system, cfg, perf, gt, rseed, self.max_virtual_time),
            Some(spec) => {
                let mut rcfg = cfg.clone();
                let mut rgt = gt.clone();
                if let Some(gpu) = &spec.gpu {
                    // re-derive KV capacity for the new device ONLY
                    // when the operator left it at the derived
                    // default — an explicitly pinned capacity (e.g.
                    // a KV-tight experiment) must survive per-
                    // replica compute overrides
                    let was_derived =
                        rcfg.kv_capacity_tokens == derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                    rcfg.gpu = gpu.clone();
                    if was_derived {
                        rcfg.kv_capacity_tokens = derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                    }
                    rgt.gpu = gpu.clone();
                }
                if let Some(drift) = &spec.drift {
                    rgt.drift = drift.clone();
                }
                Replica::new(i, system, &rcfg, perf, &rgt, rseed, self.max_virtual_time)
            }
        }
    }
}

/// How the dispatch loop drives the fleet.  Two implementations —
/// [`SerialFleet`] and [`ParallelFleet`] — which must be observationally
/// identical: the loop routes from [`ReplicaSignals`] snapshots only,
/// and each replica's evolution is a pure function of its own command
/// sequence, so where replicas live (this thread or a worker) cannot
/// change any output bit.
trait FleetBackend {
    /// Replicas ever spawned (retired included).
    fn replica_count(&self) -> usize;
    /// Horizon barrier: every replica reaches virtual time `t` and the
    /// signal snapshot of every non-drained replica is refreshed.
    /// (A drained replica's signals cannot change while drained; its
    /// cached snapshot stays valid — the idle fast-forward.)
    fn advance_to(&mut self, t: f64);
    /// Snapshots as of the last barrier, indexed by replica id.
    fn signals(&self) -> &[ReplicaSignals];
    /// Route request `r` to replica `id`.
    fn push(&mut self, id: usize, r: Request);
    /// Build and adopt the next replica; returns its id.
    fn spawn(&mut self) -> usize;
    /// Refresh replica `id`'s offline grid and its snapshot.
    fn reprofile(&mut self, id: usize);
    /// Kill replica `id` at `t` (failure injection); returns the
    /// orphaned requests that can re-queue elsewhere and refreshes the
    /// snapshot (the dead replica reads as drained).
    fn crash(&mut self, id: usize, t: f64) -> Vec<Request>;
    /// Drain every replica to completion; outputs ordered by id.
    fn finish(self) -> Vec<EngineOutput>;
}

/// The `sim_threads = 1` backend: replicas live on the dispatch thread.
struct SerialFleet<'a> {
    ctx: FleetCtx<'a>,
    replicas: Vec<Replica>,
    signals: Vec<ReplicaSignals>,
}

impl<'a> SerialFleet<'a> {
    fn new(ctx: FleetCtx<'a>, init: usize) -> SerialFleet<'a> {
        let replicas: Vec<Replica> = (0..init).map(|i| ctx.build_replica(i)).collect();
        let signals = replicas.iter().map(Replica::signals).collect();
        SerialFleet { ctx, replicas, signals }
    }
}

impl FleetBackend for SerialFleet<'_> {
    fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    fn advance_to(&mut self, t: f64) {
        for r in self.replicas.iter_mut() {
            if !r.drained {
                r.advance_to(t);
                self.signals[r.id] = r.signals();
            }
        }
    }

    fn signals(&self) -> &[ReplicaSignals] {
        &self.signals
    }

    fn push(&mut self, id: usize, r: Request) {
        self.signals[id].note_push(&r);
        self.replicas[id].push(r);
    }

    fn spawn(&mut self) -> usize {
        let id = self.replicas.len();
        let r = self.ctx.build_replica(id);
        self.signals.push(r.signals());
        self.replicas.push(r);
        id
    }

    fn reprofile(&mut self, id: usize) {
        self.replicas[id].reprofile();
        self.signals[id] = self.replicas[id].signals();
    }

    fn crash(&mut self, id: usize, t: f64) -> Vec<Request> {
        let orphans = self.replicas[id].crash(t);
        self.signals[id] = self.replicas[id].signals();
        orphans
    }

    fn finish(self) -> Vec<EngineOutput> {
        self.replicas.into_iter().map(Replica::finish).collect()
    }
}

/// Commands a worker replays over its owned replicas, in dispatch
/// order — the same calls `SerialFleet` makes directly.
enum WorkerCmd {
    /// Advance every owned non-drained replica to the horizon; reply
    /// `Signals` for those that moved.
    Advance(f64),
    Push(usize, Request),
    /// Take ownership of a freshly spawned replica.
    Adopt(Box<Replica>),
    /// Reprofile one replica; reply its refreshed `Signals`.
    Reprofile(usize),
    /// Kill one replica at the instant; reply `Orphans`.
    Crash(usize, f64),
    /// Drain all owned replicas; reply `Outputs`, then exit.
    Finish,
}

enum WorkerReply {
    Signals(Vec<ReplicaSignals>),
    Orphans(Vec<Request>, ReplicaSignals),
    Outputs(Vec<(usize, EngineOutput)>),
}

/// A simulation worker: owns the replicas with `id % workers == w` and
/// replays dispatch commands over them.  Per-worker command channels
/// are FIFO, so each replica sees exactly the serial call sequence.
fn fleet_worker(
    rx: mpsc::Receiver<WorkerCmd>,
    tx: mpsc::Sender<WorkerReply>,
    mut owned: Vec<Replica>,
) {
    let find = |owned: &[Replica], id: usize| -> usize {
        owned.iter().position(|r| r.id == id).expect("command for unowned replica")
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Advance(t) => {
                let mut moved = Vec::new();
                for r in owned.iter_mut() {
                    if !r.drained {
                        r.advance_to(t);
                        moved.push(r.signals());
                    }
                }
                if tx.send(WorkerReply::Signals(moved)).is_err() {
                    return;
                }
            }
            WorkerCmd::Push(id, req) => {
                let i = find(&owned, id);
                owned[i].push(req);
            }
            WorkerCmd::Adopt(r) => owned.push(*r),
            WorkerCmd::Reprofile(id) => {
                let i = find(&owned, id);
                owned[i].reprofile();
                let sig = vec![owned[i].signals()];
                if tx.send(WorkerReply::Signals(sig)).is_err() {
                    return;
                }
            }
            WorkerCmd::Crash(id, t) => {
                let i = find(&owned, id);
                let orphans = owned[i].crash(t);
                let sig = owned[i].signals();
                if tx.send(WorkerReply::Orphans(orphans, sig)).is_err() {
                    return;
                }
            }
            WorkerCmd::Finish => {
                let outs = owned.drain(..).map(|r| (r.id, r.finish())).collect();
                let _ = tx.send(WorkerReply::Outputs(outs));
                return;
            }
        }
    }
}

/// The `sim_threads > 1` backend: replicas are sharded `id % workers`
/// across a persistent [`std::thread::scope`] pool; `advance_to` is the
/// horizon barrier (fan out one `Advance`, collect one reply per live
/// worker).  Replies are merged by replica id, so worker timing cannot
/// reorder anything the dispatcher observes.
struct ParallelFleet<'a> {
    ctx: FleetCtx<'a>,
    workers: usize,
    cmd_tx: Vec<mpsc::Sender<WorkerCmd>>,
    reply_rx: Vec<mpsc::Receiver<WorkerReply>>,
    signals: Vec<ReplicaSignals>,
    /// Main-thread mirror of each replica's drained flag (updated from
    /// barrier replies and pushes), used to skip waking workers whose
    /// replicas all provably cannot move.
    drained: Vec<bool>,
}

impl<'a> ParallelFleet<'a> {
    fn new<'scope, 'env>(
        s: &'scope thread::Scope<'scope, 'env>,
        workers: usize,
        ctx: FleetCtx<'a>,
        init: usize,
    ) -> ParallelFleet<'a> {
        // build on main, in id order, exactly like the serial backend —
        // construction order is part of the determinism contract
        let replicas: Vec<Replica> = (0..init).map(|i| ctx.build_replica(i)).collect();
        let signals: Vec<ReplicaSignals> = replicas.iter().map(Replica::signals).collect();
        let drained: Vec<bool> = replicas.iter().map(|r| r.drained).collect();
        let mut shards: Vec<Vec<Replica>> = (0..workers).map(|_| Vec::new()).collect();
        for r in replicas {
            let w = r.id % workers;
            shards[w].push(r);
        }
        let mut cmd_tx = Vec::with_capacity(workers);
        let mut reply_rx = Vec::with_capacity(workers);
        for shard in shards {
            let (ctx_tx, cmd_rx) = mpsc::channel::<WorkerCmd>();
            let (rep_tx, rep_rx) = mpsc::channel::<WorkerReply>();
            s.spawn(move || fleet_worker(cmd_rx, rep_tx, shard));
            cmd_tx.push(ctx_tx);
            reply_rx.push(rep_rx);
        }
        ParallelFleet { ctx, workers, cmd_tx, reply_rx, signals, drained }
    }

    fn send(&self, w: usize, cmd: WorkerCmd) {
        self.cmd_tx[w].send(cmd).expect("simulation worker died");
    }

    fn recv(&self, w: usize) -> WorkerReply {
        self.reply_rx[w].recv().expect("simulation worker died")
    }

    fn merge_signals(&mut self, sigs: Vec<ReplicaSignals>) {
        for s in sigs {
            self.drained[s.id] = s.drained;
            self.signals[s.id] = s;
        }
    }
}

impl FleetBackend for ParallelFleet<'_> {
    fn replica_count(&self) -> usize {
        self.signals.len()
    }

    fn advance_to(&mut self, t: f64) {
        // wake only workers owning a live replica; an all-drained
        // worker's replicas cannot move (the parallel form of the
        // serial backend's skip)
        let mut live = vec![false; self.workers];
        for (id, &d) in self.drained.iter().enumerate() {
            if !d {
                live[id % self.workers] = true;
            }
        }
        for w in 0..self.workers {
            if live[w] {
                self.send(w, WorkerCmd::Advance(t));
            }
        }
        // the virtual-clock barrier: collected in worker order, merged
        // by replica id — deterministic regardless of thread timing
        for w in 0..self.workers {
            if live[w] {
                match self.recv(w) {
                    WorkerReply::Signals(sigs) => self.merge_signals(sigs),
                    _ => unreachable!("non-signal reply at a barrier"),
                }
            }
        }
    }

    fn signals(&self) -> &[ReplicaSignals] {
        &self.signals
    }

    fn push(&mut self, id: usize, r: Request) {
        self.signals[id].note_push(&r);
        self.drained[id] = false;
        self.send(id % self.workers, WorkerCmd::Push(id, r));
    }

    fn spawn(&mut self) -> usize {
        let id = self.signals.len();
        let r = self.ctx.build_replica(id);
        self.signals.push(r.signals());
        self.drained.push(r.drained);
        self.send(id % self.workers, WorkerCmd::Adopt(Box::new(r)));
        id
    }

    fn reprofile(&mut self, id: usize) {
        let w = id % self.workers;
        self.send(w, WorkerCmd::Reprofile(id));
        match self.recv(w) {
            WorkerReply::Signals(sigs) => self.merge_signals(sigs),
            _ => unreachable!("non-signal reply to reprofile"),
        }
    }

    fn crash(&mut self, id: usize, t: f64) -> Vec<Request> {
        let w = id % self.workers;
        self.send(w, WorkerCmd::Crash(id, t));
        match self.recv(w) {
            WorkerReply::Orphans(orphans, sig) => {
                self.merge_signals(vec![sig]);
                orphans
            }
            _ => unreachable!("non-orphan reply to crash"),
        }
    }

    fn finish(self) -> Vec<EngineOutput> {
        for w in 0..self.workers {
            self.send(w, WorkerCmd::Finish);
        }
        let mut out: Vec<Option<EngineOutput>> = (0..self.signals.len()).map(|_| None).collect();
        for w in 0..self.workers {
            match self.recv(w) {
                WorkerReply::Outputs(v) => {
                    for (id, o) in v {
                        out[id] = Some(o);
                    }
                }
                _ => unreachable!("non-output reply after finish"),
            }
        }
        out.into_iter().map(|o| o.expect("missing replica output")).collect()
    }
}

/// Process every injected failure due at or before `now`: crash the
/// replica through the backend, route it out of eligibility exactly like
/// a retire (prefix-affinity sessions re-home via `unpin_replica`), and
/// re-dispatch the orphans the crash returned at arrival time `now`.
/// Orphan re-routes append to `assignments` (a re-homed id appears
/// twice: original route + re-route).
#[allow(clippy::too_many_arguments)]
fn process_due_failures<F: FleetBackend>(
    fleet: &mut F,
    dispatcher: &mut Dispatcher,
    failures: &[FailureSpec],
    next_failure: &mut usize,
    now: f64,
    cfg: &ServingConfig,
    perf: &PerfModel,
    retired_at: &mut [Option<f64>],
    eligible: &mut Vec<usize>,
    scale_events: &mut Vec<ScaleEvent>,
    assignments: &mut Vec<(u64, usize)>,
) {
    while *next_failure < failures.len() && failures[*next_failure].at <= now {
        let f = failures[*next_failure];
        *next_failure += 1;
        let id = f.replica;
        assert!(id < retired_at.len(), "failure injection names unknown replica {id}");
        if retired_at[id].is_some() {
            continue; // already retired or crashed — nothing to kill
        }
        let orphans = fleet.crash(id, now);
        retired_at[id] = Some(now);
        eligible.retain(|&i| i != id);
        dispatcher.unpin_replica(id);
        assert!(
            !eligible.is_empty(),
            "failure injection killed the last live replica at t={now}"
        );
        let fleet_after = retired_at.iter().filter(|t| t.is_none()).count();
        scale_events.push(ScaleEvent {
            t: now,
            action: ScaleAction::Crash,
            replica: id,
            fleet_after,
        });
        for o in orphans {
            let k = dispatcher.pick_among(fleet.signals(), eligible, &o, perf, &cfg.slo);
            assignments.push((o.id, k));
            fleet.push(k, o);
        }
    }
}

/// The dispatch loop, generic over the backend: advance to each arrival
/// (the horizon barrier), run the autoscaler control step if due, route
/// from the signal snapshots, push.  Router reads, dispatch and scale
/// actions are serial and ordered here on the calling thread — the
/// backends only move replicas through virtual time.
fn run_dispatch<F: FleetBackend>(
    mut fleet: F,
    cfg: &ServingConfig,
    perf: &PerfModel,
    trace: &[Request],
    cluster: &ClusterConfig,
) -> ClusterOutput {
    let autoscaled = cluster.autoscale.enabled;
    let init = fleet.replica_count();
    let mut dispatcher = Dispatcher::new(cluster.router);
    dispatcher.set_memo(cfg.memo);
    let mut scaler = autoscaled.then(|| Autoscaler::new(cluster.autoscale.clone()));
    let mut spawned_at: Vec<f64> = vec![0.0; init];
    let mut retired_at: Vec<Option<f64>> = vec![None; init];
    let mut eligible: Vec<usize> = (0..init).collect();
    let mut scale_events: Vec<ScaleEvent> = Vec::new();
    let mut assignments = Vec::with_capacity(trace.len());
    // injected failures fire in (at, replica) order as the dispatch
    // clock passes them
    let mut failures = cluster.failures.clone();
    failures.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.replica.cmp(&b.replica)));
    let mut next_failure = 0usize;

    for r in trace {
        // barrier: every replica reaches the dispatch horizon before
        // the router or autoscaler observes fleet state (retired
        // replicas keep draining through the same barriers)
        fleet.advance_to(r.arrival);

        process_due_failures(
            &mut fleet,
            &mut dispatcher,
            &failures,
            &mut next_failure,
            r.arrival,
            cfg,
            perf,
            &mut retired_at,
            &mut eligible,
            &mut scale_events,
            &mut assignments,
        );

        if let Some(scaler) = scaler.as_mut() {
            scaler.note_arrival(r.arrival, r.input_len, r.output_len);
            // health snapshots and capacity pricing only when a control
            // evaluation will actually run (evaluate re-checks the gate)
            let decision = if scaler.due(r.arrival) {
                let health: Vec<ReplicaHealth> = fleet
                    .signals()
                    .iter()
                    .filter(|s| retired_at[s.id].is_none())
                    .map(ReplicaSignals::health)
                    .collect();
                let nominal = service_capacity_tokens_per_s(perf, cfg, scaler.prefill_frac());
                scaler.evaluate(r.arrival, nominal, &health)
            } else {
                None
            };
            if let Some(decision) = decision {
                let target = match decision {
                    ScaleDecision::ScaleOut => {
                        let id = fleet.spawn();
                        spawned_at.push(r.arrival);
                        retired_at.push(None);
                        eligible.push(id);
                        id
                    }
                    ScaleDecision::ScaleIn(id) | ScaleDecision::Retire(id) => {
                        retired_at[id] = Some(r.arrival);
                        eligible.retain(|&i| i != id);
                        // sessions pinned here must re-home on their
                        // next turn
                        dispatcher.unpin_replica(id);
                        id
                    }
                    ScaleDecision::Reprofile(id) => {
                        fleet.reprofile(id);
                        id
                    }
                };
                let fleet_after = retired_at.iter().filter(|t| t.is_none()).count();
                scale_events.push(ScaleEvent {
                    t: r.arrival,
                    action: decision.action(),
                    replica: target,
                    fleet_after,
                });
            }
        }

        let k = dispatcher.pick_among(fleet.signals(), &eligible, r, perf, &cfg.slo);
        assignments.push((r.id, k));
        fleet.push(k, r.clone());
    }

    // failures scheduled past the last arrival still fire: advance the
    // fleet to each remaining instant and process it there
    while next_failure < failures.len() {
        let t = failures[next_failure].at;
        fleet.advance_to(t);
        process_due_failures(
            &mut fleet,
            &mut dispatcher,
            &failures,
            &mut next_failure,
            t,
            cfg,
            perf,
            &mut retired_at,
            &mut eligible,
            &mut scale_events,
            &mut assignments,
        );
    }

    let mut per_replica = fleet.finish();
    // lifecycle events ride the targeted replica's own output/timeline
    for ev in &scale_events {
        per_replica[ev.replica].scale_events.push(*ev);
        per_replica[ev.replica].timeline.push_event(*ev);
    }
    let records = merge_records(per_replica.iter().map(|o| o.records.as_slice()));
    let outcomes = merge_outcomes(per_replica.iter().map(|o| o.outcomes.as_slice()));
    let virtual_duration = per_replica
        .iter()
        .map(|o| o.virtual_duration)
        .fold(0.0, f64::max);
    let replica_steps: f64 = if autoscaled || !cluster.failures.is_empty() {
        // seconds each replica was held: spawn → retirement (drain
        // included) for retired replicas, spawn → end-of-run otherwise
        per_replica
            .iter()
            .enumerate()
            .map(|(i, o)| {
                let end = match retired_at[i] {
                    Some(t) => t.max(o.virtual_duration),
                    None => virtual_duration,
                };
                (end - spawned_at[i]).max(0.0)
            })
            .sum()
    } else {
        // a fixed fleet holds every replica for the whole run
        init as f64 * virtual_duration
    };
    ClusterOutput {
        records,
        outcomes,
        per_replica,
        assignments,
        virtual_duration,
        scale_events,
        replica_steps,
        router_memo: dispatcher.probe_memo_counters(),
    }
}

/// Serve `trace` on `cluster.replicas` instances of `system` behind the
/// configured router.  With `cluster.autoscale.enabled`, the fleet is
/// dynamic: spawned replicas join the live run with inherited hardware
/// specs and seed derivation; retired replicas stop receiving traffic
/// (their prefix-affinity sessions re-home) but keep draining.  Replica
/// advances run on `cluster.sim_threads` workers — any thread count
/// yields bit-identical output (see module docs).
pub fn serve_cluster(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
    cluster: &ClusterConfig,
) -> ClusterOutput {
    let asc = &cluster.autoscale;
    let init = if asc.enabled {
        let min = asc.min_replicas.max(1);
        let max = asc.max_replicas.max(min);
        cluster.replicas.clamp(min, max)
    } else {
        cluster.replicas.max(1)
    };
    // Wedge guard that scales with the trace horizon: long-duration
    // traces must not trip the single-GPU default cap.
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let max_virtual_time = CoreOptions::default().max_virtual_time.max(4.0 * horizon);
    let ctx = FleetCtx { system, cfg, perf, gt, seed, max_virtual_time, cluster };
    let workers = cluster.effective_sim_threads();
    if workers <= 1 {
        run_dispatch(SerialFleet::new(ctx, init), cfg, perf, trace, cluster)
    } else {
        thread::scope(|s| {
            run_dispatch(ParallelFleet::new(s, workers, ctx, init), cfg, perf, trace, cluster)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        (cfg, perf, gt)
    }

    #[test]
    fn round_robin_splits_evenly_and_completes() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 12, 7);
        let ccfg =
            ClusterConfig { replicas: 3, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 1, &ccfg);
        assert_eq!(out.records.len(), 12);
        assert_eq!(out.per_replica_counts(), vec![4, 4, 4]);
        // merged records id-ordered and unique
        for (i, r) in out.records.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn state_aware_routers_complete_the_trace() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 16, 11);
        for router in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
            let ccfg = ClusterConfig { replicas: 2, router, ..Default::default() };
            let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 2, &ccfg);
            assert_eq!(out.records.len(), 16, "{}", router.label());
            let counts = out.per_replica_counts();
            // a state-aware router must not starve a replica under load
            assert!(counts.iter().all(|&c| c > 0), "{:?}", counts);
        }
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 3);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::LeastKv, ..Default::default() };
        let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        assert_eq!(a.records, b.records);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn parallel_backend_is_bit_identical_to_serial() {
        // the tentpole invariant, in-module form: the full matrix lives
        // in tests/parallel_parity.rs
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 24, 23);
        for router in RouterPolicy::all() {
            let run = |threads| {
                let ccfg = ClusterConfig {
                    replicas: 4,
                    router,
                    sim_threads: threads,
                    ..Default::default()
                };
                serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 6, &ccfg)
            };
            let serial = run(1);
            let parallel = run(4);
            assert_eq!(serial.records, parallel.records, "{}", router.label());
            assert_eq!(serial.assignments, parallel.assignments, "{}", router.label());
            assert_eq!(
                serial.virtual_duration.to_bits(),
                parallel.virtual_duration.to_bits(),
                "{}",
                router.label()
            );
        }
    }

    #[test]
    fn effective_threads_cap_at_the_fleet_bound() {
        let fixed = ClusterConfig { replicas: 3, sim_threads: 64, ..Default::default() };
        assert_eq!(fixed.effective_sim_threads(), 3);
        let serial = ClusterConfig { replicas: 8, sim_threads: 1, ..Default::default() };
        assert_eq!(serial.effective_sim_threads(), 1);
        let auto = ClusterConfig { replicas: 8, sim_threads: 0, ..Default::default() };
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert_eq!(auto.effective_sim_threads(), avail.min(8));
        let scaled = ClusterConfig {
            replicas: 1,
            sim_threads: 64,
            autoscale: AutoscaleConfig::on(1, 6),
            ..Default::default()
        };
        assert_eq!(scaled.effective_sim_threads(), 6);
    }

    #[test]
    fn replicas_cut_makespan_under_saturation() {
        let (cfg, perf, gt) = setup();
        // heavily saturating: compute-bound prefills arrive far faster
        // than one GPU can drain them
        let trace = generate_n_requests(&Dataset::azure_code(), 40.0, 40, 13);
        let one = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 1, router: RouterPolicy::RoundRobin, ..Default::default() },
        );
        let four = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 4, router: RouterPolicy::LeastKv, ..Default::default() },
        );
        assert_eq!(four.records.len(), 40);
        assert!(
            four.virtual_duration < one.virtual_duration * 0.55,
            "1 replica {}s vs 4 replicas {}s",
            one.virtual_duration,
            four.virtual_duration
        );
    }

    #[test]
    fn prefix_affinity_pins_sessions_and_earns_hits() {
        use crate::workload::{generate_sessions, SessionProfile};
        let cfg = ServingConfig { prefix_cache: true, ..ServingConfig::default() };
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        let trace = generate_sessions(&SessionProfile::conversational(), 1.5, 12, 19);
        let run = |router| {
            serve_cluster(
                System::Bullet,
                &cfg,
                &perf,
                &gt,
                &trace,
                4,
                &ClusterConfig { replicas: 3, router, ..Default::default() },
            )
        };
        let aff = run(RouterPolicy::PrefixAffinity);
        assert_eq!(aff.records.len(), trace.len());
        // stickiness: every turn of a session lands on one replica
        let mut session_replica = std::collections::BTreeMap::new();
        for (r, &(id, k)) in trace.iter().zip(&aff.assignments) {
            assert_eq!(r.id, id);
            let sid = r.session_id.unwrap();
            assert_eq!(*session_replica.entry(sid).or_insert(k), k, "session {sid} split");
        }
        // and that locality converts later turns into prefix hits
        let s = aff.prefix_stats();
        assert!(s.hits > 0, "affinity routing must earn hits: {s:?}");
        // round-robin scatters turns across private caches — it cannot
        // beat stickiness on hit rate
        let rr = run(RouterPolicy::RoundRobin);
        assert!(
            s.hit_rate() >= rr.prefix_stats().hit_rate(),
            "affinity {:.2} < round-robin {:.2}",
            s.hit_rate(),
            rr.prefix_stats().hit_rate()
        );
    }

    #[test]
    fn heterogeneous_replicas_calibrate_apart() {
        use crate::config::CalibrationConfig;
        // Replica 1 is a half-speed device; the shared offline model is
        // profiled for the full-speed one.  Per-replica calibration must
        // learn the difference: replica 1's slowdown diverges from
        // replica 0's.
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.5,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.5,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::RoundRobin,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
            ..Default::default()
        };
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 21);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 20);
        let sd = out.calibrated_slowdowns();
        assert!(
            sd[1] > sd[0] * 1.3,
            "half-speed replica must learn a ~2x larger slowdown: {sd:?}"
        );
        let cs = out.calibration_stats();
        assert!(cs.samples > 0);
    }

    #[test]
    fn slo_slack_router_sheds_load_off_the_slow_replica() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.4,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.4,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::SloSlack,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
            ..Default::default()
        };
        let trace = generate_n_requests(&Dataset::azure_code(), 10.0, 30, 5);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 7, &ccfg);
        assert_eq!(out.records.len(), 30);
        let counts = out.per_replica_counts();
        assert!(
            counts[1] < counts[0],
            "router must shed load off the slow replica: {counts:?}"
        );
    }

    #[test]
    fn cluster_scales_chunked_systems_too() {
        // the whole point of the shared core: baselines scale out with
        // zero engine changes.
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 17);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Sglang1024, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 10);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        assert!(s.throughput_tok_s > 0.0);
    }

    #[test]
    fn replica_crash_rehomes_traffic_and_accounts_every_request() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 24, 31);
        let mid = trace[trace.len() / 2].arrival;
        let ccfg = ClusterConfig {
            replicas: 3,
            router: RouterPolicy::LeastKv,
            failures: vec![FailureSpec { replica: 0, at: mid }],
            ..Default::default()
        };
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 9, &ccfg);
        // the crash is a timeline event on the dead replica
        assert!(out
            .scale_events
            .iter()
            .any(|e| e.action == ScaleAction::Crash && e.replica == 0));
        // no traffic routes to the corpse after the crash instant: the
        // crash fires before routing at its horizon, so every assignment
        // to replica 0 predates it
        for &(id, k) in &out.assignments {
            if k == 0 {
                let r = trace.iter().find(|r| r.id == id).unwrap();
                assert!(r.arrival <= mid, "request {id} routed to dead replica");
            }
        }
        // every submitted request ends exactly once: completed, or a
        // terminal outcome (lost in the crash)
        let stats = out.lifecycle_stats();
        assert_eq!(stats.submitted(), trace.len());
        assert_eq!(stats.cancelled, 0);
        assert_eq!(stats.expired, 0);
        // the crashed replica stops accruing replica-steps at the crash
        assert!(
            out.replica_steps < 3.0 * out.virtual_duration,
            "steps {} vs 3x makespan {}",
            out.replica_steps,
            3.0 * out.virtual_duration
        );
    }

    #[test]
    fn crash_injection_is_thread_count_invariant() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 20, 37);
        let mid = trace[trace.len() / 3].arrival;
        let run = |threads| {
            let ccfg = ClusterConfig {
                replicas: 3,
                router: RouterPolicy::PrefixAffinity,
                sim_threads: threads,
                failures: vec![FailureSpec { replica: 1, at: mid }],
                ..Default::default()
            };
            serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 12, &ccfg)
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(serial.records, parallel.records);
        assert_eq!(serial.outcomes, parallel.outcomes);
        assert_eq!(serial.assignments, parallel.assignments);
        assert_eq!(
            serial.virtual_duration.to_bits(),
            parallel.virtual_duration.to_bits()
        );
        assert_eq!(serial.lifecycle_stats().submitted(), trace.len());
    }
}
