//! Multi-replica scale-out: N independent simulated-GPU engine instances
//! behind a dispatcher.
//!
//! Each [`Replica`] is one [`EngineCore`] plus one boxed
//! [`ServingPolicy`] — the same pairing as single-GPU serving, which is
//! the point: once every system is a policy over the shared core, the
//! cluster layer can scale *any* of them (Bullet, chunked, NanoFlow,
//! MuxServe-style fixed quotas) without touching engine code.
//!
//! Co-simulation model: replicas share the global virtual timeline.  The
//! dispatcher walks the trace in arrival order; before routing a request
//! it advances every replica's clock to the arrival instant
//! ([`EngineCore::run_until`]), so state-aware routers (least-kv,
//! slo-slack) observe live queue depths, KV pressure and backlogs — not
//! a static pre-partition of the trace.  A replica mid-kernel may
//! overshoot the instant by one completion; routing signals are
//! heuristics, so this bounded skew is acceptable and keeps the replicas
//! lock-step-free.  Determinism: replica seeds derive from the run seed,
//! and the dispatcher is a pure function of replica state.

pub mod autoscale;
pub mod router;

pub use autoscale::{AutoscaleConfig, Autoscaler, ReplicaHealth, ScaleDecision};
pub use router::{Dispatcher, RouterPolicy};

use crate::baselines::System;
use crate::config::{derive_kv_capacity, DriftSpec, GpuSpec, ServingConfig};
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::kvcache::prefix::PrefixStats;
use crate::metrics::timeline::ScaleEvent;
use crate::metrics::{merge_records, RequestRecord};
use crate::perf::{CalibrationStats, PerfModel, PerfPredictor};
use crate::sched::policy::service_capacity_tokens_per_s;
use crate::workload::Request;

/// Per-replica hardware overrides for a heterogeneous fleet.  `None`
/// fields inherit the cluster-wide config / ground truth, so an
/// all-default spec is exactly a homogeneous replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaSpec {
    /// This replica's GPU (KV capacity is re-derived from it).
    pub gpu: Option<GpuSpec>,
    /// This replica's drift regime (throttling, co-tenant, lottery).
    pub drift: Option<DriftSpec>,
}

/// Cluster shape: replica count + routing policy (+ optional
/// heterogeneous per-replica hardware, + the optional autoscaler).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub replicas: usize,
    pub router: RouterPolicy,
    /// Entry `i` overrides replica `i`'s hardware; replicas beyond the
    /// list (or an empty list — the default) are homogeneous.  A shared
    /// offline perf model is wrong for such a fleet by construction;
    /// per-replica online calibration (`ServingConfig::calibration`) is
    /// how routing signals stay truthful.  Autoscaler-spawned replicas
    /// inherit entry `i` for their id too (ids past the list get the
    /// cluster default — the "inherited `GpuSpec`" of a scale-out).
    pub replica_specs: Vec<ReplicaSpec>,
    /// Calibration-driven fleet control (disabled by default: the
    /// fixed-fleet dispatch path runs bit-identically to pre-autoscaler
    /// behavior).  With `enabled`, `replicas` (clamped into
    /// `[min_replicas, max_replicas]`) is the starting fleet.
    pub autoscale: AutoscaleConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            replica_specs: Vec::new(),
            autoscale: AutoscaleConfig::off(),
        }
    }
}

/// One simulated GPU running one serving policy.
pub struct Replica {
    pub id: usize,
    core: EngineCore,
    policy: Box<dyn ServingPolicy>,
}

impl Replica {
    pub fn new(
        id: usize,
        system: System,
        cfg: &ServingConfig,
        perf: &PerfModel,
        gt: &GroundTruth,
        seed: u64,
        max_virtual_time: f64,
    ) -> Replica {
        let opts = CoreOptions {
            seed,
            max_virtual_time,
            ..CoreOptions::default()
        };
        Replica {
            id,
            core: EngineCore::new(cfg.clone(), gt.clone(), Vec::new(), &opts),
            policy: system.policy(cfg, perf),
        }
    }

    pub fn label(&self) -> String {
        self.policy.label()
    }

    pub fn now(&self) -> f64 {
        self.core.now()
    }

    /// Requests routed to this replica so far.
    pub fn assigned(&self) -> usize {
        self.core.trace_len()
    }

    /// Routing signal: KV tokens reserved + queued reservations.
    pub fn outstanding_kv_tokens(&self) -> usize {
        self.core.outstanding_kv_tokens()
    }

    /// Routing signal: prompt tokens awaiting prefill (queue + active
    /// batch remainder).
    pub fn backlog_tokens(&self) -> usize {
        self.core.queued_prefill_tokens() + self.policy.private_backlog_tokens()
    }

    pub fn decode_batch(&self) -> usize {
        self.core.decode.len()
    }

    /// Estimated TTFT were `req` routed here now: the prefill backlog
    /// plus the request's own prompt, at the estimator's per-token rate
    /// (contended if a decode batch is resident), scaled by the
    /// replica's learned slowdown — so on a heterogeneous or drifting
    /// fleet the slo-slack router ranks replicas by their *calibrated*
    /// speed, not the shared offline grid.  The slowdown (not a cell
    /// lookup at this probe's shape) is used deliberately: calibration
    /// cells are shape-local and the fixed probe shape may never have
    /// been launched, while the slowdown aggregates every observed
    /// cell.  Exactly 1.0 for calibration-free or unobserved replicas.
    pub fn estimated_ttft(&self, req: &Request, perf: &PerfModel) -> f64 {
        let cfg = &self.core.cfg;
        let contended = !self.core.decode.is_empty();
        let reference = 2048usize;
        let per_token =
            perf.predict_prefill_layer(reference, 0, cfg.gpu.num_sms, contended) / reference as f64;
        let tokens = (self.backlog_tokens() + req.input_len) as f64;
        tokens * per_token * cfg.model.n_layers as f64 * self.calibrated_slowdown()
    }

    /// The replica's learned observed/nominal slowdown (1.0 until its
    /// calibrator has samples, or for calibration-free policies).
    pub fn calibrated_slowdown(&self) -> f64 {
        self.policy
            .predictor()
            .map(|p| p.calibrated_slowdown())
            .unwrap_or(1.0)
    }

    /// The replica's live calibration counters (identity for
    /// calibration-free policies) — the autoscaler's health snapshot.
    pub fn calibration(&self) -> CalibrationStats {
        self.policy
            .predictor()
            .map(|p| p.calibration())
            .unwrap_or_default()
    }

    /// Refresh this replica's offline perf grid in place (autoscaler
    /// re-profiling action).  Calibration-free policies decline.
    pub fn reprofile(&mut self) -> bool {
        self.policy.reprofile()
    }

    fn advance_to(&mut self, t: f64) {
        self.core.run_until(self.policy.as_mut(), t);
    }

    fn push(&mut self, r: Request) {
        self.core.push_request(r);
    }

    fn finish(mut self) -> EngineOutput {
        self.core.run(self.policy.as_mut());
        self.core.into_output()
    }
}

/// Everything a cluster run produces.
#[derive(Debug, Clone)]
pub struct ClusterOutput {
    /// All records, id-ordered (directly comparable with single-GPU runs).
    pub records: Vec<RequestRecord>,
    /// Per-replica engine outputs (replica index = vec index; with
    /// autoscaling, every replica ever spawned — retired ones included).
    pub per_replica: Vec<EngineOutput>,
    /// (request id, replica index) routing decisions, in arrival order.
    pub assignments: Vec<(u64, usize)>,
    /// Global makespan: the latest replica finish time.
    pub virtual_duration: f64,
    /// Autoscaler decisions on the global timeline (empty with the
    /// autoscaler off).  Each also rides the targeted replica's
    /// `EngineOutput::scale_events` / timeline.
    pub scale_events: Vec<ScaleEvent>,
    /// Replica-steps consumed: Σ over replicas of seconds held (spawn →
    /// retirement-or-end-of-run, drain included).  A fixed fleet spends
    /// `replicas × virtual_duration`; the autoscaler's provisioning bar
    /// is beating `max_replicas × virtual_duration` while also beating
    /// the fixed fleet's latency.
    pub replica_steps: f64,
}

impl ClusterOutput {
    /// Requests routed to each replica.
    pub fn per_replica_counts(&self) -> Vec<usize> {
        let n = self.per_replica.len();
        let mut counts = vec![0usize; n];
        for &(_, k) in &self.assignments {
            counts[k] += 1;
        }
        counts
    }

    /// Cluster-wide prefix-cache counters (summed over replicas; all
    /// zero with the cache off).  Replica caches are private, so the
    /// aggregate hit rate is what the routing policy actually earned.
    pub fn prefix_stats(&self) -> PrefixStats {
        let mut total = PrefixStats::default();
        for o in &self.per_replica {
            total.merge(&o.prefix);
        }
        total
    }

    /// Cluster-wide calibration counters (sample-weighted merge).
    pub fn calibration_stats(&self) -> CalibrationStats {
        let mut total = CalibrationStats::default();
        for o in &self.per_replica {
            total.merge(&o.calibration);
        }
        total
    }

    /// Each replica's learned slowdown — the heterogeneity fingerprint
    /// (all 1.0 with calibration off).
    pub fn calibrated_slowdowns(&self) -> Vec<f64> {
        self.per_replica.iter().map(|o| o.calibration.slowdown).collect()
    }
}

/// Everything replica construction needs — shared by the fixed-fleet
/// path and the autoscaler's spawn action, so a scaled-out replica is
/// constructed exactly like a boot-time one.
struct FleetCtx<'a> {
    system: System,
    cfg: &'a ServingConfig,
    perf: &'a PerfModel,
    gt: &'a GroundTruth,
    seed: u64,
    max_virtual_time: f64,
    cluster: &'a ClusterConfig,
}

impl FleetCtx<'_> {
    /// Build replica `i` with its derived seed and (optional)
    /// per-replica hardware spec.
    fn build_replica(&self, i: usize) -> Replica {
        let (system, cfg, perf, gt) = (self.system, self.cfg, self.perf, self.gt);
        // distinct per-replica seeds decorrelate simulator noise
        // (and draw distinct device-lottery factors under drift)
        let rseed = self
            .seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1));
        // heterogeneous fleet: apply this replica's hardware spec
        match self.cluster.replica_specs.get(i) {
            None => Replica::new(i, system, cfg, perf, gt, rseed, self.max_virtual_time),
            Some(spec) => {
                let mut rcfg = cfg.clone();
                let mut rgt = gt.clone();
                if let Some(gpu) = &spec.gpu {
                    // re-derive KV capacity for the new device ONLY
                    // when the operator left it at the derived
                    // default — an explicitly pinned capacity (e.g.
                    // a KV-tight experiment) must survive per-
                    // replica compute overrides
                    let was_derived =
                        rcfg.kv_capacity_tokens == derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                    rcfg.gpu = gpu.clone();
                    if was_derived {
                        rcfg.kv_capacity_tokens = derive_kv_capacity(&rcfg.gpu, &rcfg.model);
                    }
                    rgt.gpu = gpu.clone();
                }
                if let Some(drift) = &spec.drift {
                    rgt.drift = drift.clone();
                }
                Replica::new(i, system, &rcfg, perf, &rgt, rseed, self.max_virtual_time)
            }
        }
    }
}

/// Serve `trace` on `cluster.replicas` instances of `system` behind the
/// configured router.  With `cluster.autoscale.enabled`, the fleet is
/// dynamic: see [`serve_cluster_autoscaled`].
pub fn serve_cluster(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
    cluster: &ClusterConfig,
) -> ClusterOutput {
    if cluster.autoscale.enabled {
        return serve_cluster_autoscaled(system, cfg, perf, gt, trace, seed, cluster);
    }
    let n = cluster.replicas.max(1);
    // Wedge guard that scales with the trace horizon: long-duration
    // traces must not trip the single-GPU default cap.
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let max_virtual_time = CoreOptions::default().max_virtual_time.max(4.0 * horizon);
    let ctx = FleetCtx { system, cfg, perf, gt, seed, max_virtual_time, cluster };
    let mut replicas: Vec<Replica> = (0..n).map(|i| ctx.build_replica(i)).collect();
    let mut dispatcher = Dispatcher::new(cluster.router);
    let mut assignments = Vec::with_capacity(trace.len());

    for r in trace {
        for rep in replicas.iter_mut() {
            rep.advance_to(r.arrival);
        }
        let k = dispatcher.pick(&replicas, r, perf, &cfg.slo);
        assignments.push((r.id, k));
        replicas[k].push(r.clone());
    }

    let per_replica: Vec<EngineOutput> = replicas.into_iter().map(Replica::finish).collect();
    let records = merge_records(per_replica.iter().map(|o| o.records.as_slice()));
    let virtual_duration = per_replica
        .iter()
        .map(|o| o.virtual_duration)
        .fold(0.0, f64::max);
    ClusterOutput {
        records,
        per_replica,
        assignments,
        virtual_duration,
        scale_events: Vec::new(),
        // a fixed fleet holds every replica for the whole run
        replica_steps: n as f64 * virtual_duration,
    }
}

/// The dynamic-fleet dispatch loop: identical co-simulation to the
/// fixed path, plus one [`Autoscaler`] evaluation per control interval.
/// Spawned replicas join the live run with inherited hardware specs and
/// seed derivation; retired replicas stop receiving traffic (their
/// prefix-affinity sessions re-home) but keep draining to completion.
fn serve_cluster_autoscaled(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
    cluster: &ClusterConfig,
) -> ClusterOutput {
    let asc = &cluster.autoscale;
    let min = asc.min_replicas.max(1);
    let max = asc.max_replicas.max(min);
    let init = cluster.replicas.clamp(min, max);
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let max_virtual_time = CoreOptions::default().max_virtual_time.max(4.0 * horizon);
    let ctx = FleetCtx { system, cfg, perf, gt, seed, max_virtual_time, cluster };
    let mut replicas: Vec<Replica> = (0..init).map(|i| ctx.build_replica(i)).collect();
    let mut spawned_at: Vec<f64> = vec![0.0; init];
    let mut retired_at: Vec<Option<f64>> = vec![None; init];
    let mut dispatcher = Dispatcher::new(cluster.router);
    let mut scaler = Autoscaler::new(asc.clone());
    let mut scale_events: Vec<ScaleEvent> = Vec::new();
    let mut assignments = Vec::with_capacity(trace.len());

    for r in trace {
        // co-advance EVERY replica — retired ones keep draining
        for rep in replicas.iter_mut() {
            rep.advance_to(r.arrival);
        }
        scaler.note_arrival(r.arrival, r.input_len, r.output_len);

        // health snapshots and capacity pricing only when a control
        // evaluation will actually run (evaluate re-checks the gate)
        let decision = if scaler.due(r.arrival) {
            let fleet: Vec<ReplicaHealth> = replicas
                .iter()
                .enumerate()
                .filter(|(i, _)| retired_at[*i].is_none())
                .map(|(i, rep)| ReplicaHealth {
                    id: i,
                    slowdown: rep.calibrated_slowdown(),
                    calib: rep.calibration(),
                })
                .collect();
            let nominal = service_capacity_tokens_per_s(perf, cfg, scaler.prefill_frac());
            scaler.evaluate(r.arrival, nominal, &fleet)
        } else {
            None
        };
        if let Some(decision) = decision {
            let target = match decision {
                ScaleDecision::ScaleOut => {
                    let id = replicas.len();
                    replicas.push(ctx.build_replica(id));
                    spawned_at.push(r.arrival);
                    retired_at.push(None);
                    id
                }
                ScaleDecision::ScaleIn(id) | ScaleDecision::Retire(id) => {
                    retired_at[id] = Some(r.arrival);
                    // sessions pinned here must re-home on their next turn
                    dispatcher.unpin_replica(id);
                    id
                }
                ScaleDecision::Reprofile(id) => {
                    replicas[id].reprofile();
                    id
                }
            };
            let fleet_after = retired_at.iter().filter(|t| t.is_none()).count();
            scale_events.push(ScaleEvent {
                t: r.arrival,
                action: decision.action(),
                replica: target,
                fleet_after,
            });
        }

        let eligible: Vec<usize> = (0..replicas.len())
            .filter(|&i| retired_at[i].is_none())
            .collect();
        let k = dispatcher.pick_among(&replicas, &eligible, r, perf, &cfg.slo);
        assignments.push((r.id, k));
        replicas[k].push(r.clone());
    }

    let mut per_replica: Vec<EngineOutput> = replicas.into_iter().map(Replica::finish).collect();
    // lifecycle events ride the targeted replica's own output/timeline
    for ev in &scale_events {
        per_replica[ev.replica].scale_events.push(*ev);
        per_replica[ev.replica].timeline.push_event(*ev);
    }
    let records = merge_records(per_replica.iter().map(|o| o.records.as_slice()));
    let virtual_duration = per_replica
        .iter()
        .map(|o| o.virtual_duration)
        .fold(0.0, f64::max);
    // seconds each replica was held: spawn → retirement (drain included)
    // for retired replicas, spawn → end-of-run for surviving ones
    let replica_steps: f64 = per_replica
        .iter()
        .enumerate()
        .map(|(i, o)| {
            let end = match retired_at[i] {
                Some(t) => t.max(o.virtual_duration),
                None => virtual_duration,
            };
            (end - spawned_at[i]).max(0.0)
        })
        .sum();
    ClusterOutput {
        records,
        per_replica,
        assignments,
        virtual_duration,
        scale_events,
        replica_steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        (cfg, perf, gt)
    }

    #[test]
    fn round_robin_splits_evenly_and_completes() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 12, 7);
        let ccfg =
            ClusterConfig { replicas: 3, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 1, &ccfg);
        assert_eq!(out.records.len(), 12);
        assert_eq!(out.per_replica_counts(), vec![4, 4, 4]);
        // merged records id-ordered and unique
        for (i, r) in out.records.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn state_aware_routers_complete_the_trace() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 12.0, 16, 11);
        for router in [RouterPolicy::LeastKv, RouterPolicy::SloSlack] {
            let ccfg = ClusterConfig { replicas: 2, router, ..Default::default() };
            let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 2, &ccfg);
            assert_eq!(out.records.len(), 16, "{}", router.label());
            let counts = out.per_replica_counts();
            // a state-aware router must not starve a replica under load
            assert!(counts.iter().all(|&c| c > 0), "{:?}", counts);
        }
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 3);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::LeastKv, ..Default::default() };
        let a = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        let b = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 5, &ccfg);
        assert_eq!(a.records, b.records);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn replicas_cut_makespan_under_saturation() {
        let (cfg, perf, gt) = setup();
        // heavily saturating: compute-bound prefills arrive far faster
        // than one GPU can drain them
        let trace = generate_n_requests(&Dataset::azure_code(), 40.0, 40, 13);
        let one = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 1, router: RouterPolicy::RoundRobin, ..Default::default() },
        );
        let four = serve_cluster(
            System::Bullet, &cfg, &perf, &gt, &trace, 1,
            &ClusterConfig { replicas: 4, router: RouterPolicy::LeastKv, ..Default::default() },
        );
        assert_eq!(four.records.len(), 40);
        assert!(
            four.virtual_duration < one.virtual_duration * 0.55,
            "1 replica {}s vs 4 replicas {}s",
            one.virtual_duration,
            four.virtual_duration
        );
    }

    #[test]
    fn prefix_affinity_pins_sessions_and_earns_hits() {
        use crate::workload::{generate_sessions, SessionProfile};
        let cfg = ServingConfig { prefix_cache: true, ..ServingConfig::default() };
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        let trace = generate_sessions(&SessionProfile::conversational(), 1.5, 12, 19);
        let run = |router| {
            serve_cluster(
                System::Bullet,
                &cfg,
                &perf,
                &gt,
                &trace,
                4,
                &ClusterConfig { replicas: 3, router, ..Default::default() },
            )
        };
        let aff = run(RouterPolicy::PrefixAffinity);
        assert_eq!(aff.records.len(), trace.len());
        // stickiness: every turn of a session lands on one replica
        let mut session_replica = std::collections::BTreeMap::new();
        for (r, &(id, k)) in trace.iter().zip(&aff.assignments) {
            assert_eq!(r.id, id);
            let sid = r.session_id.unwrap();
            assert_eq!(*session_replica.entry(sid).or_insert(k), k, "session {sid} split");
        }
        // and that locality converts later turns into prefix hits
        let s = aff.prefix_stats();
        assert!(s.hits > 0, "affinity routing must earn hits: {s:?}");
        // round-robin scatters turns across private caches — it cannot
        // beat stickiness on hit rate
        let rr = run(RouterPolicy::RoundRobin);
        assert!(
            s.hit_rate() >= rr.prefix_stats().hit_rate(),
            "affinity {:.2} < round-robin {:.2}",
            s.hit_rate(),
            rr.prefix_stats().hit_rate()
        );
    }

    #[test]
    fn heterogeneous_replicas_calibrate_apart() {
        use crate::config::CalibrationConfig;
        // Replica 1 is a half-speed device; the shared offline model is
        // profiled for the full-speed one.  Per-replica calibration must
        // learn the difference: replica 1's slowdown diverges from
        // replica 0's.
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.5,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.5,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::RoundRobin,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
            ..Default::default()
        };
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 21);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 20);
        let sd = out.calibrated_slowdowns();
        assert!(
            sd[1] > sd[0] * 1.3,
            "half-speed replica must learn a ~2x larger slowdown: {sd:?}"
        );
        let cs = out.calibration_stats();
        assert!(cs.samples > 0);
    }

    #[test]
    fn slo_slack_router_sheds_load_off_the_slow_replica() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let slow_gpu = GpuSpec {
            peak_flops: GpuSpec::a100().peak_flops * 0.4,
            peak_bandwidth: GpuSpec::a100().peak_bandwidth * 0.4,
            ..GpuSpec::a100()
        };
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::SloSlack,
            replica_specs: vec![
                ReplicaSpec::default(),
                ReplicaSpec { gpu: Some(slow_gpu), drift: None },
            ],
            ..Default::default()
        };
        let trace = generate_n_requests(&Dataset::azure_code(), 10.0, 30, 5);
        let out = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 7, &ccfg);
        assert_eq!(out.records.len(), 30);
        let counts = out.per_replica_counts();
        assert!(
            counts[1] < counts[0],
            "router must shed load off the slow replica: {counts:?}"
        );
    }

    #[test]
    fn cluster_scales_chunked_systems_too() {
        // the whole point of the shared core: baselines scale out with
        // zero engine changes.
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 10, 17);
        let ccfg =
            ClusterConfig { replicas: 2, router: RouterPolicy::RoundRobin, ..Default::default() };
        let out = serve_cluster(System::Sglang1024, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(out.records.len(), 10);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        assert!(s.throughput_tok_s > 0.0);
    }
}
