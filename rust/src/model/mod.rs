//! Analytical transformer cost model: turns (phase, shape) into the
//! kernel descriptors the GPU simulator executes.

pub mod phases;

pub use phases::{decode_layer_kernels, prefill_layer_kernels, LayerCosts, PhaseShape};
