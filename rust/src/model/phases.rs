//! Per-operator FLOP/byte/grid accounting for prefill and decode.
//!
//! Grid sizes follow the tiling heuristics of vendor GEMM libraries
//! (128×128 output tiles) and FlashAttention (one thread block per
//! (head, 128-query block)); with these, Eq. 1 reproduces the paper's
//! Table 1 — e.g. QKV @ sl=1024 → 384 blocks → 11.1% idle on 108 SMs,
//! and Attn @ sl=1024 → 256 blocks → 21.0%.

use crate::config::ModelSpec;
use crate::gpu::kernel::{KernelDesc, OpClass};

/// GEMM output-tile edge used by the grid heuristic.
pub const GEMM_TILE: usize = 128;
/// FlashAttention query-block rows per thread block.
pub const ATTN_BLOCK_Q: usize = 128;
/// Decode-GEMM rows per thread block (skinny tiles).
pub const DECODE_TILE_M: usize = 16;

/// Shape of one phase step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseShape {
    /// Prefill: new tokens in this chunk. Decode: batch size.
    pub tokens: usize,
    /// Context tokens already cached (per sequence, average).
    pub context: usize,
}

/// Aggregated per-layer costs (for reporting).
#[derive(Debug, Clone, Default)]
pub struct LayerCosts {
    pub kernels: Vec<KernelDesc>,
}

impl LayerCosts {
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    pub fn total_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.bytes).sum()
    }
}

fn gemm_grid(m: usize, n: usize) -> usize {
    m.div_ceil(GEMM_TILE) * n.div_ceil(GEMM_TILE)
}

fn gemm_kernel(op: OpClass, m: usize, k: usize, n: usize, dtype: usize, grid: usize) -> KernelDesc {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    // weights + input + output
    let bytes = (k * n + m * k + m * n) as f64 * dtype as f64;
    KernelDesc::new(op, flops, bytes, grid)
}

/// Kernels of ONE transformer layer processing a prefill chunk.
///
/// `shape.tokens` = chunk size (the whole prompt for unchunked prefill),
/// `shape.context` = tokens already prefilled in earlier chunks — whose
/// cached K/V must be RELOADED for the attention of this chunk (§2.3.1's
/// N(N+1)/2 reload cost emerges from summing this over chunks).
pub fn prefill_layer_kernels(model: &ModelSpec, shape: PhaseShape) -> Vec<KernelDesc> {
    let n = shape.tokens;
    let ctx = shape.context;
    let d = model.d_model;
    let q_dim = model.n_heads * model.head_dim;
    let kv_dim = model.n_kv_heads * model.head_dim;
    let dt = model.dtype_bytes;
    let mut out = Vec::with_capacity(5);

    // QKV projection: [n, d] x [d, q+2kv]
    let qkv_n = q_dim + 2 * kv_dim;
    out.push(gemm_kernel(
        OpClass::GemmQkv,
        n,
        d,
        qkv_n,
        dt,
        gemm_grid(n, qkv_n),
    ));

    // Attention: each of the n new queries attends to ctx + (causal) half
    // of the chunk itself. flops = 4 * n * kv_len_avg * (heads*hd)
    let kv_len_avg = ctx as f64 + (n as f64 + 1.0) / 2.0;
    let attn_flops = 4.0 * n as f64 * kv_len_avg * q_dim as f64;
    // bytes: read Q once, K/V for ctx+n tokens (the ctx part is the
    // chunked-prefill reload), write O.
    let kv_token_bytes = (2 * kv_dim * dt) as f64; // K+V per token per layer
    let attn_bytes = (2 * n * q_dim * dt) as f64 + (ctx + n) as f64 * kv_token_bytes;
    let attn_grid = model.n_heads * n.div_ceil(ATTN_BLOCK_Q);
    out.push(KernelDesc::new(
        OpClass::AttnPrefill,
        attn_flops,
        attn_bytes,
        attn_grid,
    ));

    // Output projection: [n, q_dim] x [q_dim, d].  Vendor libraries pick
    // wider output tiles for skinny-M problems (fewer, fatter blocks) —
    // that heuristic is exactly what makes OProj's wave quantization so
    // bad at short sequences (paper: 40.7% idle @ sl=1024).
    let oproj_tile_n = if n <= 1024 { 512 } else { 256 };
    let oproj_grid = n.div_ceil(GEMM_TILE) * d.div_ceil(oproj_tile_n);
    out.push(gemm_kernel(OpClass::GemmOProj, n, q_dim, d, dt, oproj_grid));

    // MLP: two kernels — the fused gate+up GEMM ([n,d]x[d,ffn] twice)
    // and the down GEMM ([n,ffn]x[ffn,d]).  Wave quantization applies
    // per GEMM, so they must not be merged into one grid.
    let ffn = model.ffn_dim;
    let gateup_flops = 2.0 * n as f64 * d as f64 * ffn as f64 * 2.0;
    let gateup_bytes = (2 * d * ffn + n * d + 2 * n * ffn) as f64 * dt as f64;
    out.push(KernelDesc::new(
        OpClass::GemmMlp,
        gateup_flops,
        gateup_bytes,
        gemm_grid(n, ffn),
    ));
    let down_flops = 2.0 * n as f64 * d as f64 * ffn as f64;
    let down_bytes = (d * ffn + n * ffn + n * d) as f64 * dt as f64;
    out.push(KernelDesc::new(
        OpClass::GemmMlp,
        down_flops,
        down_bytes,
        gemm_grid(n, d),
    ));

    // Elementwise (norms, rope, residuals): bandwidth only.
    let ew_bytes = (8 * n * d * dt) as f64;
    out.push(KernelDesc::new(
        OpClass::Elementwise,
        (2 * n * d) as f64,
        ew_bytes,
        n.div_ceil(4).max(1),
    ));

    out
}

/// Kernels of ONE transformer layer for a decode step.
///
/// `shape.tokens` = decode batch size, `shape.context` = average context
/// length per sequence (the KV sweep dominates bytes).
pub fn decode_layer_kernels(model: &ModelSpec, shape: PhaseShape) -> Vec<KernelDesc> {
    let bs = shape.tokens;
    let cl = shape.context;
    let d = model.d_model;
    let q_dim = model.n_heads * model.head_dim;
    let kv_dim = model.n_kv_heads * model.head_dim;
    let dt = model.dtype_bytes;
    let mut out = Vec::with_capacity(5);

    let skinny_grid = |n: usize| bs.div_ceil(DECODE_TILE_M) * n.div_ceil(GEMM_TILE);

    // QKV projection (weight-streaming bound at small batch).
    let qkv_n = q_dim + 2 * kv_dim;
    out.push(gemm_kernel(
        OpClass::GemmDecode,
        bs,
        d,
        qkv_n,
        dt,
        skinny_grid(qkv_n),
    ));

    // Decode attention: each sequence sweeps its own KV cache.
    let attn_flops = 4.0 * bs as f64 * cl as f64 * q_dim as f64;
    let kv_token_bytes = (2 * kv_dim * dt) as f64;
    let attn_bytes = bs as f64 * cl as f64 * kv_token_bytes + (2 * bs * q_dim * dt) as f64;
    // one block per (sequence, kv head) — paged attention style
    let attn_grid = (bs * model.n_kv_heads).max(1);
    out.push(KernelDesc::new(
        OpClass::AttnDecode,
        attn_flops,
        attn_bytes.max(1.0),
        attn_grid,
    ));

    // Output projection.
    out.push(gemm_kernel(
        OpClass::GemmDecode,
        bs,
        q_dim,
        d,
        dt,
        skinny_grid(d),
    ));

    // MLP.
    let ffn = model.ffn_dim;
    let mlp_flops = 2.0 * bs as f64 * d as f64 * ffn as f64 * 3.0;
    let mlp_bytes = (3 * d * ffn + 2 * bs * d + 3 * bs * ffn) as f64 * dt as f64;
    out.push(KernelDesc::new(
        OpClass::GemmDecode,
        mlp_flops,
        mlp_bytes,
        2 * skinny_grid(ffn) + skinny_grid(d),
    ));

    // Elementwise.
    out.push(KernelDesc::new(
        OpClass::Elementwise,
        (2 * bs * d) as f64,
        (8 * bs * d * dt) as f64,
        bs.div_ceil(4).max(1),
    ));

    out
}

/// All layers of a prefill chunk, flattened in execution order, each
/// kernel tagged with its layer index.
pub fn prefill_all_layers(model: &ModelSpec, shape: PhaseShape) -> Vec<KernelDesc> {
    (0..model.n_layers)
        .flat_map(|l| {
            prefill_layer_kernels(model, shape)
                .into_iter()
                .map(move |k| k.with_tag(l as u32))
        })
        .collect()
}

/// All layers of a decode step, flattened, tagged by layer.
pub fn decode_all_layers(model: &ModelSpec, shape: PhaseShape) -> Vec<KernelDesc> {
    (0..model.n_layers)
        .flat_map(|l| {
            decode_layer_kernels(model, shape)
                .into_iter()
                .map(move |k| k.with_tag(l as u32))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::wave::wave_quantization_idle_ratio;

    fn llama() -> ModelSpec {
        ModelSpec::llama31_8b()
    }

    #[test]
    fn qkv_grid_reproduces_table1() {
        // Paper Table 1: QKV idle 11.1% @ sl=1024 and 2048, 1.9% @ 16384.
        let m = llama();
        for (sl, expect) in [(1024usize, 0.111), (2048, 0.111), (16384, 0.019)] {
            let ks = prefill_layer_kernels(&m, PhaseShape { tokens: sl, context: 0 });
            let qkv = &ks[0];
            let idle = wave_quantization_idle_ratio(qkv.grid, 108);
            assert!(
                (idle - expect).abs() < 0.02,
                "sl={sl}: idle {idle} expect {expect} (grid {})",
                qkv.grid
            );
        }
    }

    #[test]
    fn attn_grid_reproduces_table1() {
        // Paper Table 1: Attn idle 21.0% @ 1024, 5.2% @ 2048, 0.2% @ 16384.
        let m = llama();
        for (sl, expect) in [(1024usize, 0.210), (2048, 0.052), (16384, 0.002)] {
            let ks = prefill_layer_kernels(&m, PhaseShape { tokens: sl, context: 0 });
            let attn = &ks[1];
            let idle = wave_quantization_idle_ratio(attn.grid, 108);
            assert!(
                (idle - expect).abs() < 0.01,
                "sl={sl}: idle {idle} expect {expect} (grid {})",
                attn.grid
            );
        }
    }

    #[test]
    fn prefill_flops_quadratic_in_attention() {
        let m = llama();
        let k1 = prefill_layer_kernels(&m, PhaseShape { tokens: 1024, context: 0 });
        let k4 = prefill_layer_kernels(&m, PhaseShape { tokens: 4096, context: 0 });
        let ratio = k4[1].flops / k1[1].flops;
        assert!((ratio - 16.0).abs() / 16.0 < 0.01, "ratio {ratio}");
        // GEMMs scale linearly.
        let g = k4[0].flops / k1[0].flops;
        assert!((g - 4.0).abs() < 1e-9);
    }

    #[test]
    fn context_reload_adds_attention_bytes() {
        let m = llama();
        let no_ctx = prefill_layer_kernels(&m, PhaseShape { tokens: 1024, context: 0 });
        let with_ctx = prefill_layer_kernels(&m, PhaseShape { tokens: 1024, context: 8192 });
        let delta = with_ctx[1].bytes - no_ctx[1].bytes;
        // 8192 reloaded tokens * 2 (K+V) * kv_dim * dtype
        let expect = 8192.0 * 2.0 * (m.n_kv_heads * m.head_dim) as f64 * m.dtype_bytes as f64;
        assert!((delta - expect).abs() / expect < 1e-9);
        // flops also grow (new queries attend to the context)
        assert!(with_ctx[1].flops > no_ctx[1].flops * 5.0);
    }

    #[test]
    fn decode_attention_is_memory_dominated() {
        let m = llama();
        let ks = decode_layer_kernels(&m, PhaseShape { tokens: 32, context: 2048 });
        let attn = &ks[1];
        // intensity ~2 flops/byte — far below the A100 ridge (~150)
        assert!(attn.intensity() < 10.0, "intensity {}", attn.intensity());
    }

    #[test]
    fn decode_bytes_scale_with_context() {
        let m = llama();
        let a = decode_layer_kernels(&m, PhaseShape { tokens: 16, context: 1000 });
        let b = decode_layer_kernels(&m, PhaseShape { tokens: 16, context: 2000 });
        assert!(b[1].bytes > a[1].bytes * 1.8);
    }

    #[test]
    fn all_layers_tagged() {
        let m = llama();
        let ks = prefill_all_layers(&m, PhaseShape { tokens: 512, context: 0 });
        assert_eq!(ks.len(), 6 * m.n_layers);
        assert_eq!(ks[0].tag, 0);
        assert_eq!(ks[6].tag, 1);
        assert_eq!(ks.last().unwrap().tag, (m.n_layers - 1) as u32);
    }

    #[test]
    fn oproj_grid_reproduces_table1() {
        // Paper Table 1: OProj idle 40.7% @ 1024, 21.0% @ 2048, 5.2% @ 4096.
        let m = llama();
        for (sl, expect) in [(1024usize, 0.407), (2048, 0.210), (4096, 0.052)] {
            let ks = prefill_layer_kernels(&m, PhaseShape { tokens: sl, context: 0 });
            let idle = wave_quantization_idle_ratio(ks[2].grid, 108);
            assert!(
                (idle - expect).abs() < 0.02,
                "sl={sl}: idle {idle} expect {expect} (grid {})",
                ks[2].grid
            );
        }
    }

    #[test]
    fn weights_bytes_read_once_per_gemm() {
        // Weight bytes of QKV GEMM must not scale with tokens.
        let m = llama();
        let a = prefill_layer_kernels(&m, PhaseShape { tokens: 128, context: 0 });
        let b = prefill_layer_kernels(&m, PhaseShape { tokens: 256, context: 0 });
        let w = (m.d_model * (m.n_heads + 2 * m.n_kv_heads) * m.head_dim * m.dtype_bytes) as f64;
        assert!(a[0].bytes > w && b[0].bytes > w);
        assert!((b[0].bytes - a[0].bytes) < w * 0.1); // only activations grew
    }
}
