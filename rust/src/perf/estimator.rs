//! The profile-augmented analytical performance model (Eq. 2).

use crate::config::{GpuSpec, ModelSpec};
use crate::gpu::kernel::KernelDesc;
use crate::gpu::wave::wave_slowdown;
use crate::model::phases::{decode_layer_kernels, prefill_layer_kernels, PhaseShape};
use crate::perf::grid::{Grid2, Grid3};
use crate::perf::PerfPredictor;

/// Analytical ceilings the estimator *assumes* before profiling (Eq. 2's
/// C and B with a generic achieved-fraction guess).  Profiling ratios
/// absorb the per-class reality.
pub const ASSUMED_COMPUTE_CEIL: f64 = 0.85;
pub const ASSUMED_BANDWIDTH_CEIL: f64 = 0.85;

/// Profile-augmented model: analytical Eq. 2 times interpolated
/// measured/analytic correction ratios, plus contention factors.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    /// Correction ratio grid for a prefill layer: (sl, pm) -> ratio.
    pub prefill_ratio: Grid2,
    /// Correction for a decode step: (bs, cl, dm) -> ratio.
    pub decode_ratio: Grid3,
    /// Contention decay on co-located prefill (multiplies time, >= 1).
    pub p_c: f64,
    /// Contention decay on co-located decode (multiplies time, >= 1).
    pub p_b: f64,
}

impl PerfModel {
    /// Purely analytical model (ratios = 1, no contention): what the
    /// estimator predicts before profiling.
    pub fn analytical(gpu: GpuSpec, model: ModelSpec) -> PerfModel {
        PerfModel {
            gpu,
            model,
            prefill_ratio: Grid2::new(vec![1.0], vec![1.0], 1.0),
            decode_ratio: Grid3::new(vec![1.0], vec![1.0], vec![1.0], 1.0),
            p_c: 1.0,
            p_b: 1.0,
        }
    }

    /// Eq. 2 for one kernel on `m` SMs (linear scaling + wave term).
    pub fn analytic_kernel(&self, k: &KernelDesc, m: usize) -> f64 {
        if m == 0 {
            return f64::INFINITY;
        }
        let scale = self.gpu.num_sms as f64 / m as f64;
        let tc = k.flops / (self.gpu.peak_flops * ASSUMED_COMPUTE_CEIL) * scale
            * wave_slowdown(k.grid, m);
        // Eq. 2 scales both terms linearly in M/m; the profiled ratio
        // grids absorb the true (saturating) bandwidth curve.  A clamp
        // here would bias the scheduler into over-squeezing decode at
        // small partitions (predicting cheap TPOT that reality denies).
        let tb = k.bytes / (self.gpu.peak_bandwidth * ASSUMED_BANDWIDTH_CEIL) * scale;
        tc.max(tb) + self.gpu.launch_overhead
    }

    /// Analytical time of one prefill layer (chunk `sl` tokens over
    /// `ctx` cached tokens) on `pm` SMs.
    pub fn analytic_prefill_layer(&self, sl: usize, ctx: usize, pm: usize) -> f64 {
        prefill_layer_kernels(&self.model, PhaseShape { tokens: sl, context: ctx })
            .iter()
            .map(|k| self.analytic_kernel(k, pm))
            .sum()
    }

    /// Analytical time of one full decode step (all layers) on `dm` SMs.
    pub fn analytic_decode_step(&self, bs: usize, cl: usize, dm: usize) -> f64 {
        let per_layer: f64 = decode_layer_kernels(&self.model, PhaseShape { tokens: bs, context: cl })
            .iter()
            .map(|k| self.analytic_kernel(k, dm))
            .sum();
        per_layer * self.model.n_layers as f64
    }

    /// Predicted time of one prefill LAYER under the current partition.
    /// `contended` = a decode step co-runs.
    pub fn predict_prefill_layer(&self, sl: usize, ctx: usize, pm: usize, contended: bool) -> f64 {
        let base = self.analytic_prefill_layer(sl, ctx, pm)
            * self.prefill_ratio.interp(sl as f64, pm as f64);
        if contended {
            base * self.p_c
        } else {
            base
        }
    }

    /// Predicted remaining prefill time for `layers_left` layers.
    pub fn predict_prefill_remaining(
        &self,
        sl: usize,
        ctx: usize,
        pm: usize,
        layers_left: usize,
        contended: bool,
    ) -> f64 {
        self.predict_prefill_layer(sl, ctx, pm, contended) * layers_left as f64
    }

    /// Predicted time of one decode ITERATION (all layers, compound
    /// launch) under the current partition.
    pub fn predict_decode_step(&self, bs: usize, cl: usize, dm: usize, contended: bool) -> f64 {
        if bs == 0 {
            return 0.0;
        }
        let base = self.analytic_decode_step(bs, cl, dm)
            * self
                .decode_ratio
                .interp(bs as f64, cl as f64, dm as f64);
        if contended {
            base * self.p_b
        } else {
            base
        }
    }
}

/// The frozen offline model IS a predictor (identity wiring — the
/// inherent methods above are the implementation).
impl PerfPredictor for PerfModel {
    fn predict_prefill_layer(&self, sl: usize, ctx: usize, pm: usize, contended: bool) -> f64 {
        PerfModel::predict_prefill_layer(self, sl, ctx, pm, contended)
    }

    fn predict_decode_step(&self, bs: usize, cl: usize, dm: usize, contended: bool) -> f64 {
        PerfModel::predict_decode_step(self, bs, cl, dm, contended)
    }

    fn predict_prefill_remaining(
        &self,
        sl: usize,
        ctx: usize,
        pm: usize,
        layers_left: usize,
        contended: bool,
    ) -> f64 {
        PerfModel::predict_prefill_remaining(self, sl, ctx, pm, layers_left, contended)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn analytical() -> PerfModel {
        PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b())
    }

    #[test]
    fn prefill_layer_scales_with_tokens() {
        let m = analytical();
        let t1 = m.analytic_prefill_layer(1024, 0, 108);
        let t4 = m.analytic_prefill_layer(4096, 0, 108);
        assert!(t4 > 3.0 * t1 && t4 < 8.0 * t1, "t1={t1} t4={t4}");
    }

    #[test]
    fn fewer_sms_slower() {
        let m = analytical();
        let full = m.analytic_prefill_layer(2048, 0, 108);
        let half = m.analytic_prefill_layer(2048, 0, 54);
        assert!(half > 1.5 * full);
    }

    #[test]
    fn decode_step_is_bandwidth_dominated_plausible() {
        let m = analytical();
        // 32 layers streaming ~16 GB of weights at ~1.7 TB/s → ~10 ms.
        let t = m.analytic_decode_step(32, 2048, 108);
        assert!(t > 5e-3 && t < 40e-3, "t={t}");
    }

    #[test]
    fn contention_factors_apply() {
        let mut m = analytical();
        m.p_c = 1.3;
        m.p_b = 1.5;
        let solo = m.predict_prefill_layer(1024, 0, 54, false);
        let cont = m.predict_prefill_layer(1024, 0, 54, true);
        assert!((cont / solo - 1.3).abs() < 1e-9);
        let dsolo = m.predict_decode_step(16, 1024, 54, false);
        let dcont = m.predict_decode_step(16, 1024, 54, true);
        assert!((dcont / dsolo - 1.5).abs() < 1e-9);
    }

    #[test]
    fn empty_batch_costs_nothing() {
        let m = analytical();
        assert_eq!(m.predict_decode_step(0, 1024, 54, true), 0.0);
    }

    #[test]
    fn zero_sms_infinite() {
        let m = analytical();
        assert!(m.analytic_prefill_layer(1024, 0, 0).is_infinite());
    }
}
