//! Offline profiling (§3.2.2): sample the simulated GPU over the
//! (sl, bs, cl, pm, dm) space, fit correction ratios and contention
//! factors, and return the augmented [`PerfModel`].
//!
//! The paper samples sl/bs/cl at steps of 1024/8/1024 and SM counts at a
//! step of 6, keeping ~12k trials within a two-hour budget.  We expose
//! the step sizes in [`ProfileSpec`] so tests can profile coarsely while
//! the benches use paper-fidelity grids (the simulated "two hours" passes
//! in a second or two of CPU).

use crate::config::{GpuSpec, ModelSpec};
use crate::gpu::roofline::GroundTruth;
use crate::gpu::simulator::Simulator;
use crate::gpu::stream::SmMask;
use crate::model::phases::{decode_all_layers, prefill_layer_kernels, PhaseShape};
use crate::perf::estimator::PerfModel;
use crate::perf::grid::{Grid2, Grid3};

/// Sampling plan.
#[derive(Debug, Clone)]
pub struct ProfileSpec {
    pub sl_points: Vec<usize>,
    pub bs_points: Vec<usize>,
    pub cl_points: Vec<usize>,
    pub sm_points: Vec<usize>,
    /// Co-located (contention) probe pairs per phase pair.
    pub contention_probes: usize,
    /// Simulator seed (noise realization).
    pub seed: u64,
}

impl ProfileSpec {
    /// Paper-like grid (§3.2.2): ~12k samples, still fast in simulation.
    pub fn paper(gpu: &GpuSpec) -> ProfileSpec {
        let sl: Vec<usize> = (1..=16).map(|i| i * 1024).collect();
        let bs: Vec<usize> = (1..=16).map(|i| i * 8).collect();
        let cl: Vec<usize> = (1..=8).map(|i| i * 1024).collect();
        let sm: Vec<usize> = (1..=(gpu.num_sms / 6)).map(|i| i * 6).collect();
        ProfileSpec {
            sl_points: sl,
            bs_points: bs,
            cl_points: cl,
            sm_points: sm,
            contention_probes: 200,
            seed: 0xB011E7,
        }
    }

    /// Coarse grid for unit tests.
    pub fn coarse(gpu: &GpuSpec) -> ProfileSpec {
        ProfileSpec {
            sl_points: vec![512, 2048, 8192],
            bs_points: vec![8, 64, 192],
            cl_points: vec![1024, 4096],
            sm_points: vec![24, 54, gpu.num_sms],
            contention_probes: 24,
            seed: 0xB011E7,
        }
    }

    pub fn sample_count(&self) -> usize {
        self.sl_points.len() * self.sm_points.len()
            + self.bs_points.len() * self.cl_points.len() * self.sm_points.len()
            + self.contention_probes * 2
    }
}

/// Measure one prefill layer solo on `pm` SMs.
fn measure_prefill_layer(gt: &GroundTruth, seed: u64, model: &ModelSpec, sl: usize, pm: usize) -> f64 {
    let mut sim = Simulator::new(gt.clone(), seed);
    let st = sim.create_stream(SmMask::first(pm), "probe-prefill");
    sim.submit_all(
        st,
        prefill_layer_kernels(model, PhaseShape { tokens: sl, context: 0 }),
    );
    sim.run_until_idle();
    sim.now()
}

/// Measure one full decode step solo on `dm` SMs.
fn measure_decode_step(
    gt: &GroundTruth,
    seed: u64,
    model: &ModelSpec,
    bs: usize,
    cl: usize,
    dm: usize,
) -> f64 {
    let mut sim = Simulator::new(gt.clone(), seed);
    let st = sim.create_stream(SmMask::first(dm), "probe-decode");
    sim.submit_all(st, decode_all_layers(model, PhaseShape { tokens: bs, context: cl }));
    sim.run_until_idle();
    sim.now()
}

/// Measure co-located prefill layer + decode step on complementary masks;
/// returns (prefill slowdown vs solo, decode slowdown vs solo).
#[allow(clippy::too_many_arguments)]
fn measure_contention(
    gt: &GroundTruth,
    seed: u64,
    model: &ModelSpec,
    sl: usize,
    bs: usize,
    cl: usize,
    pm: usize,
    dm: usize,
) -> (f64, f64) {
    let solo_p = measure_prefill_layer(gt, seed, model, sl, pm);
    let solo_d = measure_decode_step(gt, seed.wrapping_add(1), model, bs, cl, dm);

    let mut sim = Simulator::new(gt.clone(), seed.wrapping_add(2));
    let total = gt.gpu.num_sms;
    let ps = sim.create_stream(SmMask::first(pm), "co-prefill");
    let ds = sim.create_stream(SmMask::last(dm.min(total - 1).max(1), total), "co-decode");
    // Loop the prefill layer so the decode step is contended throughout.
    for _ in 0..4 {
        sim.submit_all(
            ps,
            prefill_layer_kernels(model, PhaseShape { tokens: sl, context: 0 }),
        );
    }
    sim.submit_all(ds, decode_all_layers(model, PhaseShape { tokens: bs, context: cl }));
    // decode completion time:
    sim.run_until_stream_idle(ds);
    let co_d = sim.now();
    // time per prefill layer while contended: count completed prefill kernels
    let completions = sim.take_completions();
    let prefill_done: Vec<&crate::gpu::simulator::Completion> = completions
        .iter()
        .filter(|c| c.stream == ps)
        .collect();
    let co_p = if prefill_done.is_empty() {
        solo_p
    } else {
        // average per-layer time from kernel spans
        let kernels_per_layer =
            prefill_layer_kernels(model, PhaseShape { tokens: sl, context: 0 }).len() as f64;
        let span = prefill_done.last().unwrap().end - prefill_done[0].start;
        let layers = prefill_done.len() as f64 / kernels_per_layer;
        span / layers.max(1.0)
    };
    ((co_p / solo_p).max(1.0), (co_d / solo_d).max(1.0))
}

/// Run the offline profiling pass and return the augmented model.
pub fn profile(gt: &GroundTruth, model: &ModelSpec, spec: &ProfileSpec) -> PerfModel {
    let analytic = PerfModel::analytical(gt.gpu.clone(), model.clone());
    let mut seed = spec.seed;
    let mut next_seed = || {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        seed
    };

    // --- prefill correction grid ---
    let ax_sl: Vec<f64> = spec.sl_points.iter().map(|&x| x as f64).collect();
    let ax_sm: Vec<f64> = spec.sm_points.iter().map(|&x| x as f64).collect();
    let mut prefill_ratio = Grid2::new(ax_sl, ax_sm.clone(), 1.0);
    for (i, &sl) in spec.sl_points.iter().enumerate() {
        for (j, &pm) in spec.sm_points.iter().enumerate() {
            let measured = measure_prefill_layer(gt, next_seed(), model, sl, pm);
            let predicted = analytic.analytic_prefill_layer(sl, 0, pm);
            prefill_ratio.set(i, j, measured / predicted);
        }
    }

    // --- decode correction grid ---
    let ax_bs: Vec<f64> = spec.bs_points.iter().map(|&x| x as f64).collect();
    let ax_cl: Vec<f64> = spec.cl_points.iter().map(|&x| x as f64).collect();
    let mut decode_ratio = Grid3::new(ax_bs, ax_cl, ax_sm, 1.0);
    for (i, &bs) in spec.bs_points.iter().enumerate() {
        for (j, &cl) in spec.cl_points.iter().enumerate() {
            for (k, &dm) in spec.sm_points.iter().enumerate() {
                let measured = measure_decode_step(gt, next_seed(), model, bs, cl, dm);
                let predicted = analytic.analytic_decode_step(bs, cl, dm);
                decode_ratio.set(i, j, k, measured / predicted);
            }
        }
    }

    // --- contention factors ---
    let mut pc_acc = 0.0;
    let mut pb_acc = 0.0;
    let mut n = 0usize;
    let total = gt.gpu.num_sms;
    for probe in 0..spec.contention_probes {
        let sl = spec.sl_points[probe % spec.sl_points.len()];
        let bs = spec.bs_points[(probe / 2) % spec.bs_points.len()];
        let cl = spec.cl_points[probe % spec.cl_points.len()];
        // split the GPU at varying points
        let k = spec.sm_points.len();
        let pm = spec.sm_points[probe % k].clamp(6, total - 6);
        let dm = total - pm;
        let (pc, pb) = measure_contention(gt, next_seed(), model, sl, bs, cl, pm, dm);
        pc_acc += pc;
        pb_acc += pb;
        n += 1;
    }
    let p_c = if n > 0 { pc_acc / n as f64 } else { 1.0 };
    let p_b = if n > 0 { pb_acc / n as f64 } else { 1.0 };

    PerfModel {
        gpu: gt.gpu.clone(),
        model: model.clone(),
        prefill_ratio,
        decode_ratio,
        p_c,
        p_b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::util::stats;

    fn setup() -> (GroundTruth, ModelSpec, PerfModel) {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let model = ModelSpec::llama31_8b();
        let spec = ProfileSpec::coarse(&gt.gpu);
        let pm = profile(&gt, &model, &spec);
        (gt, model, pm)
    }

    #[test]
    fn profiled_model_accurate_on_grid_points() {
        let (gt, model, pm) = setup();
        // On a profiled point, prediction should be near-exact (noiseless).
        let measured = measure_prefill_layer(&gt, 1, &model, 2048, 54);
        let predicted = pm.predict_prefill_layer(2048, 0, 54, false);
        let err = ((predicted - measured) / measured).abs();
        assert!(err < 0.02, "err {err}");
    }

    #[test]
    fn profiled_model_reasonable_off_grid() {
        let (gt, model, pm) = setup();
        // Off-grid interpolation should be within ~30%.
        let mut errs = Vec::new();
        for (sl, sm) in [(1024usize, 36usize), (3072, 84), (6144, 48)] {
            let measured = measure_prefill_layer(&gt, 2, &model, sl, sm);
            let predicted = pm.predict_prefill_layer(sl, 0, sm, false);
            errs.push(((predicted - measured) / measured).abs());
        }
        let mre = stats::mean(&errs);
        assert!(mre < 0.30, "mre {mre} errs {errs:?}");
    }

    #[test]
    fn pure_analytical_is_worse_than_profiled() {
        let (gt, model, pm) = setup();
        let analytic = PerfModel::analytical(gt.gpu.clone(), model.clone());
        let mut an_err = 0.0;
        let mut pr_err = 0.0;
        for (sl, sm) in [(1024usize, 24usize), (2048, 54), (8192, 108)] {
            let measured = measure_prefill_layer(&gt, 3, &model, sl, sm);
            an_err += ((analytic.predict_prefill_layer(sl, 0, sm, false) - measured) / measured).abs();
            pr_err += ((pm.predict_prefill_layer(sl, 0, sm, false) - measured) / measured).abs();
        }
        assert!(pr_err < an_err, "profiled {pr_err} analytic {an_err}");
    }

    #[test]
    fn contention_factors_exceed_one() {
        let (_, _, pm) = setup();
        assert!(pm.p_c >= 1.0, "p_c {}", pm.p_c);
        assert!(pm.p_b >= 1.0, "p_b {}", pm.p_b);
        // decode is bandwidth-hungry; co-location must slow something.
        assert!(pm.p_b > 1.01 || pm.p_c > 1.01);
    }

    #[test]
    fn decode_prediction_tracks_measurement() {
        let (gt, model, pm) = setup();
        let measured = measure_decode_step(&gt, 5, &model, 64, 2048, 54);
        let predicted = pm.predict_decode_step(64, 2048, 54, false);
        let err = ((predicted - measured) / measured).abs();
        assert!(err < 0.25, "err {err}");
    }

    #[test]
    fn paper_spec_sample_count_near_12k() {
        let spec = ProfileSpec::paper(&GpuSpec::a100());
        let n = spec.sample_count();
        assert!(n > 2000 && n < 20000, "samples {n}");
    }
}
