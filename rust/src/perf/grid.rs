//! Small n-linear interpolation grids over irregular sorted axes.

/// Locate `x` on a sorted axis: returns (i, frac) such that the value is
/// between axis[i] and axis[i+1] at fraction `frac` (clamped at the ends).
///
/// Binary search (`partition_point`): this sits on the scheduler's
/// candidate-partition probe path, which interpolates the correction
/// grids once per candidate per cycle — O(log n) instead of a linear
/// scan keeps wide profiled axes (paper-fidelity grids, 16+ knots; see
/// the perf hot-path bench's wide-axis case) off the decision budget.
fn locate(axis: &[f64], x: f64) -> (usize, f64) {
    assert!(!axis.is_empty());
    if axis.len() == 1 || x <= axis[0] {
        return (0, 0.0);
    }
    let last = axis.len() - 1;
    if x >= axis[last] {
        return (last - 1, 1.0);
    }
    // interior: axis[0] < x < axis[last].  The cell index is the number
    // of interior knots strictly below x — identical to the old linear
    // scan, found in O(log n).
    let i = axis[1..].partition_point(|&v| v < x);
    let span = axis[i + 1] - axis[i];
    let frac = if span <= 0.0 { 0.0 } else { (x - axis[i]) / span };
    (i, frac)
}

/// Bilinear grid over two axes.
#[derive(Debug, Clone)]
pub struct Grid2 {
    pub ax0: Vec<f64>,
    pub ax1: Vec<f64>,
    /// Row-major: data[i0 * ax1.len() + i1].
    pub data: Vec<f64>,
}

impl Grid2 {
    pub fn new(ax0: Vec<f64>, ax1: Vec<f64>, fill: f64) -> Grid2 {
        let n = ax0.len() * ax1.len();
        Grid2 {
            ax0,
            ax1,
            data: vec![fill; n],
        }
    }

    pub fn set(&mut self, i0: usize, i1: usize, v: f64) {
        let n1 = self.ax1.len();
        self.data[i0 * n1 + i1] = v;
    }

    pub fn at(&self, i0: usize, i1: usize) -> f64 {
        self.data[i0 * self.ax1.len() + i1]
    }

    /// Bilinear interpolation (clamped outside the grid).
    pub fn interp(&self, x0: f64, x1: f64) -> f64 {
        let (i0, f0) = locate(&self.ax0, x0);
        let (i1, f1) = locate(&self.ax1, x1);
        let j0 = (i0 + 1).min(self.ax0.len() - 1);
        let j1 = (i1 + 1).min(self.ax1.len() - 1);
        let a = self.at(i0, i1) * (1.0 - f1) + self.at(i0, j1) * f1;
        let b = self.at(j0, i1) * (1.0 - f1) + self.at(j0, j1) * f1;
        a * (1.0 - f0) + b * f0
    }
}

/// Trilinear grid.
#[derive(Debug, Clone)]
pub struct Grid3 {
    pub ax0: Vec<f64>,
    pub ax1: Vec<f64>,
    pub ax2: Vec<f64>,
    pub data: Vec<f64>,
}

impl Grid3 {
    pub fn new(ax0: Vec<f64>, ax1: Vec<f64>, ax2: Vec<f64>, fill: f64) -> Grid3 {
        let n = ax0.len() * ax1.len() * ax2.len();
        Grid3 {
            ax0,
            ax1,
            ax2,
            data: vec![fill; n],
        }
    }

    fn idx(&self, i0: usize, i1: usize, i2: usize) -> usize {
        (i0 * self.ax1.len() + i1) * self.ax2.len() + i2
    }

    pub fn set(&mut self, i0: usize, i1: usize, i2: usize, v: f64) {
        let i = self.idx(i0, i1, i2);
        self.data[i] = v;
    }

    pub fn at(&self, i0: usize, i1: usize, i2: usize) -> f64 {
        self.data[self.idx(i0, i1, i2)]
    }

    /// Trilinear interpolation (clamped).
    pub fn interp(&self, x0: f64, x1: f64, x2: f64) -> f64 {
        let (i0, f0) = locate(&self.ax0, x0);
        let (i1, f1) = locate(&self.ax1, x1);
        let (i2, f2) = locate(&self.ax2, x2);
        let j0 = (i0 + 1).min(self.ax0.len() - 1);
        let j1 = (i1 + 1).min(self.ax1.len() - 1);
        let j2 = (i2 + 1).min(self.ax2.len() - 1);
        let mut acc = 0.0;
        for (a, wa) in [(i0, 1.0 - f0), (j0, f0)] {
            for (b, wb) in [(i1, 1.0 - f1), (j1, f1)] {
                for (c, wc) in [(i2, 1.0 - f2), (j2, f2)] {
                    acc += self.at(a, b, c) * wa * wb * wc;
                }
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_clamps() {
        let ax = [1.0, 2.0, 4.0];
        assert_eq!(locate(&ax, 0.5), (0, 0.0));
        assert_eq!(locate(&ax, 5.0), (1, 1.0));
        let (i, f) = locate(&ax, 3.0);
        assert_eq!(i, 1);
        assert!((f - 0.5).abs() < 1e-12);
    }

    #[test]
    fn grid2_exact_at_nodes() {
        let mut g = Grid2::new(vec![0.0, 1.0], vec![0.0, 1.0], 0.0);
        g.set(0, 0, 1.0);
        g.set(0, 1, 2.0);
        g.set(1, 0, 3.0);
        g.set(1, 1, 4.0);
        assert_eq!(g.interp(0.0, 0.0), 1.0);
        assert_eq!(g.interp(1.0, 1.0), 4.0);
        assert!((g.interp(0.5, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn grid2_clamp_outside() {
        let mut g = Grid2::new(vec![0.0, 1.0], vec![0.0, 1.0], 0.0);
        g.set(1, 1, 4.0);
        assert_eq!(g.interp(9.0, 9.0), 4.0);
        assert_eq!(g.interp(-9.0, -9.0), 0.0);
    }

    #[test]
    fn grid3_linear_function_reproduced() {
        // f(x,y,z) = x + 2y + 3z is reproduced exactly by trilinear interp.
        let ax: Vec<f64> = vec![0.0, 1.0, 2.0];
        let mut g = Grid3::new(ax.clone(), ax.clone(), ax.clone(), 0.0);
        for (i, &x) in ax.iter().enumerate() {
            for (j, &y) in ax.iter().enumerate() {
                for (k, &z) in ax.iter().enumerate() {
                    g.set(i, j, k, x + 2.0 * y + 3.0 * z);
                }
            }
        }
        for (x, y, z) in [(0.5, 1.5, 0.25), (1.9, 0.1, 1.0), (0.0, 2.0, 2.0)] {
            let v = g.interp(x, y, z);
            assert!((v - (x + 2.0 * y + 3.0 * z)).abs() < 1e-12, "{v}");
        }
    }

    #[test]
    fn single_point_axis() {
        let g = Grid2::new(vec![5.0], vec![1.0, 2.0], 7.0);
        assert_eq!(g.interp(100.0, 1.5), 7.0);
    }

    #[test]
    fn locate_binary_search_matches_linear_scan() {
        // the pre-optimization reference implementation
        fn locate_linear(axis: &[f64], x: f64) -> (usize, f64) {
            if axis.len() == 1 || x <= axis[0] {
                return (0, 0.0);
            }
            let last = axis.len() - 1;
            if x >= axis[last] {
                return (last - 1, 1.0);
            }
            let mut i = 0;
            while i + 1 < axis.len() && axis[i + 1] < x {
                i += 1;
            }
            let span = axis[i + 1] - axis[i];
            let frac = if span <= 0.0 { 0.0 } else { (x - axis[i]) / span };
            (i, frac)
        }
        // irregular wide axis, probes on knots, between knots, outside
        let axis: Vec<f64> = (0..64).map(|i| (i * i) as f64 * 0.5 + i as f64).collect();
        let mut probes: Vec<f64> = axis.clone();
        probes.extend(axis.windows(2).map(|w| 0.3 * w[0] + 0.7 * w[1]));
        probes.extend([-5.0, 1e9]);
        for x in probes {
            let (ia, fa) = locate(&axis, x);
            let (ib, fb) = locate_linear(&axis, x);
            assert_eq!(ia, ib, "index mismatch at x={x}");
            assert_eq!(fa.to_bits(), fb.to_bits(), "frac mismatch at x={x}");
        }
    }
}
