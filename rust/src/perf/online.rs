//! Online calibration (the live half of §3.2): wrap the offline-profiled
//! [`PerfModel`] in a closed feedback loop.
//!
//! Offline profiling fits correction ratios once, before deployment.
//! Anything the profiled regime did not cover — thermal throttling,
//! co-tenant interference, per-device silicon variation, or simply a
//! replica whose GPU differs from the profiled one — leaves a persistent
//! predicted-vs-observed gap that the SLO scheduler then converts into
//! mis-partitioned SMs.  The [`OnlineCalibrator`] closes the loop:
//!
//! - the serving engine feeds every lane-drain boundary back as a
//!   `(shape, partition, observed)` sample ([`OnlineCalibrator::observe_prefill`] /
//!   [`OnlineCalibrator::observe_decode`]);
//! - samples EWMA-update a per-cell correction ratio, where a cell is a
//!   coarse bucket over (phase, size, context, SM share) — coarse enough
//!   to accumulate confidence quickly, fine enough to keep the learned
//!   ratio shape-local;
//! - predictions blend the learned ratio in proportion to the cell's
//!   sample count (confidence gating): cold cells fall back to the
//!   offline grid bit-for-bit, so an idle or disabled calibrator is
//!   exactly the frozen model;
//! - a residual-trend detector widens the learning rate when the signed
//!   residual drifts (regime change), then relaxes back;
//! - every ratio is clamped into a finite band, so calibration can never
//!   emit a non-finite or absurd prediction no matter what it observes.
//!
//! Determinism: `BTreeMap` cells and pure-arithmetic updates — a
//! calibrated run is a pure function of the observation sequence.

use crate::config::CalibrationConfig;
use crate::perf::estimator::PerfModel;
use crate::perf::PerfPredictor;
use crate::util::memo::MemoCounters;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Run-level calibration counters (surfaced in `EngineOutput` and the
/// CLI tables; merged cluster-wide like `PrefixStats`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationStats {
    /// Observation samples ingested.
    pub samples: u64,
    /// Sum of |observed - predicted| / predicted over all samples
    /// (predicted = the calibrated prediction at observation time).
    pub abs_residual_sum: f64,
    /// Fast EWMA of |residual| — the *recent* prediction error, which
    /// decays after adaptation where the cumulative mean cannot.  The
    /// cluster autoscaler's re-profiling trigger reads this: a converged
    /// calibrator whose recent residual stays high needs its offline
    /// grid refreshed, not more EWMA steps.
    pub recent_abs_residual: f64,
    /// Drift events flagged by the residual-trend detector.
    pub drift_events: u64,
    /// Offline-grid refreshes applied ([`OnlineCalibrator::reprofile`]).
    pub reprofiles: u64,
    /// Learned observed/nominal slowdown vs the ORIGINAL offline grid
    /// (EWMA over sample ratios; 1.0 until samples arrive).  Survives
    /// re-profiling — the device did not get faster because the grid
    /// moved under it.
    pub slowdown: f64,
}

impl Default for CalibrationStats {
    fn default() -> Self {
        CalibrationStats {
            samples: 0,
            abs_residual_sum: 0.0,
            recent_abs_residual: 0.0,
            drift_events: 0,
            reprofiles: 0,
            slowdown: 1.0,
        }
    }
}

impl CalibrationStats {
    /// Mean |residual| per sample (0 before any sample).
    pub fn mean_abs_residual(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.abs_residual_sum / self.samples as f64
        }
    }

    /// Field-wise accumulate (cluster-level aggregation); `slowdown` and
    /// `recent_abs_residual` merge sample-weighted.
    pub fn merge(&mut self, o: &CalibrationStats) {
        let total = self.samples + o.samples;
        if total > 0 {
            let w = |a: f64, b: f64| {
                (a * self.samples as f64 + b * o.samples as f64) / total as f64
            };
            self.slowdown = w(self.slowdown, o.slowdown);
            self.recent_abs_residual = w(self.recent_abs_residual, o.recent_abs_residual);
        }
        self.samples = total;
        self.abs_residual_sum += o.abs_residual_sum;
        self.drift_events += o.drift_events;
        self.reprofiles += o.reprofiles;
    }
}

/// One sample's effect, reported back to the caller (the engine bumps
/// its run counters from this).
#[derive(Debug, Clone, Copy)]
pub struct SampleOutcome {
    /// |observed - calibrated| / calibrated for this sample.
    pub abs_residual: f64,
    /// The residual-trend detector fired on this sample.
    pub drift: bool,
}

/// Correction-cell key: coarse bucket over (phase, size, context, SMs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct CellKey {
    /// 0 = prefill layer, 1 = decode iteration.
    phase: u8,
    /// log2 bucket of the size axis (prefill tokens / decode batch).
    size: u8,
    /// log2 bucket of the context axis.
    ctx: u8,
    /// SM share bucket (12-SM granularity).
    sms: u8,
}

fn log2_bucket(x: usize) -> u8 {
    (usize::BITS - x.max(1).leading_zeros()) as u8
}

impl CellKey {
    fn prefill(sl: usize, ctx: usize, pm: usize) -> CellKey {
        CellKey {
            phase: 0,
            size: log2_bucket(sl),
            ctx: log2_bucket(ctx + 1),
            sms: (pm / 12) as u8,
        }
    }

    fn decode(bs: usize, cl: usize, dm: usize) -> CellKey {
        CellKey {
            phase: 1,
            size: log2_bucket(bs),
            ctx: log2_bucket(cl),
            sms: (dm / 12) as u8,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Cell {
    /// EWMA of observed/offline-predicted ratios for this bucket.
    ratio: f64,
    samples: u64,
}

/// Exact-argument key for the corrected-prediction memo:
/// (phase tag 0/1, size, ctx, sms, contended).
type PredictKey = (u8, usize, usize, usize, bool);

/// Keep the memo bounded; predictions cluster on a handful of shapes ×
/// candidate partitions per cycle, so this is never reached in practice.
const PREDICT_MEMO_CAP: usize = 4096;

/// Calibrated-prediction memo, valid for one calibration epoch.  A
/// prediction is a pure function of (args, cells, grid refresh), and
/// the latter two only change when a sample updates a cell or a
/// re-profile folds the grid — both bump the epoch, which clears the
/// map lazily on the next lookup.  A hit returns the exact f64 the
/// blend produced earlier, so memoized and fresh predictions are
/// bitwise identical.  `HashMap` is safe here: its iteration order is
/// never observed.
#[derive(Debug, Clone, Default)]
struct PredictMemo {
    epoch: u64,
    map: HashMap<PredictKey, f64>,
    counters: MemoCounters,
}

/// The feedback-calibrated predictor (see module docs).
#[derive(Debug, Clone)]
pub struct OnlineCalibrator {
    inner: PerfModel,
    cfg: CalibrationConfig,
    cells: BTreeMap<CellKey, Cell>,
    /// Recent signed relative residuals vs the CALIBRATED prediction.
    window: VecDeque<f64>,
    /// Boosted-learning-rate updates remaining after a drift event.
    boost_left: u32,
    /// Accumulated offline-grid refresh factor ([`Self::reprofile`]):
    /// base predictions are the wrapped model's times this.  Exactly
    /// 1.0 until a re-profile, and the multiply is skipped then, so an
    /// un-refreshed calibrator stays bitwise-faithful to the offline
    /// grid.
    grid_refresh: f64,
    stats: CalibrationStats,
    /// Calibration epoch: bumped whenever learned state that feeds
    /// predictions changes (a cell EWMA update, a grid re-profile).
    /// The prediction memo is valid only within one epoch.
    epoch: u64,
    /// Hot-path memoization toggle ([`crate::config::ServingConfig::memo`]).
    /// Off runs the reference (always-recompute) path; both are
    /// bit-identical by construction.
    memo_enabled: bool,
    memo: RefCell<PredictMemo>,
}

impl OnlineCalibrator {
    pub fn new(inner: PerfModel, cfg: CalibrationConfig) -> OnlineCalibrator {
        OnlineCalibrator {
            inner,
            cfg,
            cells: BTreeMap::new(),
            window: VecDeque::new(),
            boost_left: 0,
            grid_refresh: 1.0,
            stats: CalibrationStats::default(),
            epoch: 0,
            memo_enabled: true,
            memo: RefCell::new(PredictMemo::default()),
        }
    }

    /// Toggle the corrected-prediction memo (reference path when off).
    pub fn set_memo(&mut self, on: bool) {
        self.memo_enabled = on;
        let mut m = self.memo.borrow_mut();
        m.map.clear();
        m.epoch = self.epoch;
    }

    /// Hit/miss/invalidation counters for the prediction memo.
    pub fn memo_counters(&self) -> MemoCounters {
        self.memo.borrow().counters
    }

    /// Memo lookup for the current epoch; lazily clears a stale map.
    fn memo_get(&self, key: PredictKey) -> Option<f64> {
        let mut m = self.memo.borrow_mut();
        if m.epoch != self.epoch {
            if !m.map.is_empty() {
                m.counters.invalidations += 1;
                m.map.clear();
            }
            m.epoch = self.epoch;
        }
        match m.map.get(&key) {
            Some(&v) => {
                m.counters.hits += 1;
                Some(v)
            }
            None => {
                m.counters.misses += 1;
                None
            }
        }
    }

    fn memo_put(&self, key: PredictKey, v: f64) {
        let mut m = self.memo.borrow_mut();
        if m.map.len() >= PREDICT_MEMO_CAP {
            m.map.clear();
        }
        m.map.insert(key, v);
    }

    /// The wrapped offline model.
    pub fn offline(&self) -> &PerfModel {
        &self.inner
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn stats(&self) -> CalibrationStats {
        self.stats
    }

    /// Correction cells holding at least one sample.
    pub fn warm_cells(&self) -> usize {
        self.cells.len()
    }

    /// Recent |residual| EWMA — the re-profiling trigger signal (see
    /// [`CalibrationStats::recent_abs_residual`]).
    pub fn recent_abs_residual(&self) -> f64 {
        self.stats.recent_abs_residual
    }

    /// Whether enough samples have been ingested that the learned state
    /// is trustworthy — the convergence gate autoscalers apply before
    /// acting on residuals (a cold calibrator's residuals are noise).
    pub fn converged(&self, min_samples: u64) -> bool {
        self.cfg.enabled && self.stats.samples >= min_samples
    }

    /// The accumulated grid-refresh factor (1.0 before any re-profile).
    pub fn grid_refresh(&self) -> f64 {
        self.grid_refresh
    }

    /// Simulated §3.2.2 offline-grid refresh: fold the learned aggregate
    /// slowdown into the base grid (every base prediction scales by it),
    /// clear the per-cell ratios and residual history, and keep
    /// calibrating against the refreshed baseline.  Used by the cluster
    /// autoscaler when a CONVERGED calibrator's recent residual stays
    /// high — per-cell EWMA cannot fix a grid that is wrong everywhere.
    /// `calibrated_slowdown()` stays continuous across the refresh: the
    /// device's slowdown is measured against the original grid.  Returns
    /// the fold factor (1.0 when disabled or nothing learned).
    pub fn reprofile(&mut self) -> f64 {
        if !self.cfg.enabled || self.stats.samples == 0 {
            return 1.0;
        }
        let fold = self.clamp_ratio(self.stats.slowdown / self.grid_refresh);
        self.grid_refresh *= fold;
        self.cells.clear();
        self.window.clear();
        self.boost_left = 0;
        self.stats.reprofiles += 1;
        self.stats.recent_abs_residual = 0.0;
        self.epoch += 1; // grid moved: memoized predictions are stale
        fold
    }

    /// A base (offline-grid) value under the current refresh factor.
    /// The multiply is skipped at exactly 1.0 so un-refreshed paths stay
    /// bitwise identical to the wrapped model.
    fn refreshed(&self, x: f64) -> f64 {
        if self.grid_refresh == 1.0 {
            x
        } else {
            x * self.grid_refresh
        }
    }

    /// Blend a base (offline) prediction with a cell's learned ratio.
    /// Cold or absent cells return `base` UNCHANGED (bitwise): with the
    /// calibrator disabled or unobserved, prediction is the frozen model.
    fn blend(&self, key: &CellKey, base: f64) -> f64 {
        if !self.cfg.enabled {
            return base;
        }
        let Some(cell) = self.cells.get(key) else {
            return base;
        };
        let w = (cell.samples as f64 / self.cfg.confidence_samples.max(1) as f64).min(1.0);
        base * (1.0 + w * (cell.ratio - 1.0))
    }

    fn clamp_ratio(&self, r: f64) -> f64 {
        if r.is_finite() {
            r.clamp(self.cfg.ratio_min, self.cfg.ratio_max)
        } else {
            1.0
        }
    }

    /// Shared sample path: `base` = the (refresh-scaled) offline
    /// prediction for the observed shape, `calibrated` = our current
    /// prediction for it.
    fn ingest(
        &mut self,
        key: CellKey,
        base: f64,
        calibrated: f64,
        observed: f64,
    ) -> Option<SampleOutcome> {
        if !self.cfg.enabled
            || !observed.is_finite()
            || observed <= 0.0
            || !base.is_finite()
            || base <= 0.0
        {
            return None;
        }
        let residual = (observed - calibrated) / calibrated.max(1e-12);
        // cell-relative ratio (vs the refreshed grid) drives the EWMA;
        // the total ratio (vs the ORIGINAL grid) drives the slowdown
        let sample_ratio = self.clamp_ratio(observed / base);
        let total_ratio = self.clamp_ratio((observed / base) * self.grid_refresh);

        self.stats.samples += 1;
        self.stats.abs_residual_sum += residual.abs();
        // fast |residual| EWMA: the re-profiling trigger signal
        self.stats.recent_abs_residual += 0.15 * (residual.abs() - self.stats.recent_abs_residual);
        // slow EWMA over total sample ratios = the device's learned slowdown
        self.stats.slowdown += 0.1 * (total_ratio - self.stats.slowdown);

        // Drift detection on the signed residual trend.
        let mut drift = false;
        self.window.push_back(residual);
        if self.window.len() >= self.cfg.drift_window.max(1) {
            let mean: f64 = self.window.iter().sum::<f64>() / self.window.len() as f64;
            if mean.abs() > self.cfg.drift_threshold {
                drift = true;
                self.stats.drift_events += 1;
                self.boost_left = self.cfg.drift_window.max(1) as u32;
            }
            self.window.clear();
        }

        // Deadband: an in-tolerance sample confirms the current model —
        // leave every ratio untouched (cold cells stay bitwise-frozen).
        if residual.abs() >= self.cfg.min_abs_residual {
            let mut alpha = self.cfg.alpha;
            if self.boost_left > 0 {
                alpha = (alpha * self.cfg.drift_boost).min(1.0);
                self.boost_left -= 1;
            }
            let ratio_min = self.cfg.ratio_min;
            let ratio_max = self.cfg.ratio_max;
            let cell = self.cells.entry(key).or_insert(Cell { ratio: 1.0, samples: 0 });
            cell.ratio += alpha * (sample_ratio - cell.ratio);
            cell.ratio = cell.ratio.clamp(ratio_min, ratio_max);
            cell.samples += 1;
            self.epoch += 1; // a cell moved: memoized predictions are stale
        }

        Some(SampleOutcome {
            abs_residual: residual.abs(),
            drift,
        })
    }

    /// Feed one observed prefill group: `layers` layers of shape
    /// `(sl, ctx)` ran on `pm` SMs and took `observed` seconds total.
    pub fn observe_prefill(
        &mut self,
        sl: usize,
        ctx: usize,
        pm: usize,
        contended: bool,
        layers: usize,
        observed: f64,
    ) -> Option<SampleOutcome> {
        let per_layer = observed / layers.max(1) as f64;
        let base =
            self.refreshed(PerfModel::predict_prefill_layer(&self.inner, sl, ctx, pm, contended));
        let calibrated = PerfPredictor::predict_prefill_layer(self, sl, ctx, pm, contended);
        self.ingest(CellKey::prefill(sl, ctx, pm), base, calibrated, per_layer)
    }

    /// Feed one observed decode iteration (all layers).
    pub fn observe_decode(
        &mut self,
        bs: usize,
        cl: usize,
        dm: usize,
        contended: bool,
        observed: f64,
    ) -> Option<SampleOutcome> {
        let base =
            self.refreshed(PerfModel::predict_decode_step(&self.inner, bs, cl, dm, contended));
        let calibrated = PerfPredictor::predict_decode_step(self, bs, cl, dm, contended);
        self.ingest(CellKey::decode(bs, cl, dm), base, calibrated, observed)
    }
}

impl PerfPredictor for OnlineCalibrator {
    fn predict_prefill_layer(&self, sl: usize, ctx: usize, pm: usize, contended: bool) -> f64 {
        let key = (0u8, sl, ctx, pm, contended);
        if self.memo_enabled {
            if let Some(v) = self.memo_get(key) {
                return v;
            }
        }
        let base =
            self.refreshed(PerfModel::predict_prefill_layer(&self.inner, sl, ctx, pm, contended));
        let v = self.blend(&CellKey::prefill(sl, ctx, pm), base);
        if self.memo_enabled {
            self.memo_put(key, v);
        }
        v
    }

    fn predict_decode_step(&self, bs: usize, cl: usize, dm: usize, contended: bool) -> f64 {
        let key = (1u8, bs, cl, dm, contended);
        if self.memo_enabled {
            if let Some(v) = self.memo_get(key) {
                return v;
            }
        }
        let base =
            self.refreshed(PerfModel::predict_decode_step(&self.inner, bs, cl, dm, contended));
        let v = self.blend(&CellKey::decode(bs, cl, dm), base);
        if self.memo_enabled {
            self.memo_put(key, v);
        }
        v
    }

    fn calibrated_slowdown(&self) -> f64 {
        self.stats.slowdown
    }

    fn calibration(&self) -> CalibrationStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CalibrationConfig, GpuSpec, ModelSpec};

    fn calibrator(cfg: CalibrationConfig) -> OnlineCalibrator {
        let inner = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        OnlineCalibrator::new(inner, cfg)
    }

    #[test]
    fn disabled_calibrator_is_bitwise_passthrough() {
        let mut c = calibrator(CalibrationConfig::default());
        let inner = c.offline().clone();
        // even after (ignored) observations
        assert!(c.observe_prefill(2048, 0, 54, true, 4, 1.0).is_none());
        for (sl, pm) in [(128usize, 24usize), (2048, 54), (8192, 108)] {
            let a = PerfPredictor::predict_prefill_layer(&c, sl, 0, pm, true);
            let b = PerfModel::predict_prefill_layer(&inner, sl, 0, pm, true);
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let a = PerfPredictor::predict_decode_step(&c, 64, 2048, 54, false);
        let b = PerfModel::predict_decode_step(&inner, 64, 2048, 54, false);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(c.stats().samples, 0);
    }

    #[test]
    fn cold_cells_fall_back_to_offline_grid() {
        let mut c = calibrator(CalibrationConfig::on());
        let base = PerfPredictor::predict_prefill_layer(&c, 1024, 0, 54, false);
        // teach a DECODE cell; prefill cells stay cold
        let obs = PerfModel::predict_decode_step(c.offline(), 32, 1024, 54, false) * 2.0;
        c.observe_decode(32, 1024, 54, false, obs);
        let after = PerfPredictor::predict_prefill_layer(&c, 1024, 0, 54, false);
        assert_eq!(base.to_bits(), after.to_bits(), "cold cell must pass through");
    }

    #[test]
    fn converges_to_constant_bias() {
        let mut c = calibrator(CalibrationConfig::on());
        let base = PerfModel::predict_prefill_layer(c.offline(), 2048, 0, 54, true);
        for _ in 0..40 {
            c.observe_prefill(2048, 0, 54, true, 1, base * 1.5);
        }
        let p = PerfPredictor::predict_prefill_layer(&c, 2048, 0, 54, true);
        let learned = p / base;
        assert!(
            (learned - 1.5).abs() < 0.08,
            "learned ratio {learned} should approach 1.5"
        );
        assert!(c.calibrated_slowdown() > 1.2);
        assert!(c.stats().samples == 40);
    }

    #[test]
    fn deadband_keeps_accurate_models_frozen() {
        let mut c = calibrator(CalibrationConfig {
            min_abs_residual: 0.1,
            ..CalibrationConfig::on()
        });
        let base = PerfModel::predict_decode_step(c.offline(), 64, 2048, 54, true);
        for _ in 0..20 {
            c.observe_decode(64, 2048, 54, true, base * 1.03); // within tolerance
        }
        assert_eq!(c.warm_cells(), 0, "in-tolerance samples must not open cells");
        let p = PerfPredictor::predict_decode_step(&c, 64, 2048, 54, true);
        assert_eq!(p.to_bits(), base.to_bits());
        assert_eq!(c.stats().samples, 20, "samples still counted");
    }

    #[test]
    fn drift_detector_fires_and_boosts_adaptation() {
        let cfg = CalibrationConfig {
            alpha: 0.05,
            drift_window: 5,
            drift_threshold: 0.2,
            drift_boost: 8.0,
            ..CalibrationConfig::on()
        };
        let mut slow = calibrator(cfg.clone());
        let mut fast = calibrator(cfg);
        fast.cfg.drift_boost = 1.0; // detector on, boost off
        let base = PerfModel::predict_prefill_layer(slow.offline(), 4096, 0, 72, true);
        for _ in 0..10 {
            slow.observe_prefill(4096, 0, 72, true, 1, base * 2.0);
            fast.observe_prefill(4096, 0, 72, true, 1, base * 2.0);
        }
        assert!(slow.stats().drift_events >= 1, "trend must flag drift");
        let p_boost = PerfPredictor::predict_prefill_layer(&slow, 4096, 0, 72, true);
        let p_plain = PerfPredictor::predict_prefill_layer(&fast, 4096, 0, 72, true);
        assert!(
            p_boost > p_plain,
            "boosted learning must converge faster: {p_boost} vs {p_plain}"
        );
    }

    #[test]
    fn never_produces_non_finite_predictions() {
        let mut c = calibrator(CalibrationConfig::on());
        // hostile observations: zero, negative, inf, nan, absurd
        for obs in [0.0, -1.0, f64::INFINITY, f64::NAN, 1e30, 1e-30] {
            c.observe_prefill(1024, 0, 54, true, 1, obs);
            c.observe_decode(16, 512, 24, false, obs);
        }
        for (sl, pm) in [(1usize, 2usize), (1024, 54), (16384, 108)] {
            let p = PerfPredictor::predict_prefill_layer(&c, sl, 0, pm, true);
            assert!(p.is_finite() && p >= 0.0, "prefill pred {p}");
        }
        let p = PerfPredictor::predict_decode_step(&c, 16, 512, 24, false);
        assert!(p.is_finite() && p > 0.0, "decode pred {p}");
    }

    #[test]
    fn stats_merge_is_sample_weighted() {
        let mut a = CalibrationStats {
            samples: 10,
            abs_residual_sum: 1.0,
            recent_abs_residual: 0.4,
            drift_events: 1,
            reprofiles: 1,
            slowdown: 1.0,
        };
        let b = CalibrationStats {
            samples: 30,
            abs_residual_sum: 3.0,
            recent_abs_residual: 0.0,
            drift_events: 2,
            reprofiles: 0,
            slowdown: 2.0,
        };
        a.merge(&b);
        assert_eq!(a.samples, 40);
        assert_eq!(a.drift_events, 3);
        assert_eq!(a.reprofiles, 1);
        assert!((a.slowdown - 1.75).abs() < 1e-12);
        assert!((a.recent_abs_residual - 0.1).abs() < 1e-12);
        assert!((a.mean_abs_residual() - 0.1).abs() < 1e-12);
        // merging an empty default is a no-op
        let mut c = CalibrationStats::default();
        c.merge(&CalibrationStats::default());
        assert_eq!(c.samples, 0);
        assert_eq!(c.slowdown, 1.0);
        // zero-denominator guard: a sample-free calibrator reports 0.0
        // mean residual, never NaN — the CLI tables print this raw
        assert_eq!(c.mean_abs_residual(), 0.0);
    }

    #[test]
    fn reprofile_folds_the_learned_slowdown_into_the_grid() {
        let mut c = calibrator(CalibrationConfig::on());
        let base = PerfModel::predict_prefill_layer(c.offline(), 2048, 0, 54, true);
        // the device runs a uniform 2x slower than the offline grid
        for _ in 0..60 {
            c.observe_prefill(2048, 0, 54, true, 1, base * 2.0);
        }
        assert!(c.converged(50));
        let learned = c.calibrated_slowdown();
        assert!(learned > 1.6, "slowdown {learned}");
        let fold = c.reprofile();
        assert!((fold - learned).abs() < 1e-12, "fold {fold} vs learned {learned}");
        assert_eq!(c.warm_cells(), 0, "cells cleared by the refresh");
        assert_eq!(c.stats().reprofiles, 1);
        assert_eq!(c.recent_abs_residual(), 0.0);
        // the refreshed grid predicts near-observed even with cold cells
        let p = PerfPredictor::predict_prefill_layer(&c, 2048, 0, 54, true);
        assert!(
            (p / (base * 2.0) - 1.0).abs() < 0.25,
            "refreshed base {p} should approach the observed {}",
            base * 2.0
        );
        // and the device's total slowdown stays continuous across it
        assert!((c.calibrated_slowdown() - learned).abs() < 1e-12);
        // further unbiased observations keep the slowdown near the total
        for _ in 0..40 {
            c.observe_prefill(2048, 0, 54, true, 1, base * 2.0);
        }
        assert!(
            (c.calibrated_slowdown() - 2.0).abs() < 0.4,
            "total slowdown {} should stay ~2x after the refresh",
            c.calibrated_slowdown()
        );
        // an untouched calibrator never refreshes implicitly
        let mut idle = calibrator(CalibrationConfig::on());
        assert_eq!(idle.reprofile(), 1.0);
        assert_eq!(idle.grid_refresh(), 1.0);
    }

    #[test]
    fn memoized_predictions_are_bit_identical_to_reference() {
        let mut on = calibrator(CalibrationConfig::on());
        let mut off = calibrator(CalibrationConfig::on());
        off.set_memo(false);
        let base = PerfModel::predict_prefill_layer(on.offline(), 2048, 0, 54, true);
        // interleave observations (which invalidate the memo) with
        // repeated predictions (which hit it) and compare bits
        let shapes = [(128usize, 24usize), (2048, 54), (2048, 72), (8192, 108)];
        for round in 0..12 {
            for &(sl, pm) in &shapes {
                for _ in 0..3 {
                    let a = PerfPredictor::predict_prefill_layer(&on, sl, 0, pm, true);
                    let b = PerfPredictor::predict_prefill_layer(&off, sl, 0, pm, true);
                    assert_eq!(a.to_bits(), b.to_bits(), "prefill {sl}x{pm} round {round}");
                    let a = PerfPredictor::predict_decode_step(&on, 64, 2048, pm, false);
                    let b = PerfPredictor::predict_decode_step(&off, 64, 2048, pm, false);
                    assert_eq!(a.to_bits(), b.to_bits(), "decode {pm} round {round}");
                }
            }
            on.observe_prefill(2048, 0, 54, true, 1, base * 1.5);
            off.observe_prefill(2048, 0, 54, true, 1, base * 1.5);
            if round == 6 {
                on.reprofile();
                off.reprofile();
            }
        }
        let c_on = on.memo_counters();
        let c_off = off.memo_counters();
        assert!(c_on.hits > 0, "repeats must hit the memo: {c_on:?}");
        assert!(c_on.misses > 0, "first lookups must miss: {c_on:?}");
        assert!(
            c_on.invalidations > 0,
            "ingest/reprofile must invalidate: {c_on:?}"
        );
        assert_eq!(c_off.hits + c_off.misses, 0, "memo-off must never consult the map");
    }

    #[test]
    fn ingest_invalidates_the_prediction_memo() {
        let mut c = calibrator(CalibrationConfig::on());
        let base = PerfModel::predict_prefill_layer(c.offline(), 2048, 0, 54, true);
        let cold = PerfPredictor::predict_prefill_layer(&c, 2048, 0, 54, true);
        // second lookup hits and returns the identical bits
        let hit = PerfPredictor::predict_prefill_layer(&c, 2048, 0, 54, true);
        assert_eq!(cold.to_bits(), hit.to_bits());
        assert_eq!(c.memo_counters().hits, 1);
        // a sample moves the cell; the stale memoized value must NOT survive
        for _ in 0..10 {
            c.observe_prefill(2048, 0, 54, true, 1, base * 2.0);
        }
        let after = PerfPredictor::predict_prefill_layer(&c, 2048, 0, 54, true);
        assert!(after > cold, "calibration must show through the memo");
    }
}
