//! Performance estimator (§3.2): a profile-augmented analytical model.
//!
//! The analytical core is Eq. 2 — roofline with linear SM scaling and the
//! wave-quantization correction of Eq. 1.  Because the real hardware
//! (here: the `gpu::` simulator's hidden ground truth) scales
//! *non*-linearly with the SM fraction and exhibits inter-phase
//! contention, the analytical estimate alone is biased; offline profiling
//! (§3.2.2) measures a grid of configurations and the estimator stores
//! measured/analytic *ratios*, interpolated at prediction time, plus
//! fitted contention decay factors `p_c`/`p_b`.

pub mod estimator;
pub mod grid;
pub mod profiler;

pub use estimator::PerfModel;
pub use profiler::{profile, ProfileSpec};
