//! Performance estimator (§3.2): a profile-augmented analytical model,
//! optionally wrapped in a live calibration loop.
//!
//! The analytical core is Eq. 2 — roofline with linear SM scaling and the
//! wave-quantization correction of Eq. 1.  Because the real hardware
//! (here: the `gpu::` simulator's hidden ground truth) scales
//! *non*-linearly with the SM fraction and exhibits inter-phase
//! contention, the analytical estimate alone is biased; offline profiling
//! (§3.2.2) measures a grid of configurations and the estimator stores
//! measured/analytic *ratios*, interpolated at prediction time, plus
//! fitted contention decay factors `p_c`/`p_b`.
//!
//! Prediction is consumed through the [`PerfPredictor`] trait: the
//! scheduler and routers never name the concrete model.  [`PerfModel`]
//! is the frozen offline-profiled implementation;
//! [`online::OnlineCalibrator`] wraps it in a closed feedback loop that
//! ingests `(shape, partition, predicted, observed)` samples from the
//! serving engine and EWMA-corrects per-cell ratios at runtime —
//! covering what offline profiling cannot see (clock drift, co-tenant
//! interference, per-device variation, regime changes).

pub mod estimator;
pub mod grid;
pub mod online;
pub mod profiler;

pub use estimator::PerfModel;
pub use online::{CalibrationStats, OnlineCalibrator};
pub use profiler::{profile, ProfileSpec};

/// The prediction interface the scheduler and cluster routers consume
/// (§3.2's estimator role).  Implementations: the frozen offline
/// [`PerfModel`] and the feedback-driven [`OnlineCalibrator`].
pub trait PerfPredictor {
    /// Predicted time of one prefill LAYER over `sl` chunk tokens on
    /// `ctx` cached context with `pm` SMs.  `contended` = a decode step
    /// co-runs.
    fn predict_prefill_layer(&self, sl: usize, ctx: usize, pm: usize, contended: bool) -> f64;

    /// Predicted time of one decode ITERATION (all layers) of batch `bs`
    /// at mean context `cl` on `dm` SMs.
    fn predict_decode_step(&self, bs: usize, cl: usize, dm: usize, contended: bool) -> f64;

    /// Predicted remaining prefill time for `layers_left` layers.
    fn predict_prefill_remaining(
        &self,
        sl: usize,
        ctx: usize,
        pm: usize,
        layers_left: usize,
        contended: bool,
    ) -> f64 {
        self.predict_prefill_layer(sl, ctx, pm, contended) * layers_left as f64
    }

    /// Learned observed-vs-nominal slowdown of the device this predictor
    /// serves (sample-weighted; 1.0 for an uncalibrated model).  Cluster
    /// routers use this to rank heterogeneous replicas by *calibrated*
    /// speed rather than the shared offline grid.
    fn calibrated_slowdown(&self) -> f64 {
        1.0
    }

    /// The predictor's live calibration counters (identity for frozen
    /// models).  The cluster autoscaler reads residual, convergence and
    /// drift-event state through this — the signals that drive
    /// scale-out, retirement and re-profiling decisions.
    fn calibration(&self) -> CalibrationStats {
        CalibrationStats::default()
    }
}
