//! Property-testing mini-framework.
//!
//! `proptest` is not in the offline crate set, so this provides the 20%
//! that covers our needs: seeded generators, a `forall` runner with many
//! iterations, and input reporting on failure (no shrinking — failures
//! print the seed and generated case so they can be replayed exactly).
//!
//! ```ignore
//! prop::forall(1234, 500, |g| {
//!     let xs = g.vec(0..100, |g| g.f64_in(0.0, 1e3));
//!     let p = prop::percentile(&xs, 50.0);
//!     prop::check(p >= min && p <= max, format!("median out of range"))
//! });
//! ```

use crate::util::rng::Rng;

/// Generator handed to property bodies.
pub struct Gen {
    pub rng: Rng,
    /// Case index (for diagnostics).
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec<T>(&mut self, len_lo: usize, len_hi: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| f(self)).collect()
    }
}

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `body`; panics with seed + case index on
/// the first failure so the case can be replayed.
pub fn forall(seed: u64, cases: usize, mut body: impl FnMut(&mut Gen) -> CaseResult) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let rng = root.fork(case as u64);
        let mut g = Gen { rng, case };
        if let Err(msg) = body(&mut g) {
            panic!("property failed (seed={seed}, case={case}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(1, 100, |g| {
            count += 1;
            let x = g.f64_in(0.0, 1.0);
            check((0.0..1.0).contains(&x), "f64_in out of range")
        });
        assert_eq!(count, 100);
    }

    #[test]
    #[should_panic(expected = "property failed (seed=2, case=0)")]
    fn failing_property_reports_seed_and_case() {
        forall(2, 10, |_| check(false, "always fails"));
    }

    #[test]
    fn gen_ranges() {
        forall(3, 200, |g| {
            let u = g.usize_in(5, 10);
            check((5..=10).contains(&u), format!("usize_in gave {u}"))?;
            let v = g.vec(2, 4, |g| g.bool());
            check(v.len() >= 2 && v.len() <= 4, "vec len")
        });
    }

    #[test]
    fn same_seed_same_cases() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        forall(7, 20, |g| {
            a.push(g.u64_in(0, 1_000_000));
            Ok(())
        });
        forall(7, 20, |g| {
            b.push(g.u64_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(a, b);
    }
}
