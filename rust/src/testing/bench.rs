//! Bench harness for `harness = false` benches (criterion replacement).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! mean / p50 / p90 with adaptive batching for sub-microsecond bodies.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3} us/iter (p50 {:>10.3}, p90 {:>10.3}, min {:>10.3}, n={})",
            self.name,
            self.mean_s * 1e6,
            self.p50_s * 1e6,
            self.p90_s * 1e6,
            self.min_s * 1e6,
            self.iters
        )
    }
}

/// Time `f`, auto-batching so each sample spans >= 10 us.
pub fn bench(name: &str, target_samples: usize, mut f: impl FnMut()) -> BenchResult {
    // Warmup + batch size estimation.
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= 10e-6 || batch >= 1 << 20 {
            break;
        }
        batch *= 4;
    }
    let mut samples = Vec::with_capacity(target_samples);
    for _ in 0..target_samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    finalize(name, batch, samples)
}

/// Sort the raw samples and fold them into a [`BenchResult`].  The sort
/// is `total_cmp`: a degenerate sample (a zero-batch division or an
/// arithmetic NaN from a future harness change) must not panic the
/// whole bench binary mid-run.
fn finalize(name: &str, batch: usize, mut samples: Vec<f64>) -> BenchResult {
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: batch * samples.len(),
        mean_s: mean,
        p50_s: crate::util::stats::percentile_sorted(&samples, 50.0),
        p90_s: crate::util::stats::percentile_sorted(&samples, 90.0),
        min_s: samples[0],
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 10, || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(black_box(i * i));
            }
            black_box(acc);
        });
        assert!(r.mean_s > 0.0);
        assert!(r.p90_s >= r.p50_s);
        assert!(r.min_s <= r.mean_s * 1.5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn bench_timer_sort_tolerates_nan_samples() {
        // regression: the sample sort used to be
        // `partial_cmp().unwrap()`, so one NaN sample panicked the
        // whole bench run.  total_cmp ranks NaN at the top instead:
        // p50 of mostly-finite samples stays finite and min is real.
        let r = finalize("nan", 1, vec![3e-6, f64::NAN, 1e-6, 2e-6]);
        assert_eq!(r.min_s, 1e-6);
        assert!((r.p50_s - 2.5e-6).abs() < 1e-12);
        assert!(r.p90_s.is_nan(), "NaN ranks at the top percentile");
        assert!(r.report().contains("nan"));
    }
}
