//! In-tree testing substrates: a property-testing mini-framework
//! (`prop`) and a bench harness (`bench`) — replacements for proptest and
//! criterion, which are unavailable in the offline crate set.

pub mod bench;
pub mod prop;
