//! In-tree testing substrates: a property-testing mini-framework
//! (`prop`) and a bench harness (`bench`) — replacements for proptest and
//! criterion, which are unavailable in the offline crate set.

pub mod bench;
pub mod prop;

/// Chained per-block content hashes in the PRODUCTION scheme of
/// `workload::sessions` (hash `i` covers blocks `0..=i`): the one
/// helper every prefix-cache test and bench should build chains with,
/// so a change to the chaining scheme has a single point of truth.
/// Distinct `contents` values model distinct block contents.
pub fn content_chain(contents: &[u64]) -> Vec<u64> {
    crate::workload::sessions::chain_hashes(contents.iter().copied())
}
