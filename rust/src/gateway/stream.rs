//! Token streaming: per-request output chunks over in-tree mpsc
//! channels, plus the stream-quality statistics (TTFB, inter-chunk
//! gaps) the gateway reports.
//!
//! The engine core owns the [`std::sync::mpsc::Sender`] side (attached
//! at admission) and emits one [`StreamChunk`] per produced token; the
//! gateway holds the receiver in its connection table and drains it
//! after the run (virtual clock) or live (wall clock).  A terminal chunk
//! (`done == true`) is sent on every exit path — completion,
//! cancellation, expiry, or replica crash — so a client never waits on a
//! stream that will not produce.

/// One streamed output event for a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamChunk {
    pub id: u64,
    /// Instant the token (or terminal event) was produced, trace clock.
    pub t: f64,
    /// Cumulative output tokens produced so far, including this one.
    pub tokens_out: usize,
    /// Final chunk for this request: the stream is closed after it.
    pub done: bool,
}

/// Aggregate stream-quality statistics over a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StreamStats {
    /// Streams that produced at least one chunk.
    pub streams: usize,
    /// Total chunks across all streams.
    pub chunks: usize,
    /// Mean time-to-first-byte: first chunk time minus arrival, s.
    pub mean_ttfb: f64,
    /// Mean gap between consecutive chunks within a stream, s.
    pub mean_gap: f64,
    /// Largest observed intra-stream gap, s.
    pub max_gap: f64,
}

/// Compute stream statistics from `(arrival, chunks)` per stream.
/// Streams with no chunks are skipped; gaps need at least two chunks.
pub fn stream_stats(per_stream: &[(f64, Vec<StreamChunk>)]) -> StreamStats {
    let mut s = StreamStats::default();
    let mut ttfb_sum = 0.0;
    let mut gap_sum = 0.0;
    let mut gap_n = 0usize;
    for (arrival, chunks) in per_stream {
        if chunks.is_empty() {
            continue;
        }
        s.streams += 1;
        s.chunks += chunks.len();
        ttfb_sum += chunks[0].t - arrival;
        for w in chunks.windows(2) {
            let gap = w[1].t - w[0].t;
            gap_sum += gap;
            gap_n += 1;
            if gap > s.max_gap {
                s.max_gap = gap;
            }
        }
    }
    if s.streams > 0 {
        s.mean_ttfb = ttfb_sum / s.streams as f64;
    }
    if gap_n > 0 {
        s.mean_gap = gap_sum / gap_n as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(id: u64, t: f64, tokens_out: usize, done: bool) -> StreamChunk {
        StreamChunk { id, t, tokens_out, done }
    }

    #[test]
    fn empty_input_yields_zeroed_stats() {
        let s = stream_stats(&[]);
        assert_eq!(s, StreamStats::default());
        let s = stream_stats(&[(1.0, vec![])]);
        assert_eq!(s.streams, 0);
        assert_eq!(s.mean_ttfb, 0.0);
    }

    #[test]
    fn ttfb_and_gaps() {
        let per = vec![
            (0.0, vec![chunk(0, 0.5, 1, false), chunk(0, 0.7, 2, false), chunk(0, 1.3, 3, true)]),
            (1.0, vec![chunk(1, 1.1, 1, true)]),
        ];
        let s = stream_stats(&per);
        assert_eq!(s.streams, 2);
        assert_eq!(s.chunks, 4);
        // ttfb: (0.5 + 0.1) / 2
        assert!((s.mean_ttfb - 0.3).abs() < 1e-12, "ttfb {}", s.mean_ttfb);
        // gaps: 0.2 and 0.6 within stream 0 only
        assert!((s.mean_gap - 0.4).abs() < 1e-12, "gap {}", s.mean_gap);
        assert!((s.max_gap - 0.6).abs() < 1e-12, "max gap {}", s.max_gap);
    }
}
