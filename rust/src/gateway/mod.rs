//! Live serving gateway: the wall-clock front door over the simulated
//! fleet.
//!
//! Offline runs replay a trace as fast as the simulator can integrate
//! it.  The gateway instead walks the same trace as a *request
//! lifecycle*: each arrival is admitted at its instant on a pluggable
//! clock ([`GatewayClock`]), routed across replicas by the same
//! [`Dispatcher`] the cluster layer uses, streamed back token-by-token
//! over an in-tree mpsc channel ([`StreamChunk`]), and torn down on any
//! of four exits — completion, client cancellation (`Request::cancel_at`,
//! the disconnect model: KV blocks decref immediately, mid-decode),
//! deadline expiry (`Request::deadline`, enforced inside the engine
//! scheduler via [`crate::sched::deadline_should_drop`]), or replica
//! crash ([`FailureSpec`]).
//!
//! A crash rides the retire machinery from the autoscaling PR: the dead
//! replica leaves the eligible set, its prefix-affinity sessions re-home
//! through [`Dispatcher::unpin_replica`], orphans whose prefill never
//! started re-queue on a surviving replica (their streaming sink is
//! re-attached so the client keeps its connection), and in-flight work is
//! counted [`RequestOutcome::Lost`].  Accounting is total on every path:
//! `completed + cancelled + expired + lost == submitted`.
//!
//! Clock duality is the determinism story: [`VirtualClock`] teleports
//! between events, so the entire lifecycle — admission order, routing,
//! cancellation races, crash re-homing — is a pure function of
//! `(trace, seed, config)` and CI asserts it bitwise.  [`WallClock`]
//! sleeps to the same instants, turning the identical loop into a
//! real-time server without a single branch on the clock flavor.
//!
//! [`RequestOutcome::Lost`]: crate::metrics::RequestOutcome::Lost

pub mod clock;
pub mod stream;

pub use clock::{GatewayClock, VirtualClock, WallClock};
pub use stream::{stream_stats, StreamChunk, StreamStats};

pub use crate::cluster::FailureSpec;

use crate::baselines::System;
use crate::cluster::{replica_seed, Dispatcher, Replica, ReplicaSignals, RouterPolicy};
use crate::config::ServingConfig;
use crate::engine::core::{CoreOptions, EngineOutput};
use crate::gpu::roofline::GroundTruth;
use crate::metrics::timeline::{ScaleAction, ScaleEvent};
use crate::metrics::{
    merge_outcomes, merge_records, LifecycleStats, OutcomeRecord, RequestRecord,
};
use crate::perf::PerfModel;
use crate::workload::Request;
use std::collections::BTreeMap;
use std::sync::mpsc;

/// Gateway shape: fleet size, routing, failure schedule, and an optional
/// blanket deadline.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayConfig {
    /// Homogeneous replicas behind the front door.
    pub replicas: usize,
    pub router: RouterPolicy,
    /// Scheduled replica crashes, fired at their exact instants on the
    /// gateway clock (between arrivals if need be — a live front door
    /// does not wait for traffic to notice a dead machine).
    pub failures: Vec<FailureSpec>,
    /// Deadline applied to every request that does not carry its own:
    /// `arrival + default_deadline_s`.  `None` (default) adds nothing.
    pub default_deadline_s: Option<f64>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            failures: Vec::new(),
            default_deadline_s: None,
        }
    }
}

/// Everything a gateway run produces.
#[derive(Debug)]
pub struct GatewayOutput {
    /// Completed requests, id-ordered.
    pub records: Vec<RequestRecord>,
    /// Terminal events for requests that did not complete, id-ordered.
    pub outcomes: Vec<OutcomeRecord>,
    /// Per-outcome counters; `submitted()` equals the trace length.
    pub lifecycle: LifecycleStats,
    /// Aggregate stream-quality statistics (TTFB, inter-chunk gaps).
    pub stream: StreamStats,
    /// Every request's drained stream, `(id, chunks)` in admission order.
    pub streams: Vec<(u64, Vec<StreamChunk>)>,
    /// (request id, replica index) routing decisions, in event order
    /// (orphan re-routes append a second entry for the same id).
    pub assignments: Vec<(u64, usize)>,
    /// Per-replica engine outputs (replica index = vec index).
    pub per_replica: Vec<EngineOutput>,
    /// Crash events on the global timeline.
    pub scale_events: Vec<ScaleEvent>,
    /// Global makespan on the trace clock.
    pub virtual_duration: f64,
}

impl GatewayOutput {
    /// Fleet-wide SM-second attribution ledger (summed over replicas;
    /// per-replica ledgers are finalized, so the sum stays conserved).
    pub fn ledger(&self) -> crate::obs::SmLedger {
        let mut total = crate::obs::SmLedger::default();
        for o in &self.per_replica {
            total.merge(&o.ledger);
        }
        total
    }
}

/// One gateway event: a scheduled failure or a trace arrival.  Failures
/// sort before arrivals at the same instant — a request arriving exactly
/// at a crash must not route to the corpse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    Failure,
    Arrival,
}

/// Serve `trace` through the live gateway on `clock`.
///
/// The loop walks the merged (failure ∪ arrival) event list in time
/// order; per event it waits for the instant on the clock, advances
/// every non-drained replica to it (the same horizon barrier as the
/// cluster dispatch loop, so routing signals are live), then either
/// crashes the target replica or admits the request: route via
/// [`Dispatcher::pick_among`], attach a streaming sink, push.  With no
/// failures and no lifecycle annotations this is observationally the
/// cluster's serial dispatch loop plus a channel per request — routing
/// and records are bit-identical to [`crate::cluster::serve_cluster`].
#[allow(clippy::too_many_arguments)]
pub fn serve_gateway<C: GatewayClock>(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
    gw: &GatewayConfig,
    clock: &mut C,
) -> GatewayOutput {
    // blanket deadline for requests that carry none of their own
    let trace: Vec<Request> = trace
        .iter()
        .map(|r| {
            let mut r = r.clone();
            if r.deadline.is_none() {
                if let Some(d) = gw.default_deadline_s {
                    r.deadline = Some(r.arrival + d);
                }
            }
            r
        })
        .collect();

    let n = gw.replicas.max(1);
    let horizon = trace.last().map(|r| r.arrival).unwrap_or(0.0);
    let max_virtual_time = CoreOptions::default().max_virtual_time.max(4.0 * horizon);
    let mut replicas: Vec<Replica> = (0..n)
        .map(|i| Replica::new(i, system, cfg, perf, gt, replica_seed(seed, i), max_virtual_time))
        .collect();
    let mut signals: Vec<ReplicaSignals> = replicas.iter().map(Replica::signals).collect();
    let mut dispatcher = Dispatcher::new(gw.router);
    dispatcher.set_memo(cfg.memo);
    let mut eligible: Vec<usize> = (0..n).collect();
    let mut dead: Vec<bool> = vec![false; n];

    // merged event list: (t, kind, index into failures/trace)
    let mut failures = gw.failures.clone();
    failures.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.replica.cmp(&b.replica)));
    let mut events: Vec<(f64, EventKind, usize)> =
        Vec::with_capacity(failures.len() + trace.len());
    for (i, f) in failures.iter().enumerate() {
        events.push((f.at, EventKind::Failure, i));
    }
    for (i, r) in trace.iter().enumerate() {
        events.push((r.arrival, EventKind::Arrival, i));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));

    let mut assignments: Vec<(u64, usize)> = Vec::with_capacity(trace.len());
    let mut scale_events: Vec<ScaleEvent> = Vec::new();
    // connection table: receiver drained at teardown, sender clone kept
    // so an orphan re-homed by a crash keeps its stream
    let mut conns: Vec<(u64, f64, mpsc::Receiver<StreamChunk>)> = Vec::with_capacity(trace.len());
    let mut senders: BTreeMap<u64, mpsc::Sender<StreamChunk>> = BTreeMap::new();

    for (t, kind, i) in events {
        clock.wait_until(t);
        // horizon barrier: live routing signals at the event instant
        for r in replicas.iter_mut() {
            if !r.drained {
                r.advance_to(t);
                signals[r.id] = r.signals();
            }
        }
        match kind {
            EventKind::Failure => {
                let id = failures[i].replica;
                assert!(id < n, "failure injection names unknown replica {id}");
                if dead[id] {
                    continue; // double kill is a no-op
                }
                let orphans = replicas[id].crash(t);
                signals[id] = replicas[id].signals();
                dead[id] = true;
                eligible.retain(|&k| k != id);
                dispatcher.unpin_replica(id);
                assert!(
                    !eligible.is_empty(),
                    "failure injection killed the last live replica at t={t}"
                );
                scale_events.push(ScaleEvent {
                    t,
                    action: ScaleAction::Crash,
                    replica: id,
                    fleet_after: eligible.len(),
                });
                for o in orphans {
                    let k = dispatcher.pick_among(&signals, &eligible, &o, perf, &cfg.slo);
                    assignments.push((o.id, k));
                    // the client's connection survives the re-home
                    if let Some(tx) = senders.get(&o.id) {
                        replicas[k].attach_stream(o.id, tx.clone());
                    }
                    signals[k].note_push(&o);
                    replicas[k].push(o);
                }
            }
            EventKind::Arrival => {
                let r = &trace[i];
                let k = dispatcher.pick_among(&signals, &eligible, r, perf, &cfg.slo);
                assignments.push((r.id, k));
                let (tx, rx) = mpsc::channel();
                conns.push((r.id, r.arrival, rx));
                senders.insert(r.id, tx.clone());
                replicas[k].attach_stream(r.id, tx);
                signals[k].note_push(r);
                replicas[k].push(r.clone());
            }
        }
    }

    let mut per_replica: Vec<EngineOutput> =
        replicas.into_iter().map(Replica::finish).collect();
    for ev in &scale_events {
        per_replica[ev.replica].scale_events.push(*ev);
        per_replica[ev.replica].timeline.push_event(*ev);
    }
    // all engines are torn down: every sink has sent its terminal chunk
    drop(senders);
    let mut streams = Vec::with_capacity(conns.len());
    let mut per_stream = Vec::with_capacity(conns.len());
    for (id, arrival, rx) in conns {
        let chunks: Vec<StreamChunk> = rx.try_iter().collect();
        per_stream.push((arrival, chunks.clone()));
        streams.push((id, chunks));
    }
    let stream = stream_stats(&per_stream);

    let records = merge_records(per_replica.iter().map(|o| o.records.as_slice()));
    let outcomes = merge_outcomes(per_replica.iter().map(|o| o.outcomes.as_slice()));
    let lifecycle = LifecycleStats::from_parts(&records, &outcomes);
    let virtual_duration = per_replica
        .iter()
        .map(|o| o.virtual_duration)
        .fold(0.0, f64::max);
    GatewayOutput {
        records,
        outcomes,
        lifecycle,
        stream,
        streams,
        assignments,
        per_replica,
        scale_events,
        virtual_duration,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{serve_cluster, ClusterConfig};
    use crate::config::{GpuSpec, ModelSpec};
    use crate::workload::{annotate_lifecycle, generate_n_requests, Dataset, LifecycleProfile};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        (cfg, perf, gt)
    }

    #[test]
    fn inert_gateway_matches_the_cluster_bit_for_bit() {
        // no lifecycle annotations, no failures: the gateway is the
        // cluster's serial dispatch loop plus streaming channels
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 16, 41);
        let gw = GatewayConfig {
            replicas: 2,
            router: RouterPolicy::LeastKv,
            ..Default::default()
        };
        let mut clock = VirtualClock::new();
        let live = serve_gateway(System::Bullet, &cfg, &perf, &gt, &trace, 3, &gw, &mut clock);
        let ccfg = ClusterConfig {
            replicas: 2,
            router: RouterPolicy::LeastKv,
            sim_threads: 1,
            ..Default::default()
        };
        let off = serve_cluster(System::Bullet, &cfg, &perf, &gt, &trace, 3, &ccfg);
        assert_eq!(live.records, off.records);
        assert_eq!(live.assignments, off.assignments);
        assert_eq!(
            live.virtual_duration.to_bits(),
            off.virtual_duration.to_bits()
        );
        assert!(live.outcomes.is_empty());
        // every request streamed: a first-token chunk at minimum, and a
        // terminal chunk closing each stream
        assert_eq!(live.streams.len(), 16);
        for (id, chunks) in &live.streams {
            assert!(!chunks.is_empty(), "request {id} never streamed");
            assert!(chunks.last().unwrap().done, "request {id} stream left open");
        }
        assert_eq!(live.stream.streams, 16);
        assert!(live.stream.mean_ttfb > 0.0);
    }

    #[test]
    fn gateway_runs_are_deterministic_under_virtual_clock() {
        let (cfg, perf, gt) = setup();
        let mut trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 20, 43);
        annotate_lifecycle(&mut trace, &LifecycleProfile::cancellation_heavy(), 43);
        let mid = trace[10].arrival;
        let gw = GatewayConfig {
            replicas: 3,
            router: RouterPolicy::LeastKv,
            failures: vec![FailureSpec { replica: 2, at: mid }],
            default_deadline_s: Some(30.0),
        };
        let run = || {
            let mut clock = VirtualClock::new();
            serve_gateway(System::Bullet, &cfg, &perf, &gt, &trace, 7, &gw, &mut clock)
        };
        let a = run();
        let b = run();
        assert_eq!(a.records, b.records);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.streams, b.streams);
        assert_eq!(a.lifecycle, b.lifecycle);
        assert_eq!(a.lifecycle.submitted(), trace.len());
    }

    #[test]
    fn default_deadline_expires_slow_requests() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 20.0, 12, 47);
        let gw = GatewayConfig {
            replicas: 1,
            // far too tight for any prefill to finish
            default_deadline_s: Some(1e-6),
            ..Default::default()
        };
        let mut clock = VirtualClock::new();
        let out = serve_gateway(System::Bullet, &cfg, &perf, &gt, &trace, 5, &gw, &mut clock);
        assert_eq!(out.lifecycle.expired, 12, "{:?}", out.lifecycle);
        assert_eq!(out.records.len(), 0);
        // expiry still closes every stream with a terminal chunk
        for (id, chunks) in &out.streams {
            assert!(
                chunks.last().map(|c| c.done).unwrap_or(true),
                "request {id} stream left open"
            );
        }
        // and leaks nothing
        for o in &out.per_replica {
            assert_eq!(o.final_kv_blocks, 0);
        }
    }

    #[test]
    fn crash_between_arrivals_rehomes_and_accounts() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 14, 53);
        // crash strictly between two arrivals: the live gateway fires it
        // at its own instant, not at the next arrival horizon
        let at = (trace[6].arrival + trace[7].arrival) / 2.0;
        let gw = GatewayConfig {
            replicas: 2,
            router: RouterPolicy::RoundRobin,
            failures: vec![FailureSpec { replica: 0, at }],
            default_deadline_s: None,
        };
        let mut clock = VirtualClock::new();
        let out = serve_gateway(System::Bullet, &cfg, &perf, &gt, &trace, 11, &gw, &mut clock);
        assert_eq!(out.scale_events.len(), 1);
        assert_eq!(out.scale_events[0].action, ScaleAction::Crash);
        assert!((out.scale_events[0].t - at).abs() < 1e-12);
        let stats = out.lifecycle;
        assert_eq!(stats.submitted(), trace.len());
        // post-crash traffic all routes to the survivor
        for &(id, k) in &out.assignments {
            let r = trace.iter().find(|r| r.id == id).unwrap();
            if r.arrival > at {
                assert_eq!(k, 1, "request {id} routed to the dead replica");
            }
        }
        // the dead replica leaks nothing
        assert_eq!(out.per_replica[0].final_kv_blocks, 0);
    }
}
