//! Clock abstraction behind the gateway: one lifecycle code path, two
//! time sources.
//!
//! [`VirtualClock`] jumps instantly to each requested instant, so the
//! entire gateway — admission, streaming, cancellation, deadlines,
//! failure injection — runs bit-deterministically in CI.  [`WallClock`]
//! sleeps until the same instants on the host monotonic clock, turning
//! the identical event loop into a real-time front door.  Nothing above
//! this trait knows which one is driving.

use std::thread;
use std::time::{Duration, Instant};

/// Time source the gateway schedules lifecycle events against.  Times
/// are seconds from the gateway's epoch (trace t=0).
pub trait GatewayClock {
    /// Current time, seconds since epoch.
    fn now(&self) -> f64;
    /// Block (or jump) until at least `t`.  Must be monotone: calling
    /// with a `t` in the past returns immediately.
    fn wait_until(&mut self, t: f64);
}

/// Deterministic clock: `wait_until` teleports.  The default for tests,
/// CI, and every reproducibility assertion.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }
}

impl GatewayClock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }

    fn wait_until(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// Host monotonic clock: `wait_until` sleeps the calling thread.  Shares
/// every line of lifecycle logic with [`VirtualClock`].
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    /// Epoch is the moment of construction.
    pub fn new() -> WallClock {
        WallClock { t0: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> WallClock {
        WallClock::new()
    }
}

impl GatewayClock for WallClock {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn wait_until(&mut self, t: f64) {
        let target = self.t0 + Duration::from_secs_f64(t.max(0.0));
        if let Some(d) = target.checked_duration_since(Instant::now()) {
            thread::sleep(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_jumps_and_is_monotone() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.wait_until(2.5);
        assert_eq!(c.now(), 2.5);
        c.wait_until(1.0); // past: no-op
        assert_eq!(c.now(), 2.5);
    }

    #[test]
    fn wall_clock_advances_and_past_waits_return() {
        let mut c = WallClock::new();
        let a = c.now();
        c.wait_until(0.0); // already past — must not sleep
        c.wait_until(0.002);
        let b = c.now();
        assert!(b >= a, "wall clock went backwards: {a} -> {b}");
        assert!(b >= 0.002, "wait_until(0.002) returned at {b}");
    }
}
