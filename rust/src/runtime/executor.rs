//! Model runtime: the PJRT engine plus a host-side paged KV store —
//! prefill a prompt once, then decode batches over gathered caches.
//!
//! Layout choice: per sequence, K/V are stored token-major
//! (`[token][layer][kv_head][head_dim]`) so appending a decode step's new
//! vectors is a contiguous push; the gather into the engine's
//! `[layer][slot][kv_head][ctx][head_dim]` batch layout happens at
//! decode-call time (cheap at tiny-model scale, and exactly the job the
//! paper's KV manager does with block tables).

use crate::kvcache::KvPool;
use crate::runtime::pjrt::PjrtEngine;
use crate::util::error::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;

/// Per-sequence host KV cache.
#[derive(Debug, Clone, Default)]
struct SeqKv {
    /// tokens cached
    len: usize,
    /// [token][layer][kv][hd] appended contiguously
    k: Vec<f32>,
    v: Vec<f32>,
}

/// The serving-side model runtime.
pub struct ModelRuntime {
    pub engine: PjrtEngine,
    /// Block-accounting pool (capacity tracking, shared-pool semantics).
    pub pool: KvPool,
    store: HashMap<u64, SeqKv>,
}

impl ModelRuntime {
    pub fn load(dir: &Path, weight_seed: u64) -> Result<ModelRuntime> {
        let engine = PjrtEngine::load(dir, weight_seed)?;
        // capacity: enough blocks for ~64 concurrent max-context sequences
        let capacity_tokens = engine.meta.max_ctx * 64;
        Ok(ModelRuntime {
            engine,
            pool: KvPool::new(capacity_tokens),
            store: HashMap::new(),
        })
    }

    /// Max prompt length servable.
    pub fn max_prompt(&self) -> usize {
        *self.engine.meta.prefill_buckets.last().unwrap()
    }

    pub fn max_batch(&self) -> usize {
        *self.engine.meta.decode_buckets.last().unwrap()
    }

    pub fn ctx_len(&self, seq: u64) -> Option<usize> {
        self.store.get(&seq).map(|s| s.len)
    }

    /// Prefill a prompt, store its KV, return the first generated token.
    pub fn prefill(&mut self, seq: u64, tokens: &[i32]) -> Result<i32> {
        let m_layers = self.engine.meta.n_layers;
        let kvh = self.engine.meta.n_kv_heads;
        let hd = self.engine.meta.head_dim;
        let true_len = tokens.len();
        if true_len > self.max_prompt() {
            return Err(anyhow!("prompt too long: {true_len}"));
        }
        self.pool.grow(seq, true_len).map_err(|e| anyhow!("{e}"))?;
        let out = self.engine.prefill(tokens)?;
        // engine layout: [layer][kv][bucket][hd] -> ours [token][layer][kv][hd]
        let bucket = out.bucket;
        let mut kv = SeqKv {
            len: true_len,
            k: Vec::with_capacity(true_len * m_layers * kvh * hd),
            v: Vec::with_capacity(true_len * m_layers * kvh * hd),
        };
        for t in 0..true_len {
            for l in 0..m_layers {
                for h in 0..kvh {
                    let base = ((l * kvh + h) * bucket + t) * hd;
                    kv.k.extend_from_slice(&out.k_cache[base..base + hd]);
                    kv.v.extend_from_slice(&out.v_cache[base..base + hd]);
                }
            }
        }
        self.store.insert(seq, kv);
        Ok(out.first_token)
    }

    /// One decode iteration for `seqs` (each with its latest token).
    /// Returns the next token per sequence and appends KV.
    pub fn decode(&mut self, seqs: &[u64], tokens: &[i32]) -> Result<Vec<i32>> {
        assert_eq!(seqs.len(), tokens.len());
        let n = seqs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let meta = &self.engine.meta;
        let (layers, kvh, hd, max_ctx) = (meta.n_layers, meta.n_kv_heads, meta.head_dim, meta.max_ctx);
        let bucket = meta
            .decode_bucket(n)
            .ok_or_else(|| anyhow!("batch {n} too large"))?;

        // gather host caches into the engine's batch layout
        let cache_elems = layers * bucket * kvh * max_ctx * hd;
        let mut k_cache = vec![0.0f32; cache_elems];
        let mut v_cache = vec![0.0f32; cache_elems];
        let mut ctx_lens = vec![0i32; n];
        for (slot, &seq) in seqs.iter().enumerate() {
            let s = self
                .store
                .get(&seq)
                .ok_or_else(|| anyhow!("unknown sequence {seq}"))?;
            if s.len >= max_ctx {
                return Err(anyhow!("sequence {seq} exceeds max_ctx {max_ctx}"));
            }
            ctx_lens[slot] = s.len as i32;
            for t in 0..s.len {
                for l in 0..layers {
                    for h in 0..kvh {
                        let src = ((t * layers + l) * kvh + h) * hd;
                        let dst = ((((l * bucket + slot) * kvh + h) * max_ctx) + t) * hd;
                        k_cache[dst..dst + hd].copy_from_slice(&s.k[src..src + hd]);
                        v_cache[dst..dst + hd].copy_from_slice(&s.v[src..src + hd]);
                    }
                }
            }
        }

        let out = self.engine.decode(tokens, &ctx_lens, &k_cache, &v_cache)?;

        // append new KV ([layer][bucket][kv][hd]) and account a token
        for (slot, &seq) in seqs.iter().enumerate() {
            self.pool.grow(seq, 1).map_err(|e| anyhow!("{e}"))?;
            let s = self.store.get_mut(&seq).unwrap();
            for l in 0..layers {
                for h in 0..kvh {
                    let base = ((l * bucket + slot) * kvh + h) * hd;
                    s.k.extend_from_slice(&out.k_new[base..base + hd]);
                    s.v.extend_from_slice(&out.v_new[base..base + hd]);
                }
            }
            s.len += 1;
        }
        Ok(out.next_tokens[..n].to_vec())
    }

    /// Release a finished sequence.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        self.store.remove(&seq);
        self.pool.release(seq).map_err(|e| anyhow!("{e}"))
    }

    /// Greedy generation helper (used by tests and the quickstart):
    /// prefill + decode until `max_new` tokens.
    pub fn generate(&mut self, seq: u64, prompt: &[i32], max_new: usize) -> Result<Vec<i32>> {
        let first = self.prefill(seq, prompt)?;
        let mut out = vec![first];
        let mut cur = first;
        for _ in 1..max_new {
            let next = self.decode(&[seq], &[cur])?;
            cur = next[0];
            out.push(cur);
        }
        Ok(out)
    }
}
