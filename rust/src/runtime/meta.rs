//! artifacts/meta.json — the ABI between `python/compile/aot.py` and the
//! Rust runtime: model config, flattened weight order, shape buckets.

use crate::util::json::{self, Value};
use crate::util::error::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One weight tensor in the canonical flattened order.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl WeightSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed meta.json.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub max_ctx: usize,
    pub weights: Vec<WeightSpec>,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
    /// bucket -> artifact filename
    pub prefill_artifacts: Vec<(usize, String)>,
    pub decode_artifacts: Vec<(usize, String)>,
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Value::as_usize)
        .ok_or_else(|| anyhow!("meta.json: missing integer field '{key}'"))
}

impl ModelMeta {
    /// Load `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("parsing meta.json: {e}"))?;
        let cfg = v.get("config").ok_or_else(|| anyhow!("meta.json: no config"))?;

        let weights = v
            .get("weights")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("meta.json: no weights"))?
            .iter()
            .map(|w| {
                let name = w
                    .get("name")
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow!("weight without name"))?
                    .to_string();
                let shape = w
                    .get("shape")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| anyhow!("weight without shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<usize>>>()?;
                Ok(WeightSpec { name, shape })
            })
            .collect::<Result<Vec<_>>>()?;

        let buckets = |key: &str| -> Result<Vec<usize>> {
            v.get(key)
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("meta.json: no {key}"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad bucket")))
                .collect()
        };
        let artifacts = |key: &str| -> Result<Vec<(usize, String)>> {
            let obj = v
                .get(key)
                .and_then(Value::as_obj)
                .ok_or_else(|| anyhow!("meta.json: no {key}"))?;
            let mut out: Vec<(usize, String)> = obj
                .iter()
                .map(|(k, val)| {
                    let bucket: usize = k.parse().map_err(|_| anyhow!("bad bucket key {k}"))?;
                    let f = val.as_str().ok_or_else(|| anyhow!("bad artifact"))?;
                    Ok((bucket, f.to_string()))
                })
                .collect::<Result<Vec<_>>>()?;
            out.sort_unstable();
            Ok(out)
        };

        Ok(ModelMeta {
            dir: dir.to_path_buf(),
            vocab_size: req_usize(cfg, "vocab_size")?,
            d_model: req_usize(cfg, "d_model")?,
            n_layers: req_usize(cfg, "n_layers")?,
            n_heads: req_usize(cfg, "n_heads")?,
            n_kv_heads: req_usize(cfg, "n_kv_heads")?,
            head_dim: req_usize(cfg, "head_dim")?,
            ffn_dim: req_usize(cfg, "ffn_dim")?,
            max_ctx: req_usize(cfg, "max_ctx")?,
            weights,
            prefill_buckets: buckets("prefill_buckets")?,
            decode_buckets: buckets("decode_buckets")?,
            prefill_artifacts: artifacts("prefill_artifacts")?,
            decode_artifacts: artifacts("decode_artifacts")?,
        })
    }

    /// Default artifacts directory: `$BULLET_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("BULLET_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest prefill bucket that fits `n` tokens.
    pub fn prefill_bucket(&self, n: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= n)
    }

    /// Smallest decode bucket that fits a batch of `n`.
    pub fn decode_bucket(&self, n: usize) -> Option<usize> {
        self.decode_buckets.iter().copied().find(|&b| b >= n)
    }

    /// KV floats per token (one layer set: L * kv_heads * head_dim).
    pub fn kv_floats_per_token(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.head_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if d.join("meta.json").exists() {
            Some(d)
        } else {
            None
        }
    }

    #[test]
    fn loads_real_meta() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let m = ModelMeta::load(&dir).unwrap();
        assert_eq!(m.n_layers, 4);
        assert_eq!(m.head_dim, 32);
        assert_eq!(m.weights.len(), 1 + 9 * m.n_layers + 2);
        assert_eq!(m.weights[0].name, "embed");
        assert_eq!(m.weights[0].shape, vec![m.vocab_size, m.d_model]);
        assert!(m.prefill_buckets.contains(&128));
        assert!(m.decode_buckets.contains(&8));
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipped: artifacts not built");
            return;
        };
        let m = ModelMeta::load(&dir).unwrap();
        assert_eq!(m.prefill_bucket(1), Some(16));
        assert_eq!(m.prefill_bucket(16), Some(16));
        assert_eq!(m.prefill_bucket(17), Some(32));
        assert_eq!(m.prefill_bucket(1000), None);
        assert_eq!(m.decode_bucket(3), Some(4));
    }

    #[test]
    fn missing_dir_errors() {
        let err = ModelMeta::load(Path::new("/nonexistent-bullet")).unwrap_err();
        assert!(err.to_string().contains("meta.json"));
    }
}
