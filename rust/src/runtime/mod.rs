//! PJRT runtime: load the AOT artifacts produced by `make artifacts` and
//! execute the real (tiny) model from the Rust request path.  Python is
//! never involved at serving time — the HLO text files plus meta.json are
//! the complete model.

pub mod executor;
pub mod meta;
pub mod pjrt;
pub mod weights;

pub use executor::ModelRuntime;
pub use meta::ModelMeta;
pub use pjrt::PjrtEngine;
