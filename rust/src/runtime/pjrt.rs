//! PJRT engine: compile the HLO-text artifacts once, keep the weights
//! resident as device buffers, execute prefill/decode steps.
//!
//! HLO **text** is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The real engine needs the vendored `xla` bindings, which are not part
//! of the default (offline, dependency-free) build.  It is gated behind
//! the `pjrt` cargo feature; without it a stub with the same API loads
//! nothing and returns a descriptive error, so the simulation stack —
//! every paper experiment — builds and runs everywhere.

#[cfg(not(feature = "pjrt"))]
use crate::runtime::meta::ModelMeta;
#[cfg(not(feature = "pjrt"))]
use crate::util::error::Result;
#[cfg(not(feature = "pjrt"))]
use std::path::Path;

/// Result of a prefill call.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    pub first_token: i32,
    /// Post-RoPE keys, [n_layers, n_kv_heads, bucket, head_dim] row-major.
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    /// The bucket the call was padded to.
    pub bucket: usize,
}

/// Result of a decode call.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    pub next_tokens: Vec<i32>,
    /// New keys, [n_layers, bucket, n_kv_heads, head_dim] row-major.
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    pub bucket: usize,
}

#[cfg(feature = "pjrt")]
mod real {
    use super::{DecodeOut, PrefillOut};
    use crate::runtime::meta::ModelMeta;
    use crate::runtime::weights;
    use crate::util::error::{anyhow, Context, Result};
    use std::collections::BTreeMap;
    use std::path::Path;

    /// Compiled model + resident weights.
    pub struct PjrtEngine {
        pub meta: ModelMeta,
        client: xla::PjRtClient,
        prefill_exe: BTreeMap<usize, xla::PjRtLoadedExecutable>,
        decode_exe: BTreeMap<usize, xla::PjRtLoadedExecutable>,
        weight_bufs: Vec<xla::PjRtBuffer>,
    }

    impl PjrtEngine {
        /// Load artifacts from `dir`, compile every bucket, generate and
        /// upload weights (seeded).
        pub fn load(dir: &Path, weight_seed: u64) -> Result<PjrtEngine> {
            let meta = ModelMeta::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;

            let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
            };

            let mut prefill_exe = BTreeMap::new();
            for (bucket, file) in &meta.prefill_artifacts {
                prefill_exe.insert(*bucket, compile(file).context("prefill artifact")?);
            }
            let mut decode_exe = BTreeMap::new();
            for (bucket, file) in &meta.decode_artifacts {
                decode_exe.insert(*bucket, compile(file).context("decode artifact")?);
            }

            // Weights: generate deterministically, upload once.
            let host = weights::generate_all(&meta, weight_seed);
            let mut weight_bufs = Vec::with_capacity(host.len());
            for (spec, data) in meta.weights.iter().zip(&host) {
                let buf = client
                    .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                    .map_err(|e| anyhow!("uploading weight {}: {e:?}", spec.name))?;
                weight_bufs.push(buf);
            }

            Ok(PjrtEngine {
                meta,
                client,
                prefill_exe,
                decode_exe,
                weight_bufs,
            })
        }

        fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer::<i32>(data, dims, None)
                .map_err(|e| anyhow!("upload i32: {e:?}"))
        }

        fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer::<f32>(data, dims, None)
                .map_err(|e| anyhow!("upload f32: {e:?}"))
        }

        /// Run prefill on a prompt (<= largest bucket tokens).
        pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
            let true_len = tokens.len();
            let bucket = self
                .meta
                .prefill_bucket(true_len)
                .ok_or_else(|| anyhow!("prompt of {true_len} tokens exceeds largest bucket"))?;
            let exe = &self.prefill_exe[&bucket];

            let mut padded = tokens.to_vec();
            padded.resize(bucket, 0);
            let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
            let tok_buf = self.upload_i32(&padded, &[bucket])?;
            let len_buf = self.upload_i32(&[true_len as i32], &[])?;
            args.push(&tok_buf);
            args.push(&len_buf);

            let out = exe
                .execute_b(&args)
                .map_err(|e| anyhow!("prefill execute: {e:?}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("prefill literal: {e:?}"))?;
            let (t, k, v) = lit
                .to_tuple3()
                .map_err(|e| anyhow!("prefill tuple: {e:?}"))?;
            Ok(PrefillOut {
                first_token: t
                    .get_first_element::<i32>()
                    .map_err(|e| anyhow!("first token: {e:?}"))?,
                k_cache: k.to_vec::<f32>().map_err(|e| anyhow!("k: {e:?}"))?,
                v_cache: v.to_vec::<f32>().map_err(|e| anyhow!("v: {e:?}"))?,
                bucket,
            })
        }

        /// Run one decode iteration.
        ///
        /// `tokens`/`ctx_lens`: one entry per live sequence (<= largest
        /// bucket).  `k_cache`/`v_cache`: [n_layers, bucket, n_kv, max_ctx,
        /// hd] padded arrays for the *bucketed* batch (caller pads slots).
        pub fn decode(
            &self,
            tokens: &[i32],
            ctx_lens: &[i32],
            k_cache: &[f32],
            v_cache: &[f32],
        ) -> Result<DecodeOut> {
            let n = tokens.len();
            assert_eq!(n, ctx_lens.len());
            let bucket = self
                .meta
                .decode_bucket(n)
                .ok_or_else(|| anyhow!("decode batch {n} exceeds largest bucket"))?;
            let exe = &self.decode_exe[&bucket];
            let m = &self.meta;
            let cache_elems = m.n_layers * bucket * m.n_kv_heads * m.max_ctx * m.head_dim;
            assert_eq!(k_cache.len(), cache_elems, "k_cache shape mismatch");
            assert_eq!(v_cache.len(), cache_elems, "v_cache shape mismatch");

            let mut tok = tokens.to_vec();
            tok.resize(bucket, 0);
            let mut cls = ctx_lens.to_vec();
            cls.resize(bucket, 0);

            let cache_dims = [m.n_layers, bucket, m.n_kv_heads, m.max_ctx, m.head_dim];
            let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
            let tok_buf = self.upload_i32(&tok, &[bucket])?;
            let cls_buf = self.upload_i32(&cls, &[bucket])?;
            let k_buf = self.upload_f32(k_cache, &cache_dims)?;
            let v_buf = self.upload_f32(v_cache, &cache_dims)?;
            args.push(&tok_buf);
            args.push(&cls_buf);
            args.push(&k_buf);
            args.push(&v_buf);

            let out = exe
                .execute_b(&args)
                .map_err(|e| anyhow!("decode execute: {e:?}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("decode literal: {e:?}"))?;
            let (t, k, v) = lit
                .to_tuple3()
                .map_err(|e| anyhow!("decode tuple: {e:?}"))?;
            Ok(DecodeOut {
                next_tokens: t.to_vec::<i32>().map_err(|e| anyhow!("tokens: {e:?}"))?,
                k_new: k.to_vec::<f32>().map_err(|e| anyhow!("k_new: {e:?}"))?,
                v_new: v.to_vec::<f32>().map_err(|e| anyhow!("v_new: {e:?}"))?,
                bucket,
            })
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::PjrtEngine;

/// Stub engine for dependency-free builds: same API, `load` always fails.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtEngine {
    pub meta: ModelMeta,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtEngine {
    pub fn load(_dir: &Path, _weight_seed: u64) -> Result<PjrtEngine> {
        Err(crate::anyhow!(
            "built without the `pjrt` feature: live mode needs the vendored \
             xla bindings — add them as a path dependency in rust/Cargo.toml \
             (see the [features] comment there), then build with \
             --features pjrt"
        ))
    }

    pub fn prefill(&self, _tokens: &[i32]) -> Result<PrefillOut> {
        Err(crate::anyhow!("pjrt feature disabled"))
    }

    pub fn decode(
        &self,
        _tokens: &[i32],
        _ctx_lens: &[i32],
        _k_cache: &[f32],
        _v_cache: &[f32],
    ) -> Result<DecodeOut> {
        Err(crate::anyhow!("pjrt feature disabled"))
    }
}
