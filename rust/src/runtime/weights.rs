//! Deterministic weight generation.
//!
//! No pretrained checkpoint is available offline, so the served model's
//! weights are generated in Rust — normal(0, 0.05) for projections, ones
//! for norm gains, exactly mirroring `python/compile/model.py::init_params`
//! in *distribution* (values differ; only shapes are ABI).  A fixed seed
//! makes every serving run reproducible.

use crate::runtime::meta::{ModelMeta, WeightSpec};
use crate::util::rng::Rng;

/// Scale used for non-norm weights (matches the python init).
pub const WEIGHT_SCALE: f32 = 0.05;

/// Generate one weight tensor.
pub fn generate_weight(spec: &WeightSpec, rng: &mut Rng) -> Vec<f32> {
    let n = spec.elements();
    if spec.name.ends_with("norm") {
        vec![1.0; n]
    } else {
        (0..n).map(|_| WEIGHT_SCALE * rng.normal() as f32).collect()
    }
}

/// Generate the full flattened weight list in meta order.
pub fn generate_all(meta: &ModelMeta, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed ^ 0x77E16475);
    meta.weights
        .iter()
        .map(|w| {
            let mut sub = rng.fork(0);
            generate_weight(w, &mut sub)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> WeightSpec {
        WeightSpec {
            name: name.into(),
            shape: shape.to_vec(),
        }
    }

    #[test]
    fn norm_weights_are_ones() {
        let mut rng = Rng::new(1);
        let w = generate_weight(&spec("layer0.attn_norm", &[64]), &mut rng);
        assert_eq!(w, vec![1.0; 64]);
    }

    #[test]
    fn projection_weights_scaled_normal() {
        let mut rng = Rng::new(2);
        let w = generate_weight(&spec("layer0.wq", &[256, 256]), &mut rng);
        assert_eq!(w.len(), 256 * 256);
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        let std: f32 =
            (w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32).sqrt();
        assert!(mean.abs() < 0.005, "mean {mean}");
        assert!((std - WEIGHT_SCALE).abs() < 0.005, "std {std}");
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        let s = spec("embed", &[100, 10]);
        assert_eq!(generate_weight(&s, &mut a), generate_weight(&s, &mut b));
    }
}
