//! Content-addressed prefix cache over the paged KV pool.
//!
//! Requests carry a *chained* per-block content-hash of their prompt
//! (`workload::Request::block_hashes`): entry `i` hashes blocks `0..=i`,
//! so two prompts share a chain prefix exactly as far as their token
//! contents agree, and a single map probe per block implements
//! block-granularity longest-prefix match — the admission fast path.
//!
//! Lifecycle:
//! - **lookup** (admission): walk the chain until the first miss; the
//!   caller adopts the matched blocks via [`crate::kvcache::KvPool::adopt`]
//!   and charges only the uncached suffix to the prefill compute model.
//!   The match is capped below the full prompt — the last token must
//!   always be recomputed to produce the first output logits.
//! - **insert** (prefill completion): the prompt's full blocks are
//!   published under their chain hashes; the index takes a reference
//!   ([`crate::kvcache::KvPool::incref`]) so the blocks outlive the
//!   request.
//! - **evict** (memory pressure): least-recently-used blocks whose only
//!   remaining reference is the index are dropped until the requested
//!   room exists — the *evict* side of the evict-vs-recompute hook
//!   (`EngineCore::kv_room` implements the recompute side).
//!
//! Determinism: `BTreeMap` storage, a logical LRU clock, and
//! `(last_used, hash)`-ordered eviction make every operation a pure
//! function of the call sequence.

use crate::kvcache::{KvPool, BLOCK_TOKENS};
use std::collections::BTreeMap;

/// Run-level prefix-cache counters (reported in `EngineOutput`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixStats {
    /// Lookups for cacheable (hash-carrying) requests.
    pub lookups: u64,
    /// Lookups that matched at least one block.
    pub hits: u64,
    /// Total blocks served from cache.
    pub hit_blocks: u64,
    /// Prefill tokens skipped via cached prefixes (block granularity).
    pub cached_tokens: u64,
    /// Prompt tokens across all looked-up requests (ratio denominator).
    pub prompt_tokens: u64,
    /// Blocks newly published to the index.
    pub insertions: u64,
    /// Blocks dropped under memory pressure.
    pub evictions: u64,
    /// Adoptions revoked by the recompute path (`EngineCore::kv_room`):
    /// the hit was counted, but the tokens were prefilled after all.
    pub dropped_adoptions: u64,
    /// Cached tokens un-adopted by the recompute path.
    pub dropped_tokens: u64,
    /// Blocks published at CHUNK boundaries, before their prompt's
    /// prefill completed (chunked/NanoFlow mid-prompt publication).
    pub partial_insertions: u64,
    /// Hits that matched at least one chunk-boundary-published block —
    /// reuse that full-prompt-only publication would have missed.
    pub partial_hits: u64,
}

impl PrefixStats {
    /// Fraction of cacheable requests that hit at least one block.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    /// Fraction of looked-up prompt tokens served from cache.
    pub fn cached_token_ratio(&self) -> f64 {
        if self.prompt_tokens == 0 {
            0.0
        } else {
            self.cached_tokens as f64 / self.prompt_tokens as f64
        }
    }

    /// Prefill tokens actually skipped: cached at admission MINUS the
    /// adoptions the recompute path revoked under memory pressure.
    pub fn tokens_saved(&self) -> u64 {
        self.cached_tokens.saturating_sub(self.dropped_tokens)
    }

    /// Field-wise accumulate (cluster-level aggregation).
    pub fn merge(&mut self, o: &PrefixStats) {
        self.lookups += o.lookups;
        self.hits += o.hits;
        self.hit_blocks += o.hit_blocks;
        self.cached_tokens += o.cached_tokens;
        self.prompt_tokens += o.prompt_tokens;
        self.insertions += o.insertions;
        self.evictions += o.evictions;
        self.dropped_adoptions += o.dropped_adoptions;
        self.dropped_tokens += o.dropped_tokens;
        self.partial_insertions += o.partial_insertions;
        self.partial_hits += o.partial_hits;
    }
}

#[derive(Debug, Clone, Copy)]
struct CachedBlock {
    block: usize,
    last_used: u64,
    /// Position in its content chain (content-determined, so identical
    /// across re-inserts).  Eviction frees deep (leaf) blocks first: a
    /// chain is only reachable up to its first gap, so evicting a head
    /// block would strand every cached block behind it.
    depth: u32,
    /// Published at a chunk boundary, before its prompt finished
    /// prefilling (provenance for the `partial_hits` counter).
    partial: bool,
}

/// The content-hash prefix index (see module docs).
#[derive(Debug, Default)]
pub struct PrefixIndex {
    /// chain hash → cached physical block.
    map: BTreeMap<u64, CachedBlock>,
    /// Logical LRU clock (bumped per lookup/insert).
    clock: u64,
    stats: PrefixStats,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Cached blocks currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// Physical blocks the index holds references on (test/introspection).
    pub fn cached_block_ids(&self) -> Vec<usize> {
        self.map.values().map(|cb| cb.block).collect()
    }

    /// Longest-prefix match for a prompt of `prompt_tokens` tokens:
    /// walks `chain` until the first miss and returns the matched
    /// physical blocks in token order.  Capped so at least one prompt
    /// token is left to prefill (the logits token).  Touches matched
    /// blocks for LRU.
    pub fn lookup(&mut self, chain: &[u64], prompt_tokens: usize) -> Vec<usize> {
        self.clock += 1;
        self.stats.lookups += 1;
        self.stats.prompt_tokens += prompt_tokens as u64;
        let max_blocks = prompt_tokens.saturating_sub(1) / BLOCK_TOKENS;
        let mut out = Vec::new();
        let mut touched_partial = false;
        for h in chain.iter().take(max_blocks) {
            match self.map.get_mut(h) {
                Some(cb) => {
                    cb.last_used = self.clock;
                    touched_partial |= cb.partial;
                    out.push(cb.block);
                }
                None => break,
            }
        }
        if !out.is_empty() {
            self.stats.hits += 1;
            self.stats.hit_blocks += out.len() as u64;
            self.stats.cached_tokens += (out.len() * BLOCK_TOKENS) as u64;
            if touched_partial {
                self.stats.partial_hits += 1;
            }
        }
        out
    }

    /// Publish a finished prefill's full prompt blocks under their chain
    /// hashes.  Blocks new to the index are pinned with an extra pool
    /// reference; hashes already present keep their existing copy (its
    /// recency is refreshed — and any chunk-boundary `partial` tag is
    /// cleared, since from this instant full-prompt-only publication
    /// would serve the same hits).
    pub fn insert(&mut self, pool: &mut KvPool, chain: &[u64], blocks: &[usize]) {
        self.insert_inner(pool, chain, blocks, 0, false);
    }

    /// Publish blocks a still-running prefill has computed so far (chunk
    /// boundaries).  `chain`/`blocks` are a DELTA starting at chain
    /// position `depth0`, so each boundary publishes only its newly
    /// computed blocks.  New blocks are tagged so hits they enable are
    /// attributable (`PrefixStats::partial_hits`) until the eventual
    /// full-prompt insert clears the tag.
    pub fn insert_partial(
        &mut self,
        pool: &mut KvPool,
        chain: &[u64],
        blocks: &[usize],
        depth0: usize,
    ) {
        self.insert_inner(pool, chain, blocks, depth0, true);
    }

    fn insert_inner(
        &mut self,
        pool: &mut KvPool,
        chain: &[u64],
        blocks: &[usize],
        depth0: usize,
        partial: bool,
    ) {
        debug_assert_eq!(chain.len(), blocks.len());
        self.clock += 1;
        for (i, (h, &b)) in chain.iter().zip(blocks).enumerate() {
            match self.map.get_mut(h) {
                Some(cb) => {
                    cb.last_used = self.clock;
                    if !partial {
                        cb.partial = false;
                    }
                }
                None => {
                    pool.incref(b);
                    self.map.insert(
                        *h,
                        CachedBlock {
                            block: b,
                            last_used: self.clock,
                            depth: (depth0 + i) as u32,
                            partial,
                        },
                    );
                    self.stats.insertions += 1;
                    if partial {
                        self.stats.partial_insertions += 1;
                    }
                }
            }
        }
    }

    /// Record that `EngineCore::kv_room`'s recompute path revoked an
    /// adoption of `tokens` cached tokens (the hit stands in the
    /// counters, but the tokens were not actually saved).
    pub fn note_dropped_adoption(&mut self, tokens: usize) {
        self.stats.dropped_adoptions += 1;
        self.stats.dropped_tokens += tokens as u64;
    }

    /// Evict least-recently-used blocks whose ONLY remaining reference
    /// is the index, until `need_blocks` have been freed or candidates
    /// run out.  Returns the number freed.  Blocks also referenced by a
    /// live sequence are never touched.  Among equally-recent blocks the
    /// DEEPEST chain positions go first (leaf-first, as radix-tree
    /// caches do): lookups stop at the first gap, so evicting a head
    /// block would strand every cached block behind it.
    pub fn evict_lru(&mut self, pool: &mut KvPool, need_blocks: usize) -> usize {
        if need_blocks == 0 || self.map.is_empty() {
            return 0;
        }
        let mut candidates: Vec<(u64, std::cmp::Reverse<u32>, u64, usize)> = self
            .map
            .iter()
            .filter(|(_, cb)| pool.refcount(cb.block) == 1)
            .map(|(h, cb)| (cb.last_used, std::cmp::Reverse(cb.depth), *h, cb.block))
            .collect();
        candidates.sort_unstable();
        let mut freed = 0;
        for (_, _, h, b) in candidates {
            if freed >= need_blocks {
                break;
            }
            self.map.remove(&h);
            pool.decref(b); // last reference → block returns to the pool
            freed += 1;
            self.stats.evictions += 1;
        }
        freed
    }

    /// Drop every cached block (test/teardown helper).
    pub fn clear(&mut self, pool: &mut KvPool) {
        for (_, cb) in std::mem::take(&mut self.map) {
            pool.decref(cb.block);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::testing::content_chain as chain;

    /// Grow a seq of `blocks` FULL blocks and publish it.
    fn seed_entry(pool: &mut KvPool, ix: &mut PrefixIndex, id: u64, contents: &[u64]) -> Vec<usize> {
        pool.grow(id, contents.len() * BLOCK_TOKENS).unwrap();
        let blocks = pool.get(id).unwrap().blocks.clone();
        ix.insert(pool, &chain(contents), &blocks);
        blocks
    }

    #[test]
    fn longest_prefix_match_stops_at_divergence() {
        let mut pool = KvPool::new(64 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        let blocks = seed_entry(&mut pool, &mut ix, 1, &[10, 11, 12, 13]);
        // same first two blocks, divergent third
        let probe = chain(&[10, 11, 99, 13]);
        let m = ix.lookup(&probe, 1024);
        assert_eq!(m, blocks[..2].to_vec());
        // full match when contents agree
        let m = ix.lookup(&chain(&[10, 11, 12, 13]), 1024);
        assert_eq!(m, blocks);
        assert_eq!(ix.stats().hits, 2);
    }

    #[test]
    fn lookup_never_caches_the_full_prompt() {
        let mut pool = KvPool::new(64 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        seed_entry(&mut pool, &mut ix, 1, &[1, 2, 3]);
        // prompt of exactly 3 blocks: at most 2 may come from cache
        let m = ix.lookup(&chain(&[1, 2, 3]), 3 * BLOCK_TOKENS);
        assert_eq!(m.len(), 2);
        // one extra token → all 3 cached blocks usable
        let m = ix.lookup(&chain(&[1, 2, 3]), 3 * BLOCK_TOKENS + 1);
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn cached_blocks_survive_release_until_evicted() {
        let mut pool = KvPool::new(8 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        seed_entry(&mut pool, &mut ix, 1, &[7, 8]);
        pool.release(1).unwrap();
        assert_eq!(pool.used_blocks(), 2, "index pins the blocks");
        let m = ix.lookup(&chain(&[7, 8]), 1024);
        assert_eq!(m.len(), 2);
        let freed = ix.evict_lru(&mut pool, 2);
        assert_eq!(freed, 2);
        assert_eq!(pool.used_blocks(), 0);
        assert!(ix.lookup(&chain(&[7, 8]), 1024).is_empty());
    }

    #[test]
    fn eviction_is_lru_ordered_and_skips_live_blocks() {
        let mut pool = KvPool::new(16 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        let old = seed_entry(&mut pool, &mut ix, 1, &[1, 2]);
        let hot = seed_entry(&mut pool, &mut ix, 2, &[3, 4]);
        pool.release(1).unwrap();
        pool.release(2).unwrap();
        // touch the second entry so the first is LRU
        ix.lookup(&chain(&[3, 4]), 1024);
        let freed = ix.evict_lru(&mut pool, 2);
        assert_eq!(freed, 2);
        // the cold entry went, the hot one survived
        assert!(ix.lookup(&chain(&[1, 2]), 1024).is_empty());
        assert_eq!(ix.lookup(&chain(&[3, 4]), 1024), hot);
        assert!(old.iter().all(|&b| pool.refcount(b) == 0));
        // live (sequence-held) blocks are never evicted
        let live = seed_entry(&mut pool, &mut ix, 3, &[5, 6]);
        let freed = ix.evict_lru(&mut pool, 100);
        assert!(freed >= 2, "only unreferenced blocks evictable, freed {freed}");
        assert_eq!(ix.lookup(&chain(&[5, 6]), 1024), live);
        assert!(pool.contains(3));
    }

    #[test]
    fn eviction_frees_leaf_blocks_before_chain_heads() {
        let mut pool = KvPool::new(16 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        let blocks = seed_entry(&mut pool, &mut ix, 1, &[1, 2, 3]);
        pool.release(1).unwrap();
        // equally-recent blocks: the deepest goes first, so the chain
        // head survives and still serves a (shorter) hit
        assert_eq!(ix.evict_lru(&mut pool, 1), 1);
        let m = ix.lookup(&chain(&[1, 2, 3]), 1024);
        assert_eq!(m, blocks[..2].to_vec(), "head of the chain must remain reachable");
    }

    #[test]
    fn insert_is_idempotent_for_existing_hashes() {
        let mut pool = KvPool::new(16 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        let first = seed_entry(&mut pool, &mut ix, 1, &[9, 10]);
        // a second identical prompt publishes nothing new
        pool.grow(2, 2 * BLOCK_TOKENS).unwrap();
        let dup_blocks = pool.get(2).unwrap().blocks.clone();
        ix.insert(&mut pool, &chain(&[9, 10]), &dup_blocks);
        assert_eq!(ix.len(), 2);
        assert_eq!(ix.stats().insertions, 2);
        // the index still serves the FIRST copy
        assert_eq!(ix.lookup(&chain(&[9, 10]), 1024), first);
        // and the duplicate's own blocks free normally
        pool.release(2).unwrap();
        assert!(dup_blocks.iter().all(|&b| pool.refcount(b) == 0));
    }

    #[test]
    fn partial_publication_is_tagged_and_idempotent() {
        let mut pool = KvPool::new(16 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        // mid-prefill publications: blocks [0,2) then the delta [2,3)
        pool.grow(1, 4 * BLOCK_TOKENS).unwrap();
        let blocks = pool.get(1).unwrap().blocks.clone();
        let full_chain = chain(&[1, 2, 3, 4]);
        ix.insert_partial(&mut pool, &full_chain[..2], &blocks[..2], 0);
        ix.insert_partial(&mut pool, &full_chain[2..3], &blocks[2..3], 2);
        assert_eq!(ix.stats().partial_insertions, 3);
        // a mid-prompt arrival hits the partial blocks — and is counted
        let m = ix.lookup(&full_chain, 4 * BLOCK_TOKENS + 8);
        assert_eq!(m, blocks[..3].to_vec());
        assert_eq!(ix.stats().partial_hits, 1);
        // the full publish at prefill completion adds only the tail and
        // CLEARS the partial tags — later hits are served identically
        // by full-prompt-only publication, so they are not "extra"
        ix.insert(&mut pool, &full_chain, &blocks);
        assert_eq!(ix.len(), 4);
        assert_eq!(ix.stats().insertions, 4);
        assert_eq!(ix.stats().partial_insertions, 3, "tail block is not partial");
        let m = ix.lookup(&full_chain, 4 * BLOCK_TOKENS + 8);
        assert_eq!(m.len(), 4);
        assert_eq!(ix.stats().partial_hits, 1, "post-completion hits are not partial");
        // leaf-first eviction still sees delta-published depths: the
        // deepest block goes first, the chain head stays reachable
        pool.release(1).unwrap();
        assert_eq!(ix.evict_lru(&mut pool, 1), 1);
        let m = ix.lookup(&full_chain, 4 * BLOCK_TOKENS + 8);
        assert_eq!(m, blocks[..3].to_vec(), "head of the chain must remain reachable");
    }

    #[test]
    fn stats_track_ratio_and_rate() {
        let mut pool = KvPool::new(16 * BLOCK_TOKENS);
        let mut ix = PrefixIndex::new();
        seed_entry(&mut pool, &mut ix, 1, &[1, 2]);
        ix.lookup(&chain(&[1, 2]), 3 * BLOCK_TOKENS); // hit: 2 blocks of 3
        ix.lookup(&chain(&[42]), 2 * BLOCK_TOKENS); // miss
        let s = ix.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.cached_tokens, 2 * BLOCK_TOKENS as u64);
        assert_eq!(s.prompt_tokens, 5 * BLOCK_TOKENS as u64);
        let mut total = PrefixStats::default();
        total.merge(s);
        total.merge(s);
        assert_eq!(total.lookups, 4);
    }

    #[test]
    fn empty_stats_ratios_are_zero_not_nan() {
        // zero-denominator guard: a cold run (cache off, or no
        // cacheable requests) must report 0.0 ratios, never NaN — the
        // CLI tables print these raw.
        let s = PrefixStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.cached_token_ratio(), 0.0);
        assert_eq!(s.tokens_saved(), 0);
    }
}
