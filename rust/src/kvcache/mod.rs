//! Paged KV-cache manager (PagedAttention-style, §3.5.2).
//!
//! A single pool of fixed-size token blocks is shared by the prefill and
//! decode engines — the simulator analog of the paper's CUDA-IPC-shared
//! GPU memory pool.  Prefill allocates a block table for a request;
//! migration to decode is copy-free (the block table handle moves, the
//! data stays).  The live PJRT runtime uses the same manager with an
//! actual `Vec<f32>` backing store per block (see `runtime::executor`).

use std::collections::BTreeMap;

/// Tokens per KV block (vLLM uses 16).
pub const BLOCK_TOKENS: usize = 16;

/// Errors from the pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// Not enough free blocks for the allocation.
    OutOfMemory { requested_blocks: usize, free_blocks: usize },
    /// Unknown sequence handle.
    UnknownSeq(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfMemory {
                requested_blocks,
                free_blocks,
            } => write!(f, "KV OOM: need {requested_blocks} blocks, {free_blocks} free"),
            KvError::UnknownSeq(id) => write!(f, "unknown KV sequence {id}"),
        }
    }
}

impl std::error::Error for KvError {}

/// Block table of one sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqCache {
    pub seq_id: u64,
    /// Physical block indices, in token order.
    pub blocks: Vec<usize>,
    /// Valid tokens stored.
    pub len: usize,
}

impl SeqCache {
    /// Physical (block, offset) location of token `i`.
    pub fn locate(&self, i: usize) -> Option<(usize, usize)> {
        if i >= self.len {
            return None;
        }
        Some((self.blocks[i / BLOCK_TOKENS], i % BLOCK_TOKENS))
    }
}

/// The shared paged pool.
#[derive(Debug)]
pub struct KvPool {
    capacity_blocks: usize,
    free: Vec<usize>,
    seqs: BTreeMap<u64, SeqCache>,
    /// High-water mark of allocated blocks (for reporting).
    peak_used: usize,
}

impl KvPool {
    /// Pool sized in tokens (rounded down to whole blocks).
    pub fn new(capacity_tokens: usize) -> KvPool {
        let blocks = capacity_tokens / BLOCK_TOKENS;
        KvPool {
            capacity_blocks: blocks,
            free: (0..blocks).rev().collect(),
            seqs: BTreeMap::new(),
            peak_used: 0,
        }
    }

    pub fn capacity_tokens(&self) -> usize {
        self.capacity_blocks * BLOCK_TOKENS
    }

    pub fn free_tokens(&self) -> usize {
        self.free.len() * BLOCK_TOKENS
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    /// Tokens cached across all live sequences.
    pub fn cached_tokens(&self) -> usize {
        self.seqs.values().map(|s| s.len).sum()
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Can `tokens` more tokens be stored for (possibly new) `seq_id`?
    pub fn can_grow(&self, seq_id: u64, tokens: usize) -> bool {
        let cur = self.seqs.get(&seq_id);
        let cur_len = cur.map(|s| s.len).unwrap_or(0);
        let cur_blocks = cur.map(|s| s.blocks.len()).unwrap_or(0);
        let need_blocks = (cur_len + tokens).div_ceil(BLOCK_TOKENS) - cur_blocks;
        need_blocks <= self.free.len()
    }

    /// Allocate (or extend) a sequence by `tokens` tokens.
    pub fn grow(&mut self, seq_id: u64, tokens: usize) -> Result<(), KvError> {
        let (cur_len, cur_blocks) = match self.seqs.get(&seq_id) {
            Some(s) => (s.len, s.blocks.len()),
            None => (0, 0),
        };
        let need_blocks = (cur_len + tokens).div_ceil(BLOCK_TOKENS) - cur_blocks;
        if need_blocks > self.free.len() {
            return Err(KvError::OutOfMemory {
                requested_blocks: need_blocks,
                free_blocks: self.free.len(),
            });
        }
        let entry = self.seqs.entry(seq_id).or_insert(SeqCache {
            seq_id,
            blocks: Vec::new(),
            len: 0,
        });
        for _ in 0..need_blocks {
            entry.blocks.push(self.free.pop().unwrap());
        }
        entry.len += tokens;
        self.peak_used = self.peak_used.max(self.capacity_blocks - self.free.len());
        Ok(())
    }

    /// Release a sequence, returning its blocks to the pool.
    pub fn release(&mut self, seq_id: u64) -> Result<(), KvError> {
        let s = self.seqs.remove(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        self.free.extend(s.blocks);
        Ok(())
    }

    /// Copy-free migration marker: the paper moves a finished prefill to
    /// the decode engine by handing over indices (§3.5.1).  In this
    /// manager both engines share the pool, so migration is a no-op
    /// lookup that simply validates the handle exists.
    pub fn migrate(&self, seq_id: u64) -> Result<&SeqCache, KvError> {
        self.seqs.get(&seq_id).ok_or(KvError::UnknownSeq(seq_id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_release_roundtrip() {
        let mut p = KvPool::new(16 * 10); // 10 blocks
        p.grow(1, 40).unwrap(); // 3 blocks (ceil 40/16)
        assert_eq!(p.used_blocks(), 3);
        assert_eq!(p.get(1).unwrap().len, 40);
        p.release(1).unwrap();
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.free_tokens(), 160);
    }

    #[test]
    fn incremental_growth_reuses_partial_block() {
        let mut p = KvPool::new(16 * 10);
        p.grow(1, 10).unwrap(); // 1 block
        assert_eq!(p.used_blocks(), 1);
        p.grow(1, 6).unwrap(); // fills to exactly 16 — still 1 block
        assert_eq!(p.used_blocks(), 1);
        p.grow(1, 1).unwrap(); // spills into block 2
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.get(1).unwrap().len, 17);
    }

    #[test]
    fn oom_detected_and_state_unchanged() {
        let mut p = KvPool::new(16 * 2);
        p.grow(1, 16).unwrap();
        let err = p.grow(2, 32).unwrap_err();
        assert!(matches!(err, KvError::OutOfMemory { requested_blocks: 2, free_blocks: 1 }));
        // failed grow must not leak/alter state
        assert_eq!(p.used_blocks(), 1);
        assert!(!p.contains(2));
    }

    #[test]
    fn can_grow_matches_grow() {
        let mut p = KvPool::new(16 * 4);
        assert!(p.can_grow(1, 64));
        assert!(!p.can_grow(1, 65));
        p.grow(1, 60).unwrap();
        assert!(p.can_grow(1, 4)); // block 4 has 4 slots left
        assert!(!p.can_grow(1, 5));
    }

    #[test]
    fn locate_token() {
        let mut p = KvPool::new(16 * 4);
        p.grow(7, 20).unwrap();
        let s = p.get(7).unwrap();
        let (b0, o0) = s.locate(0).unwrap();
        let (b1, o1) = s.locate(17).unwrap();
        assert_eq!(o0, 0);
        assert_eq!(o1, 1);
        assert_ne!(b0, b1);
        assert!(s.locate(20).is_none());
    }

    #[test]
    fn release_unknown_errors() {
        let mut p = KvPool::new(160);
        assert_eq!(p.release(9), Err(KvError::UnknownSeq(9)));
    }

    #[test]
    fn migrate_is_copy_free_lookup() {
        let mut p = KvPool::new(160);
        p.grow(3, 5).unwrap();
        let blocks_before = p.get(3).unwrap().blocks.clone();
        let m = p.migrate(3).unwrap();
        assert_eq!(m.blocks, blocks_before);
        assert!(p.migrate(4).is_err());
    }

    #[test]
    fn no_block_double_allocation() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 64).unwrap();
        p.grow(2, 64).unwrap();
        let b1 = &p.get(1).unwrap().blocks;
        let b2 = &p.get(2).unwrap().blocks;
        for b in b1 {
            assert!(!b2.contains(b), "block {b} allocated twice");
        }
    }

    #[test]
    fn peak_tracking() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 64).unwrap();
        p.release(1).unwrap();
        p.grow(2, 16).unwrap();
        assert_eq!(p.peak_used_blocks(), 4);
    }

    #[test]
    fn cached_tokens_sum() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 10).unwrap();
        p.grow(2, 30).unwrap();
        assert_eq!(p.cached_tokens(), 40);
        assert_eq!(p.num_seqs(), 2);
    }
}
