//! Paged KV-cache manager (PagedAttention-style, §3.5.2) with
//! refcounted block sharing.
//!
//! A single pool of fixed-size token blocks is shared by the prefill and
//! decode engines — the simulator analog of the paper's CUDA-IPC-shared
//! GPU memory pool.  Prefill allocates a block table for a request;
//! migration to decode is copy-free (the block table handle moves, the
//! data stays).  The live PJRT runtime uses the same manager with an
//! actual `Vec<f32>` backing store per block (see `runtime::executor`).
//!
//! Ownership model: every physical block carries a reference count, so a
//! block may back several sequences at once.  Three ways to share:
//!
//! - [`KvPool::fork`] clones a whole sequence copy-on-write: both
//!   sequences reference the same blocks, and the first `grow` that
//!   would write into a shared, partially-filled tail block copies it
//!   first (the CoW rule of vLLM's parallel sampling);
//! - [`KvPool::adopt`] starts a new sequence on an existing run of full
//!   blocks — the prefix-cache hit path ([`prefix::PrefixIndex`]);
//! - [`KvPool::incref`] / [`KvPool::decref`] let an external owner (the
//!   prefix index) pin blocks past the owning sequence's release.
//!
//! A block returns to the free list only when its last reference drops.
//! `used_blocks() + free_blocks() == capacity_blocks()` holds at every
//! step (asserted by `tests/properties.rs`).

pub mod prefix;

use std::collections::BTreeMap;

/// Tokens per KV block (vLLM uses 16).
pub const BLOCK_TOKENS: usize = 16;

/// Errors from the pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvError {
    /// Not enough free blocks for the allocation.
    OutOfMemory { requested_blocks: usize, free_blocks: usize },
    /// Unknown sequence handle.
    UnknownSeq(u64),
    /// Target sequence of a `fork`/`adopt` already exists.
    SeqExists(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfMemory {
                requested_blocks,
                free_blocks,
            } => write!(f, "KV OOM: need {requested_blocks} blocks, {free_blocks} free"),
            KvError::UnknownSeq(id) => write!(f, "unknown KV sequence {id}"),
            KvError::SeqExists(id) => write!(f, "KV sequence {id} already exists"),
        }
    }
}

impl std::error::Error for KvError {}

/// Block table of one sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqCache {
    pub seq_id: u64,
    /// Physical block indices, in token order.
    pub blocks: Vec<usize>,
    /// Valid tokens stored.
    pub len: usize,
}

impl SeqCache {
    /// Physical (block, offset) location of token `i`.
    pub fn locate(&self, i: usize) -> Option<(usize, usize)> {
        if i >= self.len {
            return None;
        }
        Some((self.blocks[i / BLOCK_TOKENS], i % BLOCK_TOKENS))
    }
}

/// The shared paged pool.
#[derive(Debug)]
pub struct KvPool {
    capacity_blocks: usize,
    free: Vec<usize>,
    /// Per-block reference count (0 ⇔ on the free list).
    refs: Vec<u32>,
    seqs: BTreeMap<u64, SeqCache>,
    /// High-water mark of allocated blocks (for reporting).
    peak_used: usize,
}

impl KvPool {
    /// Pool sized in tokens (rounded down to whole blocks).
    pub fn new(capacity_tokens: usize) -> KvPool {
        let blocks = capacity_tokens / BLOCK_TOKENS;
        KvPool {
            capacity_blocks: blocks,
            free: (0..blocks).rev().collect(),
            refs: vec![0; blocks],
            seqs: BTreeMap::new(),
            peak_used: 0,
        }
    }

    pub fn capacity_tokens(&self) -> usize {
        self.capacity_blocks * BLOCK_TOKENS
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn free_tokens(&self) -> usize {
        self.free.len() * BLOCK_TOKENS
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Distinct physical blocks in use (shared blocks count once).
    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    /// References currently held on a physical block (0 ⇔ free).
    pub fn refcount(&self, block: usize) -> u32 {
        self.refs[block]
    }

    /// Tokens cached across all live sequences (logical commitment:
    /// shared blocks count once per holder — the routing signal, not the
    /// physical footprint).
    pub fn cached_tokens(&self) -> usize {
        self.seqs.values().map(|s| s.len).sum()
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Ids of all live sequences, ascending.  The teardown sweep for
    /// crash/cancel exit paths: callers that must return the pool whole
    /// release every listed id (the engine cannot otherwise enumerate
    /// sequences policies reserved privately).
    pub fn seq_ids(&self) -> Vec<u64> {
        self.seqs.keys().copied().collect()
    }

    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Would appending `tokens` tokens to (possibly new) `seq_id` write
    /// into a shared, partially-filled tail block?  That write must copy
    /// the block first (copy-on-write).
    fn needs_cow(&self, seq_id: u64, tokens: usize) -> bool {
        if tokens == 0 {
            return false;
        }
        match self.seqs.get(&seq_id) {
            Some(s) => {
                s.len % BLOCK_TOKENS != 0
                    && s.blocks.last().is_some_and(|&b| self.refs[b] > 1)
            }
            None => false,
        }
    }

    /// Fresh blocks a `grow(seq_id, tokens)` would allocate (including a
    /// copy-on-write replacement of a shared tail block).
    pub fn blocks_needed(&self, seq_id: u64, tokens: usize) -> usize {
        let (cur_len, cur_blocks) = match self.seqs.get(&seq_id) {
            Some(s) => (s.len, s.blocks.len()),
            None => (0, 0),
        };
        (cur_len + tokens).div_ceil(BLOCK_TOKENS) - cur_blocks
            + usize::from(self.needs_cow(seq_id, tokens))
    }

    /// Can `tokens` more tokens be stored for (possibly new) `seq_id`?
    pub fn can_grow(&self, seq_id: u64, tokens: usize) -> bool {
        self.blocks_needed(seq_id, tokens) <= self.free.len()
    }

    /// Allocate (or extend) a sequence by `tokens` tokens, copying a
    /// shared tail block first when necessary (CoW).
    pub fn grow(&mut self, seq_id: u64, tokens: usize) -> Result<(), KvError> {
        let need = self.blocks_needed(seq_id, tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfMemory {
                requested_blocks: need,
                free_blocks: self.free.len(),
            });
        }
        let cow = self.needs_cow(seq_id, tokens);
        let mut fresh = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refs[b] = 1;
            fresh.push(b);
        }
        let entry = self.seqs.entry(seq_id).or_insert(SeqCache {
            seq_id,
            blocks: Vec::new(),
            len: 0,
        });
        let mut copied_out = None;
        if cow {
            // replace the shared tail with the first fresh block (which
            // receives the copy of the partial contents)
            copied_out = entry.blocks.pop();
        }
        entry.blocks.extend(fresh);
        entry.len += tokens;
        if let Some(b) = copied_out {
            // other holders keep the original
            debug_assert!(self.refs[b] > 1, "CoW of an exclusive block");
            self.refs[b] -= 1;
        }
        self.peak_used = self.peak_used.max(self.capacity_blocks - self.free.len());
        Ok(())
    }

    /// Fork `src` into a new sequence `dst` sharing all of `src`'s
    /// blocks copy-on-write: both sequences keep identical contents, and
    /// the first grow that would write into the shared partial tail
    /// block copies it.  No new blocks are allocated here.
    pub fn fork(&mut self, src: u64, dst: u64) -> Result<(), KvError> {
        if self.seqs.contains_key(&dst) {
            return Err(KvError::SeqExists(dst));
        }
        let (blocks, len) = match self.seqs.get(&src) {
            Some(s) => (s.blocks.clone(), s.len),
            None => return Err(KvError::UnknownSeq(src)),
        };
        for &b in &blocks {
            self.refs[b] += 1;
        }
        self.seqs.insert(dst, SeqCache { seq_id: dst, blocks, len });
        Ok(())
    }

    /// Start a new sequence on an already-cached run of FULL blocks
    /// (the prefix-cache hit path): the blocks are shared, and the
    /// sequence's length starts at `blocks.len() * BLOCK_TOKENS`.
    pub fn adopt(&mut self, seq_id: u64, blocks: &[usize]) -> Result<(), KvError> {
        if self.seqs.contains_key(&seq_id) {
            return Err(KvError::SeqExists(seq_id));
        }
        for &b in blocks {
            self.incref(b);
        }
        self.seqs.insert(
            seq_id,
            SeqCache {
                seq_id,
                blocks: blocks.to_vec(),
                len: blocks.len() * BLOCK_TOKENS,
            },
        );
        Ok(())
    }

    /// Add a reference to a live block (external pin, e.g. the prefix
    /// index caching a finished prefill's blocks).
    pub fn incref(&mut self, block: usize) {
        assert!(
            self.refs[block] > 0,
            "incref on free KV block {block}"
        );
        self.refs[block] += 1;
    }

    /// Drop a reference; the block returns to the free list when the
    /// last reference goes.
    pub fn decref(&mut self, block: usize) {
        assert!(
            self.refs[block] > 0,
            "KV refcount underflow on block {block}"
        );
        self.refs[block] -= 1;
        if self.refs[block] == 0 {
            self.free.push(block);
        }
    }

    /// Release a sequence; its blocks return to the pool when no other
    /// holder (sibling fork, prefix index) still references them.
    pub fn release(&mut self, seq_id: u64) -> Result<(), KvError> {
        let s = self.seqs.remove(&seq_id).ok_or(KvError::UnknownSeq(seq_id))?;
        for b in s.blocks {
            self.decref(b);
        }
        Ok(())
    }

    /// Copy-free migration marker: the paper moves a finished prefill to
    /// the decode engine by handing over indices (§3.5.1).  In this
    /// manager both engines share the pool, so migration is a no-op
    /// lookup that simply validates the handle exists.
    pub fn migrate(&self, seq_id: u64) -> Result<&SeqCache, KvError> {
        self.seqs.get(&seq_id).ok_or(KvError::UnknownSeq(seq_id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_release_roundtrip() {
        let mut p = KvPool::new(16 * 10); // 10 blocks
        p.grow(1, 40).unwrap(); // 3 blocks (ceil 40/16)
        assert_eq!(p.used_blocks(), 3);
        assert_eq!(p.get(1).unwrap().len, 40);
        p.release(1).unwrap();
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.free_tokens(), 160);
    }

    #[test]
    fn incremental_growth_reuses_partial_block() {
        let mut p = KvPool::new(16 * 10);
        p.grow(1, 10).unwrap(); // 1 block
        assert_eq!(p.used_blocks(), 1);
        p.grow(1, 6).unwrap(); // fills to exactly 16 — still 1 block
        assert_eq!(p.used_blocks(), 1);
        p.grow(1, 1).unwrap(); // spills into block 2
        assert_eq!(p.used_blocks(), 2);
        assert_eq!(p.get(1).unwrap().len, 17);
    }

    #[test]
    fn oom_detected_and_state_unchanged() {
        let mut p = KvPool::new(16 * 2);
        p.grow(1, 16).unwrap();
        let err = p.grow(2, 32).unwrap_err();
        assert!(matches!(err, KvError::OutOfMemory { requested_blocks: 2, free_blocks: 1 }));
        // failed grow must not leak/alter state
        assert_eq!(p.used_blocks(), 1);
        assert!(!p.contains(2));
    }

    #[test]
    fn can_grow_matches_grow() {
        let mut p = KvPool::new(16 * 4);
        assert!(p.can_grow(1, 64));
        assert!(!p.can_grow(1, 65));
        p.grow(1, 60).unwrap();
        assert!(p.can_grow(1, 4)); // block 4 has 4 slots left
        assert!(!p.can_grow(1, 5));
    }

    #[test]
    fn locate_token() {
        let mut p = KvPool::new(16 * 4);
        p.grow(7, 20).unwrap();
        let s = p.get(7).unwrap();
        let (b0, o0) = s.locate(0).unwrap();
        let (b1, o1) = s.locate(17).unwrap();
        assert_eq!(o0, 0);
        assert_eq!(o1, 1);
        assert_ne!(b0, b1);
        assert!(s.locate(20).is_none());
    }

    #[test]
    fn release_unknown_errors() {
        let mut p = KvPool::new(160);
        assert_eq!(p.release(9), Err(KvError::UnknownSeq(9)));
    }

    #[test]
    fn migrate_is_copy_free_lookup() {
        let mut p = KvPool::new(160);
        p.grow(3, 5).unwrap();
        let blocks_before = p.get(3).unwrap().blocks.clone();
        let m = p.migrate(3).unwrap();
        assert_eq!(m.blocks, blocks_before);
        assert!(p.migrate(4).is_err());
    }

    #[test]
    fn no_block_double_allocation() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 64).unwrap();
        p.grow(2, 64).unwrap();
        let b1 = &p.get(1).unwrap().blocks;
        let b2 = &p.get(2).unwrap().blocks;
        for b in b1 {
            assert!(!b2.contains(b), "block {b} allocated twice");
        }
    }

    #[test]
    fn peak_tracking() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 64).unwrap();
        p.release(1).unwrap();
        p.grow(2, 16).unwrap();
        assert_eq!(p.peak_used_blocks(), 4);
    }

    #[test]
    fn cached_tokens_sum() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 10).unwrap();
        p.grow(2, 30).unwrap();
        assert_eq!(p.cached_tokens(), 40);
        assert_eq!(p.num_seqs(), 2);
    }

    #[test]
    fn fork_shares_blocks_without_allocating() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 40).unwrap(); // 3 blocks
        assert_eq!(p.used_blocks(), 3);
        p.fork(1, 2).unwrap();
        assert_eq!(p.used_blocks(), 3, "fork must not allocate");
        assert_eq!(p.get(2).unwrap().blocks, p.get(1).unwrap().blocks);
        assert_eq!(p.get(2).unwrap().len, 40);
        for &b in &p.get(1).unwrap().blocks.clone() {
            assert_eq!(p.refcount(b), 2);
        }
        // releasing one sequence keeps the blocks alive for the other
        p.release(1).unwrap();
        assert_eq!(p.used_blocks(), 3);
        p.release(2).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn grow_after_fork_copies_shared_tail() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 20).unwrap(); // blocks [b0, b1], b1 holds 4 tokens
        p.fork(1, 2).unwrap();
        let shared_tail = *p.get(1).unwrap().blocks.last().unwrap();
        // growing the fork writes into the partial tail → CoW
        p.grow(2, 4).unwrap();
        let fork_tail = *p.get(2).unwrap().blocks.last().unwrap();
        assert_ne!(fork_tail, shared_tail, "shared tail must be copied");
        assert_eq!(p.get(2).unwrap().len, 24);
        // parent untouched, still sharing b0 with the fork
        assert_eq!(*p.get(1).unwrap().blocks.last().unwrap(), shared_tail);
        assert_eq!(p.refcount(shared_tail), 1);
        assert_eq!(p.refcount(p.get(1).unwrap().blocks[0]), 2);
        assert_eq!(p.used_blocks(), 3);
    }

    #[test]
    fn grow_past_full_shared_tail_needs_no_cow() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 32).unwrap(); // two FULL blocks
        p.fork(1, 2).unwrap();
        let before = p.get(2).unwrap().blocks.clone();
        p.grow(2, 8).unwrap(); // appends a fresh block, no copy
        let after = &p.get(2).unwrap().blocks;
        assert_eq!(&after[..2], &before[..]);
        assert_eq!(after.len(), 3);
        assert_eq!(p.used_blocks(), 3);
    }

    #[test]
    fn adopt_shares_cached_prefix() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 48).unwrap(); // 3 full blocks
        let prefix = p.get(1).unwrap().blocks[..2].to_vec();
        p.adopt(2, &prefix).unwrap();
        assert_eq!(p.get(2).unwrap().len, 32);
        assert_eq!(p.used_blocks(), 3);
        // extend the adopter past the shared prefix
        p.grow(2, 20).unwrap();
        assert_eq!(p.get(2).unwrap().len, 52);
        assert_eq!(p.used_blocks(), 5);
        // the shared prefix survives the parent's release
        p.release(1).unwrap();
        assert_eq!(p.refcount(prefix[0]), 1);
        p.release(2).unwrap();
        assert_eq!(p.used_blocks(), 0);
    }

    #[test]
    fn fork_and_adopt_reject_existing_target() {
        let mut p = KvPool::new(16 * 8);
        p.grow(1, 16).unwrap();
        p.grow(2, 16).unwrap();
        assert_eq!(p.fork(1, 2), Err(KvError::SeqExists(2)));
        assert_eq!(p.adopt(2, &[]), Err(KvError::SeqExists(2)));
        assert_eq!(p.fork(9, 3), Err(KvError::UnknownSeq(9)));
    }

    #[test]
    fn accounting_identity_holds_under_sharing() {
        let mut p = KvPool::new(16 * 10);
        p.grow(1, 50).unwrap();
        p.fork(1, 2).unwrap();
        p.grow(2, 30).unwrap(); // CoW + growth
        p.grow(1, 2).unwrap(); // parent CoW? tail now exclusive again
        assert_eq!(p.used_blocks() + p.free_blocks(), p.capacity_blocks());
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.used_blocks(), 0);
        assert_eq!(p.free_blocks(), p.capacity_blocks());
    }
}
