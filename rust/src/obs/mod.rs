//! Observability: SM-second attribution and request tracing.
//!
//! The paper's whole argument is an accounting claim — prefill wastes
//! compute to wave quantization, hybrid batches waste bandwidth — so
//! this module makes every run answer "where did every SM-second go":
//!
//! - [`ledger`]: the [`SmLedger`] charges every simulated SM-second to
//!   one category (prefill compute/attention, decode, wave-quantization
//!   padding, repartition transition, kv-blocked stall, idle), with the
//!   tested invariant that the categories sum to `num_sms × makespan`.
//!   Accrual happens inside the simulator's `advance_by` as a pure
//!   side-channel of the existing rate table, so it never perturbs the
//!   physics, the rng stream, or bitwise determinism.
//! - [`trace`]: [`TraceSpec`]-gated structured engine events (launches,
//!   repartitions, KV stalls).  Off by default and bit-identical-off;
//!   on, the recorded stream is deterministic under a fixed seed and
//!   any `sim_threads` setting.
//! - [`export`]: a Chrome trace-event JSON exporter (`--trace out.json`)
//!   producing per-replica process tracks loadable in Perfetto /
//!   chrome://tracing, built on the in-tree `util/json.rs` so the
//!   output bytes are deterministic (sorted keys, stable event order).

pub mod export;
pub mod ledger;
pub mod trace;

pub use ledger::{GpuTimeCategory, SmLedger};
pub use trace::{EngineTraceEvent, TraceSpec};
