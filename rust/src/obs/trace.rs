//! Structured request/engine tracing, gated behind [`TraceSpec`].
//!
//! The request lifecycle (queued → prefill → decode → terminal) is
//! already fully determined by the run's `RequestRecord` /
//! `OutcomeRecord` / `ScaleEvent` streams, so the exporter derives
//! those spans at export time for free.  What the engine additionally
//! records — only when tracing is enabled — are the instants those
//! streams cannot reconstruct: kernel-group launches per lane, plan
//! decisions that repartitioned the SM split, and KV-pressure stalls.
//!
//! Determinism contract: recording is a pure observer.  With
//! `TraceSpec::enabled == false` (the default) no event is ever pushed
//! and every output is bit-identical to a build without this module;
//! with it on, the event stream is a deterministic function of the
//! seed, identical across repeated runs and `sim_threads` settings.

/// Trace configuration carried on `ServingConfig`.  Off by default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceSpec {
    /// Record engine trace events and enable span export.
    pub enabled: bool,
}

impl TraceSpec {
    /// Tracing on.
    pub fn on() -> TraceSpec {
        TraceSpec { enabled: true }
    }
}

/// Engine-internal instants recorded while tracing is enabled.
/// Timestamps are virtual-clock seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EngineTraceEvent {
    /// A kernel group launched on a lane (0 = prefill, 1 = decode).
    Launch { t: f64, lane: u8, kernels: usize },
    /// The policy's plan switched the SM partition this turn.
    Repartition { t: f64, prefill_sms: usize, decode_sms: usize },
    /// A KV reservation attempt failed under memory pressure.
    KvBlocked { t: f64 },
}

impl EngineTraceEvent {
    /// Event timestamp (virtual seconds).
    pub fn t(&self) -> f64 {
        match *self {
            EngineTraceEvent::Launch { t, .. }
            | EngineTraceEvent::Repartition { t, .. }
            | EngineTraceEvent::KvBlocked { t } => t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_default_off() {
        assert!(!TraceSpec::default().enabled);
        assert!(TraceSpec::on().enabled);
    }

    #[test]
    fn event_timestamps() {
        assert_eq!(EngineTraceEvent::Launch { t: 1.5, lane: 0, kernels: 3 }.t(), 1.5);
        assert_eq!(
            EngineTraceEvent::Repartition { t: 2.0, prefill_sms: 60, decode_sms: 48 }.t(),
            2.0
        );
        assert_eq!(EngineTraceEvent::KvBlocked { t: 0.25 }.t(), 0.25);
    }
}
