//! Chrome trace-event JSON exporter (`--trace out.json`).
//!
//! Emits the [trace-event format] consumed by Perfetto and
//! chrome://tracing: one process track per replica, one thread track
//! per request (spans: queued → prefill → decode), instants for
//! terminal outcomes, fleet-lifecycle actions, launches, repartitions
//! and KV stalls, plus a `bullet` summary block embedding each
//! replica's finalized [`SmLedger`] so `tools/trace_summary.py` can
//! re-check ledger conservation straight from the trace file.
//!
//! Built on the in-tree `util/json.rs` (no serde): `Value::Obj` is a
//! `BTreeMap`, so keys serialize sorted, and events are emitted in a
//! fixed construction order — the exported bytes are a deterministic
//! function of the run output, which the trace-determinism tests
//! assert across repeated runs and `sim_threads` settings.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use crate::engine::core::EngineOutput;
use crate::metrics::timeline::ScaleAction;
use crate::metrics::RequestOutcome;
use crate::obs::ledger::SmLedger;
use crate::obs::trace::EngineTraceEvent;
use crate::util::json::Value;
use std::collections::BTreeMap;

/// Request thread-ids start here; tids 0..3 are engine/lane tracks.
const REQ_TID_BASE: u64 = 16;

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<String, Value>>(),
    )
}

fn num(x: f64) -> Value {
    Value::Num(x)
}

fn txt(x: &str) -> Value {
    Value::Str(x.to_string())
}

/// Virtual seconds → trace-event microseconds.
fn us(t: f64) -> Value {
    Value::Num(t * 1e6)
}

fn meta(pid: usize, tid: u64, kind: &str, name: &str) -> Value {
    obj(vec![
        ("ph", txt("M")),
        ("pid", num(pid as f64)),
        ("tid", num(tid as f64)),
        ("name", txt(kind)),
        ("args", obj(vec![("name", txt(name))])),
    ])
}

fn span(pid: usize, tid: u64, name: &str, cat: &str, start: f64, end: f64) -> Value {
    obj(vec![
        ("ph", txt("X")),
        ("pid", num(pid as f64)),
        ("tid", num(tid as f64)),
        ("name", txt(name)),
        ("cat", txt(cat)),
        ("ts", us(start)),
        ("dur", us((end - start).max(0.0))),
    ])
}

fn instant(pid: usize, tid: u64, name: &str, cat: &str, t: f64, args: Option<Value>) -> Value {
    let mut pairs = vec![
        ("ph", txt("i")),
        ("pid", num(pid as f64)),
        ("tid", num(tid as f64)),
        ("name", txt(name)),
        ("cat", txt(cat)),
        ("ts", us(t)),
        ("s", txt("t")),
    ];
    if let Some(a) = args {
        pairs.push(("args", a));
    }
    obj(pairs)
}

fn scale_action_name(a: ScaleAction) -> &'static str {
    match a {
        ScaleAction::ScaleOut => "scale-out",
        ScaleAction::ScaleIn => "scale-in",
        ScaleAction::Retire => "retire",
        ScaleAction::Reprofile => "reprofile",
        ScaleAction::Crash => "crash",
    }
}

fn outcome_name(o: RequestOutcome) -> &'static str {
    match o {
        RequestOutcome::Cancelled => "cancelled",
        RequestOutcome::Expired => "expired",
        RequestOutcome::Lost => "lost",
    }
}

fn ledger_value(l: &SmLedger) -> Value {
    let mut pairs: Vec<(&str, Value)> = l.entries().iter().map(|&(k, v)| (k, num(v))).collect();
    pairs.push(("total", num(l.total)));
    obj(pairs)
}

/// Build the full Chrome trace-event document for a run's per-replica
/// outputs (a single-GPU run passes a one-element slice).
pub fn chrome_trace(title: &str, per_replica: &[EngineOutput]) -> Value {
    let mut events: Vec<Value> = Vec::new();
    for (pid, o) in per_replica.iter().enumerate() {
        events.push(meta(pid, 0, "process_name", &format!("replica {pid}")));
        events.push(meta(pid, 0, "thread_name", "engine"));
        events.push(meta(pid, 1, "thread_name", "prefill lane"));
        events.push(meta(pid, 2, "thread_name", "decode lane"));
        for r in &o.records {
            let tid = REQ_TID_BASE + r.id;
            events.push(meta(pid, tid, "thread_name", &format!("req {}", r.id)));
            events.push(span(pid, tid, "queued", "request", r.arrival, r.prefill_start));
            events.push(span(pid, tid, "prefill", "request", r.prefill_start, r.first_token_time));
            if r.output_len > 1 {
                events.push(span(pid, tid, "decode", "request", r.first_token_time, r.finish_time));
            }
        }
        for oc in &o.outcomes {
            let tid = REQ_TID_BASE + oc.id;
            let args = obj(vec![("tokens_out", num(oc.tokens_out as f64))]);
            events.push(instant(pid, tid, outcome_name(oc.outcome), "lifecycle", oc.t, Some(args)));
        }
        for e in &o.scale_events {
            let args = obj(vec![
                ("replica", num(e.replica as f64)),
                ("fleet_after", num(e.fleet_after as f64)),
            ]);
            events.push(instant(pid, 0, scale_action_name(e.action), "fleet", e.t, Some(args)));
        }
        for e in &o.trace_events {
            match *e {
                EngineTraceEvent::Launch { t, lane, kernels } => {
                    let args = obj(vec![("kernels", num(kernels as f64))]);
                    events.push(instant(pid, 1 + lane as u64, "launch", "engine", t, Some(args)));
                }
                EngineTraceEvent::Repartition { t, prefill_sms, decode_sms } => {
                    let args = obj(vec![
                        ("prefill_sms", num(prefill_sms as f64)),
                        ("decode_sms", num(decode_sms as f64)),
                    ]);
                    events.push(instant(pid, 0, "repartition", "engine", t, Some(args)));
                }
                EngineTraceEvent::KvBlocked { t } => {
                    events.push(instant(pid, 0, "kv-blocked", "engine", t, None));
                }
            }
        }
    }
    let mut agg = SmLedger::default();
    let mut replicas: Vec<Value> = Vec::new();
    for (pid, o) in per_replica.iter().enumerate() {
        agg.merge(&o.ledger);
        replicas.push(obj(vec![
            ("id", num(pid as f64)),
            ("makespan", num(o.virtual_duration)),
            ("ledger", ledger_value(&o.ledger)),
        ]));
    }
    obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", txt("ms")),
        (
            "bullet",
            obj(vec![
                ("title", txt(title)),
                ("replicas", Value::Arr(replicas)),
                ("ledger", ledger_value(&agg)),
            ]),
        ),
    ])
}

/// Serialize [`chrome_trace`] to `path` (one line of compact JSON).
pub fn write_chrome_trace(
    path: &str,
    title: &str,
    per_replica: &[EngineOutput],
) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", chrome_trace(title, per_replica)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::timeline::{ScaleEvent, Timeline};
    use crate::metrics::{OutcomeRecord, RequestRecord};
    use crate::obs::ledger::GpuTimeCategory;

    fn output() -> EngineOutput {
        let mut ledger = SmLedger::default();
        ledger.charge(GpuTimeCategory::Decode, 54.0);
        ledger.finalize(108.0);
        EngineOutput {
            records: vec![RequestRecord {
                id: 0,
                arrival: 0.0,
                input_len: 64,
                output_len: 4,
                first_token_time: 0.2,
                finish_time: 0.5,
                prefill_start: 0.1,
            }],
            outcomes: vec![OutcomeRecord {
                id: 1,
                outcome: RequestOutcome::Cancelled,
                t: 0.3,
                tokens_out: 2,
            }],
            timeline: Timeline::new(),
            reconfigs: 0,
            decode_pauses: 0,
            total_flops: 0.0,
            total_bytes: 0.0,
            virtual_duration: 1.0,
            peak_kv_blocks: 0,
            final_kv_blocks: 0,
            prefix: Default::default(),
            calibration: Default::default(),
            scale_events: vec![ScaleEvent {
                t: 0.4,
                action: ScaleAction::Crash,
                replica: 0,
                fleet_after: 1,
            }],
            rate_memo: Default::default(),
            predict_memo: Default::default(),
            ledger,
            trace_events: vec![
                EngineTraceEvent::Launch { t: 0.1, lane: 0, kernels: 3 },
                EngineTraceEvent::Repartition { t: 0.15, prefill_sms: 60, decode_sms: 48 },
                EngineTraceEvent::KvBlocked { t: 0.2 },
            ],
        }
    }

    #[test]
    fn document_shape_and_roundtrip() {
        let doc = chrome_trace("unit", &[output()]);
        // serialized bytes must re-parse to an identical tree
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text, "serialization must round-trip");
        let events = doc.get("traceEvents").and_then(Value::as_arr).unwrap();
        // 4 meta + 1 req meta + 3 spans + 1 outcome + 1 scale + 3 engine
        assert_eq!(events.len(), 13);
        for e in events {
            assert!(e.get("ph").and_then(Value::as_str).is_some());
            assert!(e.get("pid").and_then(Value::as_f64).is_some());
            assert!(e.get("tid").and_then(Value::as_f64).is_some());
        }
        let ledger = doc.path(&["bullet", "ledger"]).unwrap();
        let total = ledger.get("total").and_then(Value::as_f64).unwrap();
        let sum: f64 = [
            "prefill-compute",
            "prefill-attention",
            "decode",
            "wave-quant",
            "repartition",
            "kv-blocked",
            "idle",
        ]
        .iter()
        .map(|k| ledger.get(k).and_then(Value::as_f64).unwrap())
        .sum();
        assert!((sum - total).abs() <= 1e-9 * total.max(1.0));
    }

    #[test]
    fn timestamps_are_microseconds() {
        let doc = chrome_trace("unit", &[output()]);
        let events = doc.get("traceEvents").and_then(Value::as_arr).unwrap();
        let queued = events
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("queued"))
            .unwrap();
        assert_eq!(queued.get("ts").and_then(Value::as_f64).unwrap(), 0.0);
        assert!((queued.get("dur").and_then(Value::as_f64).unwrap() - 1e5).abs() < 1e-6);
    }

    #[test]
    fn export_is_byte_deterministic() {
        let a = chrome_trace("unit", &[output()]).to_string();
        let b = chrome_trace("unit", &[output()]).to_string();
        assert_eq!(a, b);
    }
}
