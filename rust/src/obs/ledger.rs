//! SM-second attribution ledger.
//!
//! Charges every simulated SM-second of a run to exactly one category,
//! so each `System` variant gets a comparable waste profile (the
//! evidence layer behind the paper's Fig. 2 / Fig. 12).
//!
//! Accounting scheme: the simulator accrues the BUSY categories (and
//! explicitly tagged stall time) online; plain idle is the residual
//! `num_sms × makespan − accrued`, computed once at
//! [`SmLedger::finalize`].  The residual form keeps the conservation
//! invariant exact by construction and — crucially — keeps the engine's
//! history-free idle jumps (`advance_idle_to`) free of per-segment
//! floating-point sums that would differ between a replica that visited
//! every dispatch horizon and one that skipped them while drained.

/// Where one slice of GPU time went.  `Idle` has no variant here on
/// purpose: it is never charged, only derived as the finalize residual.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuTimeCategory {
    /// Prefill GEMMs / elementwise on a prefill-phase stream.
    PrefillCompute,
    /// Prefill self-attention (FlashAttention-style).
    PrefillAttention,
    /// Anything running on a decode-phase stream.
    Decode,
    /// Tail-wave SMs idled by wave quantization inside a compute-bound
    /// kernel's partition (paper Eq. 1).
    WaveQuant,
    /// Fully-idle spans on a turn whose plan repartitioned the SM split
    /// but could not launch (the transition gap of §3.4.2).
    Repartition,
    /// Fully-idle spans while admission/growth is blocked on KV memory.
    KvBlocked,
}

/// Per-run SM-second totals by category.  All fields are in SM·seconds;
/// `total` is `num_sms × makespan` and `idle` the finalize residual, so
/// the seven categories always sum to `total` (within one rounding of
/// the final subtraction — the conservation tests allow relative 1e-9).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SmLedger {
    pub prefill_compute: f64,
    pub prefill_attention: f64,
    pub decode: f64,
    pub wave_quant: f64,
    pub repartition: f64,
    pub kv_blocked: f64,
    /// Residual idle time; zero until [`SmLedger::finalize`].
    pub idle: f64,
    /// `num_sms × makespan`; zero until [`SmLedger::finalize`].
    pub total: f64,
}

impl SmLedger {
    /// Accrue `sm_seconds` of GPU time to a category.
    pub fn charge(&mut self, cat: GpuTimeCategory, sm_seconds: f64) {
        match cat {
            GpuTimeCategory::PrefillCompute => self.prefill_compute += sm_seconds,
            GpuTimeCategory::PrefillAttention => self.prefill_attention += sm_seconds,
            GpuTimeCategory::Decode => self.decode += sm_seconds,
            GpuTimeCategory::WaveQuant => self.wave_quant += sm_seconds,
            GpuTimeCategory::Repartition => self.repartition += sm_seconds,
            GpuTimeCategory::KvBlocked => self.kv_blocked += sm_seconds,
        }
    }

    /// Sum of the explicitly charged (non-idle) categories.
    pub fn accrued(&self) -> f64 {
        self.prefill_compute
            + self.prefill_attention
            + self.decode
            + self.wave_quant
            + self.repartition
            + self.kv_blocked
    }

    /// Sum over all seven categories (idle included).
    pub fn sum(&self) -> f64 {
        self.accrued() + self.idle
    }

    /// Close the books: record `total = num_sms × makespan` and derive
    /// idle as the residual (clamped at zero against rounding).
    pub fn finalize(&mut self, total: f64) {
        self.total = total;
        self.idle = (total - self.accrued()).max(0.0);
    }

    /// Fold another (finalized) ledger in — the cluster/gateway
    /// aggregation over per-replica ledgers.
    pub fn merge(&mut self, other: &SmLedger) {
        self.prefill_compute += other.prefill_compute;
        self.prefill_attention += other.prefill_attention;
        self.decode += other.decode;
        self.wave_quant += other.wave_quant;
        self.repartition += other.repartition;
        self.kv_blocked += other.kv_blocked;
        self.idle += other.idle;
        self.total += other.total;
    }

    /// `(label, SM·seconds)` rows in display order — the CLI table and
    /// the JSON export both iterate this, so their keys agree.
    pub fn entries(&self) -> [(&'static str, f64); 7] {
        [
            ("prefill-compute", self.prefill_compute),
            ("prefill-attention", self.prefill_attention),
            ("decode", self.decode),
            ("wave-quant", self.wave_quant),
            ("repartition", self.repartition),
            ("kv-blocked", self.kv_blocked),
            ("idle", self.idle),
        ]
    }

    /// Conservation check: categories sum to `total` within a relative
    /// tolerance (absolute below 1 SM·s).
    pub fn conserved(&self, rel_tol: f64) -> bool {
        (self.sum() - self.total).abs() <= rel_tol * self.total.abs().max(1.0)
    }

    /// Bit pattern of every field, for bitwise parity assertions.
    pub fn to_bits(&self) -> [u64; 8] {
        [
            self.prefill_compute.to_bits(),
            self.prefill_attention.to_bits(),
            self.decode.to_bits(),
            self.wave_quant.to_bits(),
            self.repartition.to_bits(),
            self.kv_blocked.to_bits(),
            self.idle.to_bits(),
            self.total.to_bits(),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_makes_categories_sum_to_total() {
        let mut l = SmLedger::default();
        l.charge(GpuTimeCategory::PrefillCompute, 30.0);
        l.charge(GpuTimeCategory::PrefillAttention, 10.0);
        l.charge(GpuTimeCategory::Decode, 40.0);
        l.charge(GpuTimeCategory::WaveQuant, 5.0);
        l.charge(GpuTimeCategory::Repartition, 1.0);
        l.charge(GpuTimeCategory::KvBlocked, 2.0);
        l.finalize(108.0);
        assert!((l.idle - 20.0).abs() < 1e-12);
        assert!(l.conserved(1e-9));
        assert_eq!(l.entries().iter().map(|(_, v)| v).sum::<f64>(), l.sum());
    }

    #[test]
    fn finalize_clamps_negative_residual() {
        let mut l = SmLedger::default();
        l.charge(GpuTimeCategory::Decode, 10.0);
        l.finalize(10.0 - 1e-12);
        assert_eq!(l.idle, 0.0);
        assert!(l.conserved(1e-9), "clamped residual stays conserved");
    }

    #[test]
    fn merge_adds_every_field() {
        let mut a = SmLedger::default();
        a.charge(GpuTimeCategory::Decode, 4.0);
        a.finalize(10.0);
        let mut b = SmLedger::default();
        b.charge(GpuTimeCategory::PrefillCompute, 3.0);
        b.finalize(5.0);
        let mut m = a;
        m.merge(&b);
        assert_eq!(m.total, 15.0);
        assert_eq!(m.decode, 4.0);
        assert_eq!(m.prefill_compute, 3.0);
        assert!((m.idle - 8.0).abs() < 1e-12);
        assert!(m.conserved(1e-9));
    }

    #[test]
    fn empty_run_is_all_idle() {
        let mut l = SmLedger::default();
        l.finalize(0.0);
        assert_eq!(l.sum(), 0.0);
        assert!(l.conserved(1e-9));
    }
}
