//! Configuration: GPU spec, analytical model spec, serving/scheduler
//! parameters and SLO targets.  All configs are plain structs with
//! sensible defaults matching the paper's testbed (A100-PCIe-80GB serving
//! Llama-3.1-8B), and can be overridden from JSON files via
//! [`ServingConfig::from_json`].

use crate::obs::trace::TraceSpec;
use crate::util::json::Value;

/// Physical GPU description (defaults: NVIDIA A100-PCIe-80GB as in §4.1).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Number of streaming multiprocessors (108 on A100).
    pub num_sms: usize,
    /// SM-mask allocation granularity (libsmctrl masks pairs of SMs — §3.4.1).
    pub sm_granularity: usize,
    /// Peak dense f16/bf16 tensor-core throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub peak_bandwidth: f64,
    /// Fraction of peak compute sustainable by real GEMMs ("peak
    /// sustainable capacity", the red line in Fig. 2 — §2.2.3 measures
    /// MLP at 92%).
    pub sustainable_frac: f64,
    /// HBM capacity in bytes (80 GB).
    pub hbm_bytes: u64,
    /// Fixed per-kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// CPU-side scheduling synchronization overhead per layer group, seconds.
    pub sync_overhead: f64,
}

impl Default for GpuSpec {
    fn default() -> Self {
        GpuSpec {
            num_sms: 108,
            sm_granularity: 2,
            peak_flops: 312e12,      // A100 BF16 tensor core peak
            peak_bandwidth: 2.0e12,  // paper: "2TB/s of HBM bandwidth"
            sustainable_frac: 0.92,
            hbm_bytes: 80 * (1 << 30),
            launch_overhead: 4e-6,
            sync_overhead: 8e-6,
        }
    }
}

impl GpuSpec {
    /// A100 (the paper's testbed).
    pub fn a100() -> GpuSpec {
        GpuSpec::default()
    }

    /// H100-like (132 SMs) — used by tests to check nothing hardcodes 108.
    pub fn h100() -> GpuSpec {
        GpuSpec {
            num_sms: 132,
            peak_flops: 989e12,
            peak_bandwidth: 3.35e12,
            hbm_bytes: 80 * (1 << 30),
            ..GpuSpec::default()
        }
    }

    /// Round an SM count down to the mask granularity (min one group).
    pub fn quantize_sms(&self, sms: usize) -> usize {
        let g = self.sm_granularity;
        ((sms.max(g) / g) * g).min(self.num_sms)
    }
}

/// Analytical transformer descriptor (defaults: Llama-3.1-8B).
///
/// Drives the simulator's flops/bytes/grid accounting — distinct from the
/// PJRT-executed tiny model, whose config lives in artifacts/meta.json.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub vocab_size: usize,
    /// Bytes per parameter/activation element (fp16 = 2).
    pub dtype_bytes: usize,
}

impl Default for ModelSpec {
    fn default() -> Self {
        ModelSpec::llama31_8b()
    }
}

impl ModelSpec {
    /// Llama-3.1-8B (the paper's served model).
    pub fn llama31_8b() -> ModelSpec {
        ModelSpec {
            name: "llama-3.1-8b".into(),
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_dim: 14336,
            vocab_size: 128256,
            dtype_bytes: 2,
        }
    }

    /// The tiny PJRT-served model (mirrors python ModelConfig defaults).
    pub fn tiny() -> ModelSpec {
        ModelSpec {
            name: "tiny-llama".into(),
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 32,
            ffn_dim: 704,
            vocab_size: 2048,
            dtype_bytes: 4,
        }
    }

    /// KV-cache bytes per token (all layers, K+V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim * self.dtype_bytes) as u64
    }

    /// Total parameter count (approximate, embeddings included).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let attn = d * (self.n_heads * self.head_dim) as u64 * 2
            + d * (self.n_kv_heads * self.head_dim) as u64 * 2;
        let mlp = 3 * d * self.ffn_dim as u64;
        let per_layer = attn + mlp + 2 * d;
        self.n_layers as u64 * per_layer + 2 * (self.vocab_size as u64 * d)
    }
}

/// Non-stationary GPU behavior regimes (off by default, so every run
/// without an explicit regime stays bit-identical).  The simulated
/// "silicon" applies these on top of its roofline ground truth; the
/// offline-profiled performance model knows nothing about them — which
/// is exactly the gap online calibration exists to close.
///
/// Throttling and the phantom co-tenant are COMPUTE-side effects (SM
/// clocks drop / SM cycles are stolen; HBM bandwidth is untouched), so
/// compute-bound prefill slows while memory-bound decode barely moves —
/// a phase-asymmetric shift no uniform fudge factor on the frozen model
/// could express.  The device lottery scales the whole kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftSpec {
    /// Gradual clock throttling (thermal): effective SM clock ramps
    /// linearly from 1.0 down to `throttle_floor` over
    /// `throttle_ramp_s` seconds of virtual time.  `1.0` disables.
    pub throttle_floor: f64,
    pub throttle_ramp_s: f64,
    /// Step-change interference from a phantom co-tenant stealing SM
    /// cycles: from `step_at_s` on, every kernel's compute term slows
    /// by `step_factor` (>= 1).  `f64::INFINITY` disables.
    pub step_at_s: f64,
    pub step_factor: f64,
    /// Per-device lottery: one lognormal slowdown factor drawn per
    /// simulator instance (seed-dependent), modeling silicon/bin
    /// variation across a fleet.  Scales compute AND memory.  `0.0`
    /// disables.
    pub lottery_sigma: f64,
}

impl Default for DriftSpec {
    fn default() -> Self {
        DriftSpec::none()
    }
}

impl DriftSpec {
    /// The identity regime: a drift-free GPU.
    pub fn none() -> DriftSpec {
        DriftSpec {
            throttle_floor: 1.0,
            throttle_ramp_s: 60.0,
            step_at_s: f64::INFINITY,
            step_factor: 1.0,
            lottery_sigma: 0.0,
        }
    }

    /// Thermal throttling: clocks ramp down to 60% over 40 s.
    pub fn throttle() -> DriftSpec {
        DriftSpec {
            throttle_floor: 0.6,
            throttle_ramp_s: 40.0,
            ..DriftSpec::none()
        }
    }

    /// Phantom co-tenant: a 1.6x slowdown lands at t = 10 s.
    pub fn step() -> DriftSpec {
        DriftSpec {
            step_at_s: 10.0,
            step_factor: 1.6,
            ..DriftSpec::none()
        }
    }

    /// Silicon lottery: per-device lognormal speed variation.
    pub fn lottery() -> DriftSpec {
        DriftSpec {
            lottery_sigma: 0.25,
            ..DriftSpec::none()
        }
    }

    /// Everything at once: throttling + step interference + lottery.
    pub fn storm() -> DriftSpec {
        DriftSpec {
            throttle_floor: 0.65,
            throttle_ramp_s: 40.0,
            step_at_s: 8.0,
            step_factor: 1.5,
            lottery_sigma: 0.15,
        }
    }

    /// CLI name → regime.
    pub fn by_name(name: &str) -> Option<DriftSpec> {
        match name {
            "none" => Some(DriftSpec::none()),
            "throttle" => Some(DriftSpec::throttle()),
            "step" => Some(DriftSpec::step()),
            "lottery" => Some(DriftSpec::lottery()),
            "storm" => Some(DriftSpec::storm()),
            _ => None,
        }
    }

    /// True when every regime is disabled (the identity drift factor).
    pub fn is_none(&self) -> bool {
        self.throttle_floor >= 1.0
            && (self.step_factor <= 1.0 || !self.step_at_s.is_finite())
            && self.lottery_sigma <= 0.0
    }
}

/// Online performance-model calibration knobs (`perf::OnlineCalibrator`).
/// Disabled by default: the scheduler then consults the offline-profiled
/// model bit-for-bit, exactly as before calibration existed.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationConfig {
    /// Master switch: ingest observation samples and blend learned
    /// per-cell correction ratios into predictions.
    pub enabled: bool,
    /// Base EWMA learning rate for per-cell ratio updates.
    pub alpha: f64,
    /// Samples a cell needs before its ratio gets full weight; below
    /// this the prediction blends toward the offline grid (cold cells
    /// fall back to it entirely).
    pub confidence_samples: u64,
    /// Deadband: samples whose |observed/calibrated - 1| falls below
    /// this are counted but do not move any ratio, so an accurate
    /// offline model is left untouched.
    pub min_abs_residual: f64,
    /// Residual-trend window for drift detection.
    pub drift_window: usize,
    /// |mean signed residual| over the window that flags a drift event.
    pub drift_threshold: f64,
    /// Learning-rate multiplier applied for a window after detection.
    pub drift_boost: f64,
    /// Clamp on per-sample and per-cell ratios — calibration can never
    /// produce a non-finite or absurd prediction.
    pub ratio_min: f64,
    pub ratio_max: f64,
}

impl Default for CalibrationConfig {
    fn default() -> Self {
        CalibrationConfig {
            enabled: false,
            alpha: 0.2,
            confidence_samples: 4,
            min_abs_residual: 0.0,
            drift_window: 12,
            drift_threshold: 0.2,
            drift_boost: 4.0,
            ratio_min: 0.2,
            ratio_max: 8.0,
        }
    }
}

impl CalibrationConfig {
    /// Calibration on, default gains.
    pub fn on() -> CalibrationConfig {
        CalibrationConfig {
            enabled: true,
            ..CalibrationConfig::default()
        }
    }
}

/// Latency targets for a workload (Table 2 of the paper).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Normalized TTFT budget: seconds per input token (paper: ms/token).
    pub norm_ttft_ms_per_token: f64,
    /// TPOT budget in milliseconds.
    pub tpot_ms: f64,
}

impl SloSpec {
    pub fn sharegpt() -> SloSpec {
        SloSpec {
            norm_ttft_ms_per_token: 3.0,
            tpot_ms: 150.0,
        }
    }

    pub fn azure_code() -> SloSpec {
        SloSpec {
            norm_ttft_ms_per_token: 1.5,
            tpot_ms: 200.0,
        }
    }

    pub fn arxiv_summary() -> SloSpec {
        SloSpec {
            norm_ttft_ms_per_token: 1.5,
            tpot_ms: 175.0,
        }
    }

    /// Absolute TTFT budget for an `input_len`-token request, seconds.
    pub fn ttft_budget(&self, input_len: usize) -> f64 {
        self.norm_ttft_ms_per_token * input_len as f64 * 1e-3
    }

    /// TPOT budget in seconds.
    pub fn tpot_budget(&self) -> f64 {
        self.tpot_ms * 1e-3
    }
}

/// Scheduler/engine knobs (§3.3–§3.5).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub gpu: GpuSpec,
    pub model: ModelSpec,
    pub slo: SloSpec,
    /// Layers launched per prefill scheduling cycle (§3.3.1 "fixed number
    /// of layers", 1 in the paper's example).
    pub prefill_layer_group: usize,
    /// Minimum SMs the decode phase may be squeezed to before pausing.
    pub min_decode_sms: usize,
    /// Minimum SMs for prefill when decode pressure dominates.
    pub min_prefill_sms: usize,
    /// Max decode batch size.
    pub max_decode_batch: usize,
    /// Max tokens admitted to one prefill batch.
    pub max_prefill_tokens: usize,
    /// Small-prompt batching threshold: requests are prefilled one at a
    /// time (lowest TTFT) unless several short prompts fit under this
    /// many tokens, in which case they share one batch to amortize
    /// launches.
    pub prefill_batch_tokens: usize,
    /// KV cache capacity in tokens (derived from HBM minus weights if 0).
    pub kv_capacity_tokens: usize,
    /// Percentile used for SLO checks in Algorithm 1 (paper: P90).
    pub slo_percentile: f64,
    /// Allow intentional SM overlap between phases during transitions (§3.4.2).
    pub allow_sm_overlap: bool,
    /// Shared-prefix KV reuse: match arrivals against the content-hash
    /// prefix index and prefill only the uncached suffix.  Off by
    /// default — single-turn workloads carry no content hashes, and off
    /// keeps every legacy run bit-identical.
    pub prefix_cache: bool,
    /// Online performance-model calibration (disabled by default: the
    /// scheduler consults the offline model unchanged).
    pub calibration: CalibrationConfig,
    /// Hot-path memoization (simulator rate table, scheduler per-cycle
    /// aggregates, calibrated-prediction memo, router probe memo).  On
    /// by default; off runs the reference recomputing paths.  Both legs
    /// are bit-identical — this flag exists so the parity tests can say
    /// so, and so a suspected memo bug can be ruled out in the field.
    pub memo: bool,
    /// Prefill share of the fixed SM split used by the intra-GPU P/D
    /// disaggregation baselines (`--system static-split`, and the
    /// starting point of `proactive-split`).  Fraction of `gpu.num_sms`
    /// in (0, 1), quantized to the mask granularity and clamped between
    /// `min_prefill_sms` and `num_sms - min_decode_sms` at use.  Ignored
    /// by every other system.
    pub pd_split: f64,
    /// Decode iterations per temporal-multiplexing epoch (`--system
    /// temporal-mux`): each epoch drains one queued prefill, then runs
    /// this many whole-GPU decode iterations before the next prefill
    /// turn.  Smaller favors TTFT (prefills wait less), larger favors
    /// TPOT (longer uninterrupted decode runs).  Ignored by every other
    /// system.  Must be >= 1; the default 8 reproduces the historical
    /// constant bit-for-bit.
    pub decode_epoch_iters: usize,
    /// Structured trace recording (`--trace out.json`).  Off by default
    /// and bit-identical-off.
    pub trace: TraceSpec,
}

impl Default for ServingConfig {
    fn default() -> Self {
        let gpu = GpuSpec::default();
        let model = ModelSpec::default();
        let kv_capacity_tokens = derive_kv_capacity(&gpu, &model);
        ServingConfig {
            gpu,
            model,
            slo: SloSpec::sharegpt(),
            prefill_layer_group: 1,
            min_decode_sms: 12,
            min_prefill_sms: 24,
            max_decode_batch: 256,
            max_prefill_tokens: 16384,
            prefill_batch_tokens: 512,
            kv_capacity_tokens,
            slo_percentile: 90.0,
            allow_sm_overlap: true,
            prefix_cache: false,
            calibration: CalibrationConfig::default(),
            memo: true,
            pd_split: 0.5,
            decode_epoch_iters: 8,
            trace: TraceSpec::default(),
        }
    }
}

/// Tokens of KV cache that fit in HBM after weights + activation slack.
pub fn derive_kv_capacity(gpu: &GpuSpec, model: &ModelSpec) -> usize {
    let weights = model.param_count() * model.dtype_bytes as u64;
    let slack = 6 * (1u64 << 30); // activations, fragmentation, cuda context
    let avail = gpu.hbm_bytes.saturating_sub(weights + slack);
    (avail / model.kv_bytes_per_token().max(1)) as usize
}

impl ServingConfig {
    /// Load overrides from a JSON object; missing keys keep defaults.
    pub fn from_json(v: &Value) -> ServingConfig {
        let mut cfg = ServingConfig::default();
        if let Some(g) = v.get("gpu") {
            if let Some(x) = g.get("num_sms").and_then(Value::as_usize) {
                cfg.gpu.num_sms = x;
            }
            if let Some(x) = g.get("peak_flops").and_then(Value::as_f64) {
                cfg.gpu.peak_flops = x;
            }
            if let Some(x) = g.get("peak_bandwidth").and_then(Value::as_f64) {
                cfg.gpu.peak_bandwidth = x;
            }
        }
        if let Some(s) = v.get("slo") {
            if let Some(x) = s.get("norm_ttft_ms_per_token").and_then(Value::as_f64) {
                cfg.slo.norm_ttft_ms_per_token = x;
            }
            if let Some(x) = s.get("tpot_ms").and_then(Value::as_f64) {
                cfg.slo.tpot_ms = x;
            }
        }
        if let Some(x) = v.get("prefill_layer_group").and_then(Value::as_usize) {
            cfg.prefill_layer_group = x;
        }
        if let Some(x) = v.get("max_decode_batch").and_then(Value::as_usize) {
            cfg.max_decode_batch = x;
        }
        if let Some(x) = v.get("kv_capacity_tokens").and_then(Value::as_usize) {
            cfg.kv_capacity_tokens = x;
        }
        if let Some(x) = v.get("prefix_cache").and_then(Value::as_bool) {
            cfg.prefix_cache = x;
        }
        if let Some(x) = v.get("calibration").and_then(Value::as_bool) {
            cfg.calibration.enabled = x;
        }
        if let Some(x) = v.get("memo").and_then(Value::as_bool) {
            cfg.memo = x;
        }
        if let Some(x) = v.get("pd_split").and_then(Value::as_f64) {
            cfg.pd_split = x;
        }
        if let Some(x) = v.get("decode_epoch_iters").and_then(Value::as_usize) {
            cfg.decode_epoch_iters = x.max(1);
        }
        if let Some(x) = v.get("trace").and_then(Value::as_bool) {
            cfg.trace.enabled = x;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn a100_defaults_match_paper() {
        let g = GpuSpec::a100();
        assert_eq!(g.num_sms, 108);
        assert_eq!(g.sm_granularity, 2);
        assert!((g.peak_bandwidth - 2e12).abs() < 1e9);
    }

    #[test]
    fn quantize_sms_granularity() {
        let g = GpuSpec::a100();
        assert_eq!(g.quantize_sms(7), 6);
        assert_eq!(g.quantize_sms(8), 8);
        assert_eq!(g.quantize_sms(1), 2);
        assert_eq!(g.quantize_sms(200), 108);
    }

    #[test]
    fn llama8b_param_count_plausible() {
        let m = ModelSpec::llama31_8b();
        let p = m.param_count();
        assert!(p > 7_000_000_000 && p < 9_000_000_000, "params {p}");
    }

    #[test]
    fn kv_bytes_per_token_llama8b() {
        // 2 * 32 layers * 8 kv heads * 128 dim * 2 bytes = 131072 B/token
        assert_eq!(ModelSpec::llama31_8b().kv_bytes_per_token(), 131072);
    }

    #[test]
    fn kv_capacity_positive_and_bounded() {
        let cfg = ServingConfig::default();
        assert!(cfg.kv_capacity_tokens > 50_000, "{}", cfg.kv_capacity_tokens);
        assert!(cfg.kv_capacity_tokens < 1_000_000);
    }

    #[test]
    fn slo_budgets() {
        let s = SloSpec::sharegpt();
        assert!((s.ttft_budget(1000) - 3.0).abs() < 1e-9);
        assert!((s.tpot_budget() - 0.150).abs() < 1e-12);
    }

    #[test]
    fn from_json_overrides() {
        let v = json::parse(
            r#"{"gpu": {"num_sms": 132}, "slo": {"tpot_ms": 99.0},
                "max_decode_batch": 64, "prefix_cache": true}"#,
        )
        .unwrap();
        let cfg = ServingConfig::from_json(&v);
        assert_eq!(cfg.gpu.num_sms, 132);
        assert_eq!(cfg.slo.tpot_ms, 99.0);
        assert_eq!(cfg.max_decode_batch, 64);
        assert!(cfg.prefix_cache);
        // untouched default
        assert_eq!(cfg.prefill_layer_group, 1);
    }

    #[test]
    fn drift_default_is_identity() {
        assert!(DriftSpec::default().is_none());
        assert!(DriftSpec::by_name("none").unwrap().is_none());
        for name in ["throttle", "step", "lottery", "storm"] {
            let d = DriftSpec::by_name(name).unwrap();
            assert!(!d.is_none(), "{name} must enable a regime");
        }
        assert!(DriftSpec::by_name("bogus").is_none());
    }

    #[test]
    fn calibration_default_off_and_json_toggle() {
        let cfg = ServingConfig::default();
        assert!(!cfg.calibration.enabled);
        let v = json::parse(r#"{"calibration": true}"#).unwrap();
        assert!(ServingConfig::from_json(&v).calibration.enabled);
        let on = CalibrationConfig::on();
        assert!(on.enabled && on.ratio_min > 0.0 && on.ratio_max.is_finite());
    }

    #[test]
    fn memo_default_on_and_json_toggle() {
        assert!(ServingConfig::default().memo);
        let v = json::parse(r#"{"memo": false}"#).unwrap();
        assert!(!ServingConfig::from_json(&v).memo);
    }

    #[test]
    fn pd_split_default_and_json_override() {
        assert_eq!(ServingConfig::default().pd_split, 0.5);
        let v = json::parse(r#"{"pd_split": 0.25}"#).unwrap();
        assert_eq!(ServingConfig::from_json(&v).pd_split, 0.25);
    }

    #[test]
    fn decode_epoch_default_and_json_override() {
        assert_eq!(ServingConfig::default().decode_epoch_iters, 8);
        let v = json::parse(r#"{"decode_epoch_iters": 32}"#).unwrap();
        assert_eq!(ServingConfig::from_json(&v).decode_epoch_iters, 32);
        // validated >= 1 on the JSON path, same as the CLI flag
        let v = json::parse(r#"{"decode_epoch_iters": 0}"#).unwrap();
        assert_eq!(ServingConfig::from_json(&v).decode_epoch_iters, 1);
    }

    #[test]
    fn trace_default_off_and_json_toggle() {
        assert!(!ServingConfig::default().trace.enabled);
        let v = json::parse(r#"{"trace": true}"#).unwrap();
        assert!(ServingConfig::from_json(&v).trace.enabled);
    }

    #[test]
    fn tiny_model_matches_python_abi() {
        let t = ModelSpec::tiny();
        assert_eq!(t.n_layers, 4);
        assert_eq!(t.d_model, 256);
        assert_eq!(t.head_dim, 32);
    }
}
