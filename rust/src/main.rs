//! Bullet CLI — launcher for the serving system.
//!
//! ```text
//! bullet serve   [--workload sharegpt|azure-code|arxiv-summary|conversational]
//!                [--rate R] [--requests N] [--system bullet|vllm-1024|
//!                 sglang-1024|sglang-2048|nanoflow|static-split|
//!                 proactive-split|temporal-mux] [--pd-split R]
//!                [--profile coarse|paper]
//!                [--seed S] [--prefix-cache on|off] [--replicas N]
//!                [--router round-robin|least-kv|slo-slack|prefix-affinity]
//!                [--calibration on|off] [--drift none|throttle|step|lottery|storm]
//!                [--autoscale on|off] [--min-replicas N] [--max-replicas N]
//!                [--sim-threads N] [--live off|virtual|wall]
//!                [--deadline-ms N] [--fail-replica ID@T]
//! bullet live    [--requests N] [--artifacts DIR]   # real model via PJRT
//! bullet profile [--grid coarse|paper]              # offline §3.2.2 pass
//! bullet info                                        # config + artifact info
//! ```

use bullet::baselines::{run_system_output, System};
use bullet::cluster::{serve_cluster, AutoscaleConfig, ClusterConfig, RouterPolicy};
use bullet::config::{CalibrationConfig, DriftSpec, ServingConfig, SloSpec};
use bullet::coordinator::{BuildOptions, BulletServer, Tokenizer};
use bullet::engine::live_engine::serve_live;
use bullet::gateway::{serve_gateway, FailureSpec, GatewayConfig, VirtualClock, WallClock};
use bullet::kvcache::prefix::PrefixStats;
use bullet::metrics::timeline::ScaleAction;
use bullet::metrics::{summarize, RunSummary};
use bullet::obs::export::write_chrome_trace;
use bullet::obs::{SmLedger, TraceSpec};
use bullet::perf::CalibrationStats;
use bullet::runtime::{ModelMeta, ModelRuntime};
use bullet::util::cli::Args;
use bullet::util::memo::MemoCounters;
use bullet::util::tbl::{f, ms, Table};
use bullet::workload::{trace_by_name, Request};
use std::path::PathBuf;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("live") => live(&args),
        Some("profile") => profile_cmd(&args),
        Some("info") => info(),
        _ => {
            eprintln!("{}", HELP);
            std::process::exit(2);
        }
    }
}

const HELP: &str = "bullet — spatial-temporal LLM serving (paper reproduction)

subcommands:
  serve    run a simulated serving experiment (A100 + Llama-3.1-8B scale)
  live     serve the real tiny model via PJRT (requires `make artifacts`)
  profile  run the offline profiling pass and report estimator accuracy
  info     print configuration and artifact status

common flags: --workload NAME --rate R --requests N --seed S
serve flags:  --system bullet|vllm-1024|sglang-1024|sglang-2048|nanoflow|
                       static-split|proactive-split|temporal-mux
              --pd-split R            (prefill share of the fixed P/D SM
                                       split, in (0,1); static-split pins
                                       it, proactive-split starts there;
                                       default 0.5)
              --profile coarse|paper
              --prefix-cache on|off   (shared-prefix KV reuse; pairs with
                                       --workload conversational)
              --replicas N
              --router round-robin|least-kv|slo-slack|prefix-affinity
              --calibration on|off    (live perf-model feedback; pairs
                                       with --drift)
              --drift none|throttle|step|lottery|storm
                                      (non-stationary GPU regime the
                                       offline profile cannot see)
              --autoscale on|off      (calibration-driven fleet control;
                                       --replicas is the starting fleet)
              --min-replicas N --max-replicas N
                                      (fleet bounds with --autoscale on)
              --sim-threads N         (simulation worker threads; 0 = all
                                       cores, 1 = serial — results are
                                       bit-identical at any value)
              --live off|virtual|wall (serve through the lifecycle
                                       gateway: token streaming,
                                       cancellation, deadlines; `virtual`
                                       teleports between events —
                                       bit-deterministic — while `wall`
                                       sleeps to each instant for
                                       real-time serving)
              --deadline-ms N         (with --live: blanket per-request
                                       deadline of N ms past arrival for
                                       requests carrying none)
              --fail-replica ID@T     (with --live: crash replica ID at
                                       T seconds; sessions re-home, cold
                                       orphans re-queue, in-flight work
                                       is counted lost)
              --memo on|off           (hot-path memoization: rate-table,
                                       predictor and router-probe caches;
                                       off runs the reference paths —
                                       results are bit-identical either
                                       way)
              --decode-epoch N        (temporal-mux only: decode
                                       iterations per all-SM decode
                                       epoch; integer >= 1, default 8 —
                                       small N favors TTFT, large N
                                       favors TPOT)
              --trace FILE            (export a Chrome trace-event JSON
                                       of the run — request lifecycle
                                       spans, launches, repartitions, KV
                                       stalls, per-replica SM-second
                                       ledger; load in Perfetto or
                                       chrome://tracing, or summarize
                                       with tools/trace_summary.py)";

/// The metric rows every serve table shares (single-GPU and cluster).
fn summary_rows(t: &mut Table, s: &RunSummary) {
    t.row(&["requests".to_string(), s.n_requests.to_string()]);
    t.row(&["mean TTFT (ms)".to_string(), ms(s.mean_ttft)]);
    t.row(&["P90 TTFT (ms)".to_string(), ms(s.p90_ttft)]);
    t.row(&["mean TPOT (ms)".to_string(), ms(s.mean_tpot)]);
    t.row(&["P90 TPOT (ms)".to_string(), ms(s.p90_tpot)]);
    t.row(&["throughput (tok/s)".to_string(), f(s.throughput_tok_s, 1)]);
    t.row(&["SLO attainment".to_string(), f(s.slo_attainment * 100.0, 1) + "%"]);
}

/// Prefix-cache rows appended to serve tables when the cache is on.
fn prefix_rows(t: &mut Table, ps: &PrefixStats) {
    t.row(&["prefix hit rate".to_string(), f(ps.hit_rate() * 100.0, 1) + "%"]);
    t.row(&[
        "cached-token ratio".to_string(),
        f(ps.cached_token_ratio() * 100.0, 1) + "%",
    ]);
    t.row(&["prefill tokens saved".to_string(), ps.tokens_saved().to_string()]);
    t.row(&["prefix evictions".to_string(), ps.evictions.to_string()]);
}

/// Calibration rows appended to serve tables when calibration is on.
fn calibration_rows(t: &mut Table, cs: &CalibrationStats) {
    t.row(&["calib samples".to_string(), cs.samples.to_string()]);
    t.row(&[
        "calib mean |residual|".to_string(),
        f(cs.mean_abs_residual() * 100.0, 1) + "%",
    ]);
    t.row(&["calib drift events".to_string(), cs.drift_events.to_string()]);
    t.row(&["calibrated slowdown".to_string(), f(cs.slowdown, 3) + "x"]);
}

/// Hot-path memoization rows (rate-table / predictor / router-probe
/// reuse), appended when `--memo on` (the default).
fn memo_rows(
    t: &mut Table,
    rate: &MemoCounters,
    predict: &MemoCounters,
    router: Option<&MemoCounters>,
) {
    let cell = |c: &MemoCounters| {
        if c.lookups() == 0 {
            "-".to_string()
        } else {
            format!(
                "{}% of {} ({} inval)",
                f(c.hit_rate() * 100.0, 1),
                c.lookups(),
                c.invalidations
            )
        }
    };
    t.row(&["rate-table reuse".to_string(), cell(rate)]);
    t.row(&["predictor memo hits".to_string(), cell(predict)]);
    if let Some(r) = router {
        t.row(&["router probe reuse".to_string(), cell(r)]);
    }
}

/// SM-second attribution breakdown: every simulated SM-second charged
/// to exactly one category, summing to `num_sms × makespan`.  Printed
/// for every system — it is the accounting evidence behind the paper's
/// utilization claims (where each baseline's GPU time actually goes).
fn print_ledger(title: &str, ledger: &SmLedger) {
    let mut t = Table::new(&format!("GPU time attribution — {title}"))
        .header(&["category", "SM·s", "share"]);
    let denom = if ledger.total > 0.0 { ledger.total } else { 1.0 };
    for (name, v) in ledger.entries() {
        t.row(&[name.to_string(), f(v, 1), f(v / denom * 100.0, 1) + "%"]);
    }
    t.row(&["total".to_string(), f(ledger.total, 1), "100.0%".to_string()]);
    t.print();
}

/// Export the Chrome trace-event JSON for `--trace FILE`.
fn export_trace(path: &str, title: &str, per_replica: &[bullet::engine::core::EngineOutput]) {
    if let Err(e) = write_chrome_trace(path, title, per_replica) {
        eprintln!("failed to write trace '{path}': {e}");
        std::process::exit(1);
    }
    eprintln!("wrote Chrome trace to {path} (Perfetto / chrome://tracing / tools/trace_summary.py)");
}

/// Parse a `--fail-replica ID@T` spec.
fn parse_failure(s: &str) -> FailureSpec {
    let parsed = s.split_once('@').and_then(|(id, at)| {
        Some(FailureSpec { replica: id.parse().ok()?, at: at.parse().ok()? })
    });
    parsed.unwrap_or_else(|| {
        eprintln!("bad --fail-replica '{s}' (want ID@T, e.g. 0@1.5)");
        std::process::exit(2);
    })
}

fn workload_slo(name: &str) -> SloSpec {
    match name {
        "azure-code" => SloSpec::azure_code(),
        "arxiv-summary" => SloSpec::arxiv_summary(),
        // conversational shares ShareGPT's SLOs (same interactive shape)
        _ => SloSpec::sharegpt(),
    }
}

fn serve(args: &Args) {
    let name = args.get_or("workload", "sharegpt").to_string();
    let rate = args.get_f64("rate", 10.0);
    let n = args.get_usize("requests", 200);
    let seed = args.get_u64("seed", 42);
    let trace = trace_by_name(&name, rate, n, seed).unwrap_or_else(|| {
        eprintln!("unknown workload '{name}'");
        std::process::exit(2);
    });
    let prefix_cache = match args.get_or("prefix-cache", "off") {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("unknown --prefix-cache '{other}' (use on|off)");
            std::process::exit(2);
        }
    };
    let calibration = match args.get_or("calibration", "off") {
        "on" => CalibrationConfig::on(),
        "off" => CalibrationConfig::default(),
        other => {
            eprintln!("unknown --calibration '{other}' (use on|off)");
            std::process::exit(2);
        }
    };
    let drift_name = args.get_or("drift", "none").to_string();
    let drift = DriftSpec::by_name(&drift_name).unwrap_or_else(|| {
        eprintln!("unknown --drift '{drift_name}' (use none|throttle|step|lottery|storm)");
        std::process::exit(2);
    });
    let memo = match args.get_or("memo", "on") {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("unknown --memo '{other}' (use on|off)");
            std::process::exit(2);
        }
    };
    let pd_split = args.get_f64("pd-split", 0.5);
    if !(pd_split > 0.0 && pd_split < 1.0) {
        eprintln!("bad --pd-split '{pd_split}' (want a fraction in (0, 1))");
        std::process::exit(2);
    }
    let decode_epoch_iters = args.get_usize("decode-epoch", 8);
    if decode_epoch_iters < 1 {
        eprintln!("bad --decode-epoch '{decode_epoch_iters}' (want an integer >= 1)");
        std::process::exit(2);
    }
    let trace_path = args.get("trace").map(str::to_string);
    let cfg = ServingConfig {
        slo: workload_slo(&name),
        prefix_cache,
        calibration,
        memo,
        pd_split,
        decode_epoch_iters,
        // --trace needs the runtime instants recorded; without the flag
        // tracing stays off and the run is bit-identical to pre-trace
        // builds.
        trace: if trace_path.is_some() { TraceSpec::on() } else { TraceSpec::default() },
        ..ServingConfig::default()
    };

    let build = match args.get_or("profile", "coarse") {
        "paper" => BuildOptions::with_paper_profiling(&cfg),
        "none" => BuildOptions::default(),
        _ => BuildOptions::with_coarse_profiling(&cfg),
    };
    eprintln!("building server (profiling: {})...", args.get_or("profile", "coarse"));
    let server = BulletServer::build(cfg.clone(), build);

    let sys = System::by_name(args.get_or("system", "bullet")).unwrap_or_else(|| {
        eprintln!("unknown system '{}'", args.get_or("system", "bullet"));
        std::process::exit(2);
    });

    let replicas = args.get_usize("replicas", 1);
    let router = RouterPolicy::by_name(args.get_or("router", "round-robin")).unwrap_or_else(|| {
        eprintln!("unknown router '{}'", args.get_or("router", "round-robin"));
        std::process::exit(2);
    });
    let autoscale_on = match args.get_or("autoscale", "off") {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("unknown --autoscale '{other}' (use on|off)");
            std::process::exit(2);
        }
    };
    let autoscale = if autoscale_on {
        AutoscaleConfig::on(
            args.get_usize("min-replicas", 1),
            args.get_usize("max-replicas", replicas.max(4)),
        )
    } else {
        AutoscaleConfig::off()
    };
    // 0 = all available cores; 1 = the legacy serial path.  Any value
    // yields bit-identical results — the flag trades wall-clock only.
    let sim_threads = args.get_usize("sim-threads", 0);
    if autoscale_on && !cfg.calibration.enabled {
        eprintln!(
            "note: --autoscale on without --calibration on: scaling runs on \
             arrival-rate demand against NOMINAL capacity only — per-replica \
             slowdowns read 1.0, so drift retirement and re-profiling stay \
             inert; pair with --calibration on for the full loop"
        );
    }

    // The offline profile runs on the CLEAN ground truth (that is the
    // point); the drift regime applies only to the serving-time GPU.
    let gt = server.ground_truth().clone().with_drift(drift.clone());

    let live_mode = args.get_or("live", "off").to_string();
    if live_mode != "off" {
        let failures: Vec<FailureSpec> = match args.get("fail-replica") {
            Some(s) => vec![parse_failure(s)],
            None => Vec::new(),
        };
        let deadline_ms = args.get_f64("deadline-ms", 0.0);
        let gw = GatewayConfig {
            replicas,
            router,
            failures,
            default_deadline_s: (deadline_ms > 0.0).then_some(deadline_ms / 1000.0),
        };
        eprintln!(
            "serving {} requests of {} at {} req/s through the {} gateway ({} on {} replicas)...",
            n,
            name,
            rate,
            live_mode,
            sys.label(),
            replicas
        );
        let out = match live_mode.as_str() {
            "virtual" => {
                let mut clock = VirtualClock::new();
                serve_gateway(sys, &cfg, server.perf(), &gt, &trace, seed, &gw, &mut clock)
            }
            "wall" => {
                let mut clock = WallClock::new();
                serve_gateway(sys, &cfg, server.perf(), &gt, &trace, seed, &gw, &mut clock)
            }
            other => {
                eprintln!("unknown --live '{other}' (use off|virtual|wall)");
                std::process::exit(2);
            }
        };
        let title = format!(
            "{} behind the {} gateway on {} @ {} req/s",
            sys.label(),
            live_mode,
            name,
            rate
        );
        let mut t = Table::new(&title).header(&["metric", "value"]);
        if !out.records.is_empty() {
            let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
            summary_rows(&mut t, &s);
        }
        let lc = out.lifecycle;
        t.row(&["submitted".to_string(), lc.submitted().to_string()]);
        t.row(&[
            "completed/cancelled/expired/lost".to_string(),
            format!("{}/{}/{}/{}", lc.completed, lc.cancelled, lc.expired, lc.lost),
        ]);
        t.row(&["streams".to_string(), out.stream.streams.to_string()]);
        t.row(&["stream chunks".to_string(), out.stream.chunks.to_string()]);
        t.row(&["mean TTFB (ms)".to_string(), ms(out.stream.mean_ttfb)]);
        t.row(&["mean chunk gap (ms)".to_string(), ms(out.stream.mean_gap)]);
        t.row(&["max chunk gap (ms)".to_string(), ms(out.stream.max_gap)]);
        t.row(&["makespan (s)".to_string(), f(out.virtual_duration, 2)]);
        if !out.scale_events.is_empty() {
            t.row(&[
                "crashes".to_string(),
                out.scale_events
                    .iter()
                    .map(|e| format!("replica {} @ {:.2}s", e.replica, e.t))
                    .collect::<Vec<_>>()
                    .join(", "),
            ]);
        }
        t.print();
        print_ledger(&title, &out.ledger());
        if let Some(path) = &trace_path {
            export_trace(path, &title, &out.per_replica);
        }
        return;
    }

    if replicas > 1 || autoscale_on {
        eprintln!(
            "serving {} requests of {} at {} req/s with {} on {} replicas ({}{})...",
            n,
            name,
            rate,
            sys.label(),
            replicas,
            router.label(),
            if autoscale_on { ", autoscaled" } else { "" }
        );
        let ccfg =
            ClusterConfig { replicas, router, autoscale, sim_threads, ..Default::default() };
        // direct call so --seed drives the replica simulators, exactly
        // like the single-replica path below
        let out = serve_cluster(sys, &cfg, server.perf(), &gt, &trace, seed, &ccfg);
        let s = summarize(&out.records, &cfg.slo, Some(out.virtual_duration));
        let title = format!(
            "{} x{} ({}) on {} @ {} req/s",
            sys.label(),
            replicas,
            router.label(),
            name,
            rate
        );
        let mut t = Table::new(&title).header(&["metric", "value"]);
        summary_rows(&mut t, &s);
        t.row(&["makespan (s)".to_string(), f(out.virtual_duration, 2)]);
        t.row(&[
            "per-replica requests".to_string(),
            format!("{:?}", out.per_replica_counts()),
        ]);
        if cfg.prefix_cache {
            prefix_rows(&mut t, &out.prefix_stats());
        }
        if autoscale_on {
            let count = |a: ScaleAction| {
                out.scale_events.iter().filter(|e| e.action == a).count()
            };
            t.row(&[
                "scale events".to_string(),
                format!(
                    "{} out / {} in / {} retire / {} reprofile",
                    count(ScaleAction::ScaleOut),
                    count(ScaleAction::ScaleIn),
                    count(ScaleAction::Retire),
                    count(ScaleAction::Reprofile)
                ),
            ]);
            let retired = count(ScaleAction::ScaleIn) + count(ScaleAction::Retire);
            t.row(&[
                "fleet (final/spawned)".to_string(),
                format!("{}/{}", out.per_replica.len() - retired, out.per_replica.len()),
            ]);
            t.row(&["replica-steps (GPU·s)".to_string(), f(out.replica_steps, 1)]);
        }
        if !drift.is_none() {
            t.row(&["drift regime".to_string(), drift_name.clone()]);
        }
        if cfg.calibration.enabled {
            calibration_rows(&mut t, &out.calibration_stats());
            // per-replica learned speeds: the heterogeneity fingerprint
            // (device lottery gives each replica its own silicon)
            let slowdowns: Vec<String> = out
                .calibrated_slowdowns()
                .iter()
                .map(|x| f(*x, 2))
                .collect();
            t.row(&[
                "per-replica slowdown".to_string(),
                format!("[{}]", slowdowns.join(", ")),
            ]);
        }
        if cfg.memo {
            memo_rows(
                &mut t,
                &out.rate_memo_stats(),
                &out.predict_memo_stats(),
                Some(&out.router_memo),
            );
        }
        t.print();
        print_ledger(&title, &out.ledger());
        if let Some(path) = &trace_path {
            export_trace(path, &title, &out.per_replica);
        }
        return;
    }

    eprintln!("serving {} requests of {} at {} req/s with {}...", n, name, rate, sys.label());
    let out = run_system_output(sys, &cfg, server.perf(), &gt, &trace, seed);
    let s = summarize(&out.records, &cfg.slo, None);

    let title = format!("{} on {} @ {} req/s", sys.label(), name, rate);
    let mut t = Table::new(&title).header(&["metric", "value"]);
    summary_rows(&mut t, &s);
    if cfg.prefix_cache {
        prefix_rows(&mut t, &out.prefix);
    }
    if !drift.is_none() {
        t.row(&["drift regime".to_string(), drift_name.clone()]);
    }
    if cfg.calibration.enabled {
        calibration_rows(&mut t, &out.calibration);
    }
    if cfg.memo {
        memo_rows(&mut t, &out.rate_memo, &out.predict_memo, None);
    }
    t.print();
    print_ledger(&title, &out.ledger);
    if let Some(path) = &trace_path {
        export_trace(path, &title, std::slice::from_ref(&out));
    }
}

fn live(args: &Args) {
    let n = args.get_usize("requests", 8);
    let seed = args.get_u64("seed", 7);
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ModelMeta::default_dir);
    eprintln!("loading artifacts from {} ...", dir.display());
    let rt = ModelRuntime::load(&dir, seed).unwrap_or_else(|e| {
        eprintln!("failed to load runtime: {e:#}");
        std::process::exit(1);
    });
    let vocab = rt.engine.meta.vocab_size;
    let tok = Tokenizer::new(vocab);
    let prompts = [
        "Explain spatial-temporal GPU sharing.",
        "Write a haiku about SM masks.",
        "What limits chunked prefill?",
        "How do prefill and decode differ?",
    ];
    let token_ids: Vec<Vec<i32>> = (0..n)
        .map(|i| tok.encode(prompts[i % prompts.len()]))
        .collect();
    let trace: Vec<Request> = (0..n as u64)
        .map(|i| Request {
            id: i,
            arrival: i as f64 * 0.05,
            input_len: token_ids[i as usize].len(),
            output_len: 12,
            ..Default::default()
        })
        .collect();
    let (records, stats) = serve_live(rt, trace, token_ids).unwrap();
    let slo = SloSpec::sharegpt();
    let s = summarize(&records, &slo, None);
    let mut t = Table::new("live serving (tiny model, PJRT CPU)").header(&["metric", "value"]);
    t.row(&["requests".to_string(), s.n_requests.to_string()]);
    t.row(&["mean TTFT (ms)".to_string(), ms(s.mean_ttft)]);
    t.row(&["mean TPOT (ms)".to_string(), ms(s.mean_tpot)]);
    t.row(&["throughput (tok/s)".to_string(), f(s.throughput_tok_s, 1)]);
    t.row(&["decode iterations".to_string(), stats.decode_iterations.to_string()]);
    t.row(&["max decode batch".to_string(), stats.max_batch_seen.to_string()]);
    t.print();
}

fn profile_cmd(args: &Args) {
    let cfg = ServingConfig::default();
    let build = match args.get_or("grid", "coarse") {
        "paper" => BuildOptions::with_paper_profiling(&cfg),
        _ => BuildOptions::with_coarse_profiling(&cfg),
    };
    eprintln!("profiling ({})...", args.get_or("grid", "coarse"));
    let t0 = std::time::Instant::now();
    let server = BulletServer::build(cfg, build);
    let dt = t0.elapsed().as_secs_f64();
    let pm = server.perf();
    let mut t = Table::new("offline profiling (§3.2.2)").header(&["quantity", "value"]);
    t.row(&["wall time (s)".to_string(), f(dt, 2)]);
    t.row(&["contention p_c".to_string(), f(pm.p_c, 3)]);
    t.row(&["contention p_b".to_string(), f(pm.p_b, 3)]);
    t.print();
}

fn info() {
    let cfg = ServingConfig::default();
    let mut t = Table::new("bullet configuration").header(&["key", "value"]);
    t.row(&[
        "GPU".to_string(),
        format!(
            "{} SMs, {:.0} TFLOPS, {:.1} TB/s",
            cfg.gpu.num_sms,
            cfg.gpu.peak_flops / 1e12,
            cfg.gpu.peak_bandwidth / 1e12
        ),
    ]);
    t.row(&["model".to_string(), cfg.model.name.clone()]);
    t.row(&[
        "params".to_string(),
        format!("{:.2} B", cfg.model.param_count() as f64 / 1e9),
    ]);
    t.row(&[
        "KV capacity (tokens)".to_string(),
        cfg.kv_capacity_tokens.to_string(),
    ]);
    let dir = ModelMeta::default_dir();
    let status = match ModelMeta::load(&dir) {
        Ok(m) => format!(
            "ok: {} weights, prefill {:?}, decode {:?}",
            m.weights.len(),
            m.prefill_buckets,
            m.decode_buckets
        ),
        Err(_) => "missing (run `make artifacts`)".to_string(),
    };
    t.row(&["artifacts".to_string(), status]);
    t.print();
}
