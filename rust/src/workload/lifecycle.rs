//! Lifecycle annotation: deterministic cancellation times and deadlines
//! stamped onto an existing trace.
//!
//! The gateway (and, underneath it, [`crate::engine::EngineCore`])
//! enforces two per-request lifecycle events beyond completion: a client
//! disconnect (`Request::cancel_at`) and a completion deadline
//! (`Request::deadline`).  This module draws those instants from a
//! [`LifecycleProfile`] with the trace's own RNG discipline, so
//! lifecycle-heavy scenarios are exactly as reproducible as the arrival
//! process itself — a (trace, profile, seed) triple is one bitwise
//! run.  Arrival order and every pre-existing field are left untouched:
//! annotation composes with any generator in this module tree.

use crate::util::rng::Rng;
use crate::workload::Request;

/// Distribution of lifecycle events over a trace.  Fractions are
/// per-request probabilities; times are drawn relative to each request's
/// own arrival, so the profile is rate-independent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LifecycleProfile {
    /// Probability a request's client disconnects before completion.
    pub cancel_frac: f64,
    /// Lognormal (mu, sigma) of the disconnect delay after arrival, s.
    pub cancel_mu: f64,
    pub cancel_sigma: f64,
    /// Probability a request carries a completion deadline.
    pub deadline_frac: f64,
    /// Lognormal (mu, sigma) of the deadline slack after arrival, s.
    pub deadline_mu: f64,
    pub deadline_sigma: f64,
}

impl LifecycleProfile {
    /// Impatient-client regime: roughly half the trace disconnects, most
    /// within a couple of seconds of arriving — the cancel path carries
    /// real load.  No deadlines.
    pub fn cancellation_heavy() -> LifecycleProfile {
        LifecycleProfile {
            cancel_frac: 0.5,
            cancel_mu: 0.0, // median 1 s
            cancel_sigma: 0.8,
            deadline_frac: 0.0,
            deadline_mu: 0.0,
            deadline_sigma: 0.0,
        }
    }

    /// Interactive-SLA regime: every request must complete within a tight
    /// budget (median ~1.5 s) or be dropped as expired.  No disconnects.
    pub fn deadline_tight() -> LifecycleProfile {
        LifecycleProfile {
            cancel_frac: 0.0,
            cancel_mu: 0.0,
            cancel_sigma: 0.0,
            deadline_frac: 1.0,
            deadline_mu: 0.4, // median ~1.5 s
            deadline_sigma: 0.4,
        }
    }
}

/// Stamp lifecycle annotations onto `trace` in place, deterministically
/// from `seed`.  Each request draws its lottery and delays from a
/// per-request fork of the stream, so inserting or removing requests
/// elsewhere in the trace cannot shift another request's annotations.
pub fn annotate_lifecycle(trace: &mut [Request], p: &LifecycleProfile, seed: u64) {
    let base = seed ^ 0x11FE_C7C1_E5EED;
    for r in trace.iter_mut() {
        let mut rr = Rng::new(base ^ r.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        if rr.f64() < p.cancel_frac {
            r.cancel_at = Some(r.arrival + rr.lognormal(p.cancel_mu, p.cancel_sigma));
        }
        if rr.f64() < p.deadline_frac {
            r.deadline = Some(r.arrival + rr.lognormal(p.deadline_mu, p.deadline_sigma));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_n_requests, Dataset};

    #[test]
    fn annotation_is_deterministic_and_in_range() {
        let base = generate_n_requests(&Dataset::sharegpt(), 5.0, 40, 3);
        let mut a = base.clone();
        let mut b = base.clone();
        annotate_lifecycle(&mut a, &LifecycleProfile::cancellation_heavy(), 9);
        annotate_lifecycle(&mut b, &LifecycleProfile::cancellation_heavy(), 9);
        assert_eq!(a, b);
        let cancelled = a.iter().filter(|r| r.cancel_at.is_some()).count();
        assert!(cancelled > 0 && cancelled < a.len(), "cancel lottery degenerate: {cancelled}");
        for r in &a {
            if let Some(t) = r.cancel_at {
                assert!(t > r.arrival, "cancel before arrival: {t} vs {}", r.arrival);
            }
            assert!(r.deadline.is_none(), "cancellation-heavy profile sets no deadlines");
        }
    }

    #[test]
    fn deadline_profile_covers_every_request() {
        let mut t = generate_n_requests(&Dataset::sharegpt(), 5.0, 20, 4);
        annotate_lifecycle(&mut t, &LifecycleProfile::deadline_tight(), 11);
        for r in &t {
            let d = r.deadline.expect("deadline_tight stamps every request");
            assert!(d > r.arrival);
            assert!(r.cancel_at.is_none());
        }
    }

    #[test]
    fn annotations_are_per_request_stable() {
        // removing a request must not shift its neighbors' draws
        let mut full = generate_n_requests(&Dataset::sharegpt(), 5.0, 10, 5);
        let mut tail: Vec<Request> = full[1..].to_vec();
        annotate_lifecycle(&mut full, &LifecycleProfile::cancellation_heavy(), 2);
        annotate_lifecycle(&mut tail, &LifecycleProfile::cancellation_heavy(), 2);
        assert_eq!(&full[1..], &tail[..]);
    }
}
