//! Workload generation: request traces with Poisson arrivals and
//! dataset-shaped length distributions.
//!
//! The paper evaluates on ShareGPT (conversation), Azure-Code (production
//! code completion) and arXiv-Summary (long-document summarization).  The
//! raw datasets are not available offline, so we model their published
//! input/output length CDFs (paper Fig. 10 and the source works
//! [4, 35, 49, 71]) with clipped lognormal distributions whose medians /
//! tails match the reported shapes.  The scheduler only ever observes
//! (arrival time, input_len, output_len), so this preserves everything
//! the experiments measure.

pub mod lifecycle;
pub mod sessions;

pub use lifecycle::{annotate_lifecycle, LifecycleProfile};
pub use sessions::{generate_conversational, generate_n_turns, generate_sessions, SessionProfile};

use crate::util::rng::Rng;

/// One request in a trace.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    /// Prompt length in tokens.
    pub input_len: usize,
    /// Number of tokens to generate.
    pub output_len: usize,
    /// Chained per-block content hashes of the prompt (entry `i` covers
    /// KV blocks `0..=i`), the identity the prefix cache matches on.
    /// Empty ⇒ unique content that can never be shared — the default for
    /// the single-turn datasets.  Produced by [`sessions`].
    pub block_hashes: Vec<u64>,
    /// Conversation id for multi-turn workloads; later turns of a session
    /// re-send earlier context, and the prefix-affinity router uses this
    /// to pin a session to the replica already holding its KV.
    pub session_id: Option<u64>,
    /// Absolute instant (trace clock) the client disconnects and the
    /// request should be cancelled, freeing its KV mid-flight.  `None`
    /// (the default) means the client waits forever — lifecycle-free
    /// traces behave bit-identically to before the field existed.
    /// Produced by [`lifecycle::annotate_lifecycle`].
    pub cancel_at: Option<f64>,
    /// Absolute completion deadline: past this instant the request is
    /// dropped as `Expired` instead of consuming further GPU work.
    /// `None` (the default) disables the deadline.
    pub deadline: Option<f64>,
}

/// Dataset model: clipped-lognormal input/output token lengths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dataset {
    pub name: &'static str,
    pub in_mu: f64,
    pub in_sigma: f64,
    pub in_min: usize,
    pub in_max: usize,
    pub out_mu: f64,
    pub out_sigma: f64,
    pub out_min: usize,
    pub out_max: usize,
}

impl Dataset {
    /// ShareGPT: conversational, short-to-medium prompts, medium outputs.
    pub fn sharegpt() -> Dataset {
        Dataset {
            name: "sharegpt",
            in_mu: 5.55, // median ~257 tokens
            in_sigma: 1.0,
            in_min: 8,
            in_max: 4096,
            out_mu: 5.3, // median ~200
            out_sigma: 0.8,
            out_min: 4,
            out_max: 1024,
        }
    }

    /// Azure-Code: production code completion — long prompts, short outputs.
    pub fn azure_code() -> Dataset {
        Dataset {
            name: "azure-code",
            in_mu: 7.3, // median ~1480
            in_sigma: 0.9,
            in_min: 64,
            in_max: 12288,
            out_mu: 3.4, // median ~30
            out_sigma: 0.9,
            out_min: 2,
            out_max: 256,
        }
    }

    /// arXiv-Summary: long-context summarization — very long prompts.
    pub fn arxiv_summary() -> Dataset {
        Dataset {
            name: "arxiv-summary",
            in_mu: 8.6, // median ~5430
            in_sigma: 0.6,
            in_min: 512,
            in_max: 16384,
            out_mu: 5.0, // median ~148
            out_sigma: 0.5,
            out_min: 32,
            out_max: 512,
        }
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name {
            "sharegpt" => Some(Dataset::sharegpt()),
            "azure-code" => Some(Dataset::azure_code()),
            "arxiv-summary" => Some(Dataset::arxiv_summary()),
            _ => None,
        }
    }

    pub fn all() -> [Dataset; 3] {
        [
            Dataset::sharegpt(),
            Dataset::azure_code(),
            Dataset::arxiv_summary(),
        ]
    }

    fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, lo: usize, hi: usize) -> usize {
        let x = rng.lognormal(mu, sigma);
        (x.round() as usize).clamp(lo, hi)
    }

    pub fn sample_input(&self, rng: &mut Rng) -> usize {
        Self::sample_len(rng, self.in_mu, self.in_sigma, self.in_min, self.in_max)
    }

    pub fn sample_output(&self, rng: &mut Rng) -> usize {
        Self::sample_len(rng, self.out_mu, self.out_sigma, self.out_min, self.out_max)
    }
}

/// Trace generator: Poisson arrivals at `rate` req/s over `duration` s.
pub fn generate_trace(dataset: &Dataset, rate: f64, duration: f64, seed: u64) -> Vec<Request> {
    assert!(rate > 0.0 && duration > 0.0);
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exponential(rate);
        if t >= duration {
            break;
        }
        out.push(Request {
            id,
            arrival: t,
            input_len: dataset.sample_input(&mut rng),
            output_len: dataset.sample_output(&mut rng),
            block_hashes: Vec::new(),
            session_id: None,
            cancel_at: None,
            deadline: None,
        });
        id += 1;
    }
    out
}

/// Generate a fixed number of requests (rate-shaped arrivals, unbounded
/// duration) — convenient for closed experiments.
pub fn generate_n_requests(dataset: &Dataset, rate: f64, n: usize, seed: u64) -> Vec<Request> {
    assert!(rate > 0.0, "generate_n_requests: rate must be positive, got {rate}");
    let mut rng = Rng::new(seed ^ 0xABCDEF);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    for id in 0..n {
        t += rng.exponential(rate);
        out.push(Request {
            id: id as u64,
            arrival: t,
            input_len: dataset.sample_input(&mut rng),
            output_len: dataset.sample_output(&mut rng),
            block_hashes: Vec::new(),
            session_id: None,
            cancel_at: None,
            deadline: None,
        });
    }
    out
}

/// A burst trace: `base_rate` with a `burst_rate` window in the middle —
/// used by the Fig. 12 timeline experiment to show adaptation to spikes.
pub fn generate_bursty_trace(
    dataset: &Dataset,
    base_rate: f64,
    burst_rate: f64,
    duration: f64,
    burst_start: f64,
    burst_len: f64,
    seed: u64,
) -> Vec<Request> {
    assert!(
        base_rate > 0.0 && burst_rate > 0.0 && duration > 0.0,
        "generate_bursty_trace: rates and duration must be positive \
         (base {base_rate}, burst {burst_rate}, duration {duration})"
    );
    let mut rng = Rng::new(seed ^ 0x5DEECE66D);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        let rate = if t >= burst_start && t < burst_start + burst_len {
            burst_rate
        } else {
            base_rate
        };
        t += rng.exponential(rate);
        if t >= duration {
            break;
        }
        out.push(Request {
            id,
            arrival: t,
            input_len: dataset.sample_input(&mut rng),
            output_len: dataset.sample_output(&mut rng),
            block_hashes: Vec::new(),
            session_id: None,
            cancel_at: None,
            deadline: None,
        });
        id += 1;
    }
    out
}

/// Workload catalog: the single-turn [`Dataset`]s plus the multi-turn
/// session workloads registered in [`SessionProfile::by_name`]
/// (`conversational`) — one entry point for the CLI and examples.  For
/// session workloads, `rate` is interpreted as the target *request*
/// rate (sessions arrive at `rate / mean-turns`).
pub fn trace_by_name(name: &str, rate: f64, n: usize, seed: u64) -> Option<Vec<Request>> {
    if let Some(p) = SessionProfile::by_name(name) {
        return Some(generate_n_turns(&p, rate, n, seed));
    }
    Dataset::by_name(name).map(|d| generate_n_requests(&d, rate, n, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn poisson_rate_approximately_met() {
        let trace = generate_trace(&Dataset::sharegpt(), 10.0, 100.0, 1);
        let rate = trace.len() as f64 / 100.0;
        assert!((rate - 10.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        let trace = generate_trace(&Dataset::azure_code(), 5.0, 60.0, 2);
        for w in trace.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(trace.iter().all(|r| r.arrival < 60.0));
        assert!(trace.iter().all(|r| r.input_len >= 64 && r.input_len <= 12288));
    }

    #[test]
    fn dataset_shapes_ordered() {
        // arXiv prompts >> Azure-Code prompts >> ShareGPT prompts (median).
        let mut rng = Rng::new(3);
        let med = |d: &Dataset, rng: &mut Rng| {
            let mut v: Vec<f64> = (0..2000).map(|_| d.sample_input(rng) as f64).collect();
            // total_cmp: NaN-proof total order (matches the SloScheduler
            // reorder fix — partial_cmp().unwrap() would panic on NaN)
            v.sort_by(f64::total_cmp);
            stats::percentile_sorted(&v, 50.0)
        };
        let sg = med(&Dataset::sharegpt(), &mut rng);
        let az = med(&Dataset::azure_code(), &mut rng);
        let ax = med(&Dataset::arxiv_summary(), &mut rng);
        assert!(sg < az && az < ax, "medians {sg} {az} {ax}");
        assert!(ax > 4000.0, "arxiv median {ax}");
    }

    #[test]
    fn azure_outputs_short() {
        let mut rng = Rng::new(4);
        let d = Dataset::azure_code();
        let mean = (0..2000).map(|_| d.sample_output(&mut rng) as f64).sum::<f64>() / 2000.0;
        assert!(mean < 100.0, "mean output {mean}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate_trace(&Dataset::sharegpt(), 8.0, 30.0, 7);
        let b = generate_trace(&Dataset::sharegpt(), 8.0, 30.0, 7);
        assert_eq!(a, b);
        let c = generate_trace(&Dataset::sharegpt(), 8.0, 30.0, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn bursty_trace_rate_shift() {
        let trace = generate_bursty_trace(
            &Dataset::azure_code(), 2.0, 20.0, 90.0, 30.0, 30.0, 5,
        );
        let before = trace.iter().filter(|r| r.arrival < 30.0).count();
        let during = trace
            .iter()
            .filter(|r| (30.0..60.0).contains(&r.arrival))
            .count();
        assert!(during as f64 > 4.0 * before as f64, "before {before} during {during}");
    }

    #[test]
    fn n_requests_exact_count() {
        let t = generate_n_requests(&Dataset::sharegpt(), 5.0, 123, 9);
        assert_eq!(t.len(), 123);
        assert_eq!(t.last().unwrap().id, 122);
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(Dataset::by_name("sharegpt").unwrap().name, "sharegpt");
        assert!(Dataset::by_name("nope").is_none());
    }

    #[test]
    fn trace_by_name_covers_all_workloads() {
        for name in ["sharegpt", "azure-code", "arxiv-summary", "conversational"] {
            let t = trace_by_name(name, 5.0, 20, 3).unwrap();
            assert_eq!(t.len(), 20, "{name}");
        }
        assert!(trace_by_name("nope", 5.0, 20, 3).is_none());
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn n_requests_rejects_non_positive_rate() {
        generate_n_requests(&Dataset::sharegpt(), 0.0, 10, 1);
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn bursty_trace_rejects_non_positive_base_rate() {
        generate_bursty_trace(&Dataset::sharegpt(), 0.0, 10.0, 60.0, 20.0, 10.0, 1);
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn bursty_trace_rejects_non_positive_burst_rate() {
        generate_bursty_trace(&Dataset::sharegpt(), 5.0, -1.0, 60.0, 20.0, 10.0, 1);
    }

    #[test]
    fn single_turn_requests_carry_no_content_identity() {
        let t = generate_n_requests(&Dataset::sharegpt(), 5.0, 5, 8);
        assert!(t.iter().all(|r| r.block_hashes.is_empty() && r.session_id.is_none()));
    }
}
