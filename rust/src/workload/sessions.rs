//! Multi-turn conversational workloads: the traffic shape where prefix
//! reuse pays.
//!
//! A *session* is one user's conversation with one *tenant* (a product
//! surface with a shared system prompt).  Turn `t`'s prompt is the
//! system prompt, the whole conversation so far (earlier prompts and
//! replies re-sent verbatim), and a fresh user message — so consecutive
//! turns share an ever-growing token prefix, and sessions of the same
//! tenant share at least the system prompt.  Turns are separated by
//! lognormal *think-time* gaps.
//!
//! Content is abstracted the same way the length distributions are: a
//! block's "contents" are a deterministic function of (tenant, block
//! index) inside the system prompt and (session, block index) after it,
//! folded into the chained [`Request::block_hashes`] the prefix cache
//! matches on.  Identical real prefixes ⇒ identical chains; the chain
//! breaks at the first divergent block.  Generated reply tokens are
//! treated as recomputed-on-resend (they only become cacheable once the
//! next turn's prefill publishes them), which conservatively models
//! tokenization drift between generation and re-submission.

use crate::kvcache::BLOCK_TOKENS;
use crate::util::rng::Rng;
use crate::workload::Request;

/// splitmix64-style combiner for content identities.
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fold per-block contents into the chained hash form the prefix cache
/// matches on (hash `i` covers blocks `0..=i`).  THE chaining scheme:
/// `hash_chain` below and `testing::content_chain` both build on it.
pub(crate) fn chain_hashes(contents: impl Iterator<Item = u64>) -> Vec<u64> {
    let mut out = Vec::new();
    let mut h = 0xB10Cu64;
    for c in contents {
        h = mix(h, c);
        out.push(h);
    }
    out
}

/// Shape of a conversational workload.
#[derive(Debug, Clone)]
pub struct SessionProfile {
    pub name: &'static str,
    /// Distinct tenants, each with its own shared system prompt.
    pub tenants: usize,
    /// System-prompt tokens (identical across a tenant's sessions).
    pub system_prompt_tokens: usize,
    /// Turns per session, uniform in `[min_turns, max_turns]`.
    pub min_turns: usize,
    pub max_turns: usize,
    /// Per-turn user-message tokens: clipped lognormal.
    pub user_mu: f64,
    pub user_sigma: f64,
    pub user_min: usize,
    pub user_max: usize,
    /// Per-turn reply tokens: clipped lognormal.
    pub out_mu: f64,
    pub out_sigma: f64,
    pub out_min: usize,
    pub out_max: usize,
    /// Think-time gap between consecutive turn arrivals: lognormal, s.
    pub think_mu: f64,
    pub think_sigma: f64,
    /// Prompt-length cap (production context limits truncate history).
    pub max_input_tokens: usize,
}

impl SessionProfile {
    /// The default `conversational` workload: assistant-style traffic
    /// with a 512-token shared system prompt per tenant.
    pub fn conversational() -> SessionProfile {
        SessionProfile {
            name: "conversational",
            tenants: 4,
            system_prompt_tokens: 512,
            min_turns: 2,
            max_turns: 8,
            user_mu: 4.4, // median ~81 tokens
            user_sigma: 0.7,
            user_min: 8,
            user_max: 1024,
            out_mu: 5.0, // median ~148
            out_sigma: 0.6,
            out_min: 16,
            out_max: 512,
            think_mu: 2.2, // median ~9 s
            think_sigma: 0.8,
            max_input_tokens: 12288,
        }
    }

    pub fn by_name(name: &str) -> Option<SessionProfile> {
        match name {
            "conversational" => Some(SessionProfile::conversational()),
            _ => None,
        }
    }

    /// Expected turns per session (uniform distribution midpoint).
    pub fn mean_turns(&self) -> f64 {
        (self.min_turns + self.max_turns) as f64 / 2.0
    }
}

fn sample_len(rng: &mut Rng, mu: f64, sigma: f64, lo: usize, hi: usize) -> usize {
    (rng.lognormal(mu, sigma).round() as usize).clamp(lo, hi)
}

/// Chained content hashes for one turn's prompt: block `b` carries the
/// tenant's system-prompt content while it lies wholly inside it, the
/// session's own history after.  Depending only on (tenant,
/// content-seed, block index), the chain is identical across a
/// session's turns as far as their prompts actually agree —
/// longest-prefix-match fodder.  Capped (truncated) turns pass a
/// per-turn `content_seed`, since a sliding context window shifts
/// every non-system block's contents.
fn hash_chain(
    system_prompt_tokens: usize,
    tenant_seed: u64,
    content_seed: u64,
    input_len: usize,
) -> Vec<u64> {
    let blocks = input_len / BLOCK_TOKENS;
    chain_hashes((0..blocks).map(|b| {
        if (b + 1) * BLOCK_TOKENS <= system_prompt_tokens {
            mix(tenant_seed, b as u64)
        } else {
            mix(content_seed, b as u64)
        }
    }))
}

/// Generate `n_sessions` sessions whose starts are Poisson at
/// `session_rate` sessions/s.  Returns all turns of all sessions merged
/// into one arrival-ordered trace with ids `0..len`.
pub fn generate_sessions(
    p: &SessionProfile,
    session_rate: f64,
    n_sessions: usize,
    seed: u64,
) -> Vec<Request> {
    assert!(
        session_rate > 0.0,
        "generate_sessions: session_rate must be positive, got {session_rate}"
    );
    assert!(n_sessions > 0 && p.tenants > 0);
    assert!(p.min_turns >= 1 && p.min_turns <= p.max_turns);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let mut reqs: Vec<Request> = Vec::new();
    let mut start = 0.0f64;
    for s in 0..n_sessions {
        start += rng.exponential(session_rate);
        let tenant = rng.below(p.tenants as u64);
        let session_id = mix(seed, 0x5E55 ^ (s as u64 + 1));
        let tenant_seed = mix(seed, 0x7E4A ^ tenant);
        let turns = p.min_turns + rng.below((p.max_turns - p.min_turns + 1) as u64) as usize;
        // tokens the next prompt re-sends (system prompt + history)
        let mut history = p.system_prompt_tokens;
        let mut arrival = start;
        for turn in 0..turns {
            let user = sample_len(&mut rng, p.user_mu, p.user_sigma, p.user_min, p.user_max);
            let capped = history + user > p.max_input_tokens;
            let input_len = (history + user).min(p.max_input_tokens);
            let output_len = sample_len(&mut rng, p.out_mu, p.out_sigma, p.out_min, p.out_max);
            // Context truncation slides the non-system window, shifting
            // every block's contents — so a capped turn shares only the
            // system prompt with its neighbors (per-turn content epoch),
            // instead of spuriously matching the previous capped prompt
            // bit-for-bit.
            let content_seed = if capped {
                mix(session_id, 0xCA11 ^ (turn as u64 + 1))
            } else {
                session_id
            };
            reqs.push(Request {
                id: 0, // assigned after the arrival sort
                arrival,
                input_len,
                output_len,
                block_hashes: hash_chain(p.system_prompt_tokens, tenant_seed, content_seed, input_len),
                session_id: Some(session_id),
                cancel_at: None,
                deadline: None,
            });
            history = input_len + output_len;
            arrival += rng.lognormal(p.think_mu, p.think_sigma);
        }
    }
    // stable sort: same-instant turns keep session order
    reqs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i as u64;
    }
    reqs
}

/// CLI-shaped entry point for any profile: approximately `n_requests`
/// requests arriving at roughly `rate` requests/s (sessions at
/// `rate / mean-turns`), truncated to exactly `n_requests`.
pub fn generate_n_turns(p: &SessionProfile, rate: f64, n_requests: usize, seed: u64) -> Vec<Request> {
    assert!(n_requests > 0, "generate_n_turns: need at least one request");
    // oversample sessions so truncation, not exhaustion, sets the count
    // (turn counts are random, so double until the trace is long enough)
    let mut sessions = ((n_requests as f64 / p.mean_turns()).ceil() as usize).max(1) * 2;
    loop {
        let mut reqs = generate_sessions(p, rate / p.mean_turns(), sessions, seed);
        if reqs.len() >= n_requests {
            reqs.truncate(n_requests);
            return reqs;
        }
        sessions *= 2;
    }
}

/// [`generate_n_turns`] over the default `conversational` profile.
pub fn generate_conversational(rate: f64, n_requests: usize, seed: u64) -> Vec<Request> {
    generate_n_turns(&SessionProfile::conversational(), rate, n_requests, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn per_session(trace: &[Request]) -> BTreeMap<u64, Vec<&Request>> {
        let mut m: BTreeMap<u64, Vec<&Request>> = BTreeMap::new();
        for r in trace {
            m.entry(r.session_id.unwrap()).or_default().push(r);
        }
        m
    }

    #[test]
    fn deterministic_and_arrival_ordered() {
        let p = SessionProfile::conversational();
        let a = generate_sessions(&p, 1.0, 20, 7);
        let b = generate_sessions(&p, 1.0, 20, 7);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        let c = generate_sessions(&p, 1.0, 20, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn later_turns_extend_the_conversation_prefix() {
        let p = SessionProfile::conversational();
        let trace = generate_sessions(&p, 2.0, 12, 3);
        let mut checked = 0;
        for turns in per_session(&trace).values() {
            for w in turns.windows(2) {
                let (prev, next) = (w[0], w[1]);
                assert!(next.arrival > prev.arrival);
                // the next prompt re-sends the previous prompt + reply
                assert!(
                    next.input_len > prev.input_len
                        || next.input_len == p.max_input_tokens,
                    "prompt must grow (or cap): {} -> {}",
                    prev.input_len,
                    next.input_len
                );
                // hash chains agree exactly over the previous prompt's
                // full blocks — what the prefix cache will match.
                // (Capped turns intentionally diverge: truncation slides
                // the window, so only the system prompt survives.)
                if next.input_len < p.max_input_tokens {
                    let shared = prev.input_len / BLOCK_TOKENS;
                    assert!(next.block_hashes.len() >= shared);
                    assert_eq!(
                        &next.block_hashes[..shared],
                        &prev.block_hashes[..shared],
                        "turn chain must extend its predecessor"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 0, "need at least one multi-turn session");
    }

    #[test]
    fn same_tenant_chains_share_only_the_system_prompt() {
        let sys = 512;
        let sys_blocks = sys / BLOCK_TOKENS;
        // two sessions of one tenant agree exactly on the system prompt
        let a = hash_chain(sys, 77, 1001, 1024);
        let b = hash_chain(sys, 77, 2002, 1024);
        assert_eq!(&a[..sys_blocks], &b[..sys_blocks]);
        assert_ne!(a[sys_blocks], b[sys_blocks], "histories diverge after the system prompt");
        // chained hashing: a single divergence poisons everything after
        assert!(a[sys_blocks..].iter().zip(&b[sys_blocks..]).all(|(x, y)| x != y));
        // different tenants diverge from block 0
        let c = hash_chain(sys, 78, 1001, 1024);
        assert_ne!(a[0], c[0]);
    }

    #[test]
    fn first_block_identity_is_per_tenant() {
        let p = SessionProfile::conversational();
        let trace = generate_sessions(&p, 2.0, 16, 11);
        let sessions = per_session(&trace);
        let firsts: std::collections::BTreeSet<u64> =
            sessions.values().map(|t| t[0].block_hashes[0]).collect();
        assert!(
            firsts.len() <= p.tenants,
            "first block depends only on the tenant: {} > {}",
            firsts.len(),
            p.tenants
        );
    }

    #[test]
    fn capped_turns_share_only_the_system_prompt() {
        // growth floors guarantee the cap engages by the 4th turn, so
        // the last two turns of every session are both capped
        let p = SessionProfile {
            min_turns: 5,
            max_turns: 5,
            user_min: 64,
            out_min: 64,
            max_input_tokens: 896,
            ..SessionProfile::conversational()
        };
        let trace = generate_sessions(&p, 4.0, 4, 7);
        let sys_blocks = p.system_prompt_tokens / BLOCK_TOKENS;
        let mut capped_pairs = 0;
        for turns in per_session(&trace).values() {
            for w in turns.windows(2) {
                let (a, b) = (w[0], w[1]);
                if a.input_len == p.max_input_tokens && b.input_len == p.max_input_tokens {
                    // truncation slides the window: identical lengths,
                    // but only the system prompt may match
                    assert_eq!(&a.block_hashes[..sys_blocks], &b.block_hashes[..sys_blocks]);
                    assert_ne!(
                        a.block_hashes[sys_blocks], b.block_hashes[sys_blocks],
                        "capped prompts must not alias bit-for-bit"
                    );
                    capped_pairs += 1;
                }
            }
        }
        assert!(capped_pairs >= 4, "every session must end with capped turns: {capped_pairs}");
    }

    #[test]
    fn prompts_respect_the_context_cap() {
        let p = SessionProfile {
            min_turns: 8,
            max_turns: 12,
            max_input_tokens: 2048,
            ..SessionProfile::conversational()
        };
        let trace = generate_sessions(&p, 4.0, 8, 13);
        assert!(trace.iter().all(|r| r.input_len <= 2048));
        // capped prompts still hash to capped chains
        assert!(trace.iter().all(|r| r.block_hashes.len() == r.input_len / BLOCK_TOKENS));
    }

    #[test]
    fn conversational_entry_point_counts_and_ids() {
        let t = generate_conversational(10.0, 77, 21);
        assert_eq!(t.len(), 77);
        for (i, r) in t.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.session_id.is_some());
            assert!(!r.block_hashes.is_empty() || r.input_len < BLOCK_TOKENS);
        }
        assert_eq!(t, generate_conversational(10.0, 77, 21));
    }

    #[test]
    #[should_panic(expected = "session_rate must be positive")]
    fn rejects_non_positive_session_rate() {
        generate_sessions(&SessionProfile::conversational(), 0.0, 4, 1);
    }
}
