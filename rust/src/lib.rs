//! Bullet: boosting GPU utilization for LLM serving via dynamic
//! spatial-temporal orchestration — a full reproduction of the paper's
//! system as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L3 (this crate)**: the serving coordinator — SLO-aware scheduler,
//!   computational resource manager, concurrent prefill/decode engines —
//!   plus every substrate the paper depends on (an A100-like GPU simulator
//!   with SM-masked streams, paged KV cache, workload generators, and the
//!   chunked-prefill / NanoFlow / static-partition baselines).
//! - **L2 (python/compile/model.py)**: a Llama-style transformer in JAX,
//!   AOT-lowered to HLO text artifacts executed here via PJRT.
//! - **L1 (python/compile/kernels/)**: Pallas attention kernels called by
//!   L2, validated against a pure-jnp oracle.
//!
//! # Architecture
//!
//! The serving stack is three tiers, each a thin layer over the one
//! below:
//!
//! ```text
//!  autoscaler─ calibration-driven fleet control (opt-in): grow, shrink,
//!              retire and re-profile the replica set from the same
//!              signals the routers read
//!  cluster   ─ N replicas behind a Dispatcher (round-robin / least-kv /
//!              slo-slack / prefix-affinity routing); each replica =
//!              core + policy
//!  policies  ─ decisions only: BulletPolicy (dynamic SM partitioning,
//!              Algorithm 1), ChunkedPolicy (vLLM/SGLang lock-step),
//!              NanoflowPolicy (nano-batch overlap), the intra-GPU P/D
//!              disaggregation family (static / proactive / temporal-mux
//!              splits), plus Bullet feature masks for the ablations and
//!              MuxServe-style fixed quotas
//!  core      ─ mechanisms only: EngineCore owns the virtual-clock event
//!              loop, admission (incl. the prefix-cache fast path), KV
//!              reserve/release, prefill→decode migration, timeline
//!              sampling and RequestRecord emission
//! ```
//!
//! **Serving core** ([`engine::core`]).  [`engine::EngineCore`] drives
//! admission → plan → advance → completions over the simulated GPU with
//! two execution *lanes* (prefill, decode).  A policy implements
//! [`engine::ServingPolicy`]: `plan` launches kernels at lane
//! boundaries, `on_drain` applies lifecycle effects when a lane's
//! kernels finish.  Planning per-lane gives Bullet's decoupled engines;
//! planning only when all lanes are idle gives lock-step (chunked) or
//! barrier-overlap (NanoFlow) execution.
//!
//! **Policies** ([`engine::sim_engine`], [`baselines`]).  Every system
//! the evaluation compares is a policy over the same core, so results
//! differ only by decisions, never by bookkeeping.  The
//! [`baselines::System`] enum is the catalog; `System::policy()` is the
//! factory.
//!
//! **Cluster** ([`cluster`]).  [`cluster::serve_cluster`] runs N
//! replicas of any system behind a [`cluster::RouterPolicy`]; replicas
//! co-advance along the global virtual timeline — in parallel on a
//! `sim_threads` worker pool between dispatch horizons, with bitwise
//! determinism as a tested invariant (`tests/parallel_parity.rs`) —
//! and state-aware routers read [`cluster::ReplicaSignals`] snapshots
//! frozen at each horizon barrier.  Surfaced through
//! `BulletServer::serve_cluster`, the CLI (`--replicas N --router
//! <policy> --sim-threads N`) and `examples/cluster_scaling.rs`;
//! `examples/bench_runner.rs` records the perf trajectory
//! (`BENCH_8.json`, gated by CI's `bench` job).
//!
//! **Competitor baselines** ([`baselines`]).  Five non-Bullet systems
//! share the core, each the strongest version of one resource-sharing
//! doctrine, and each has a regime where it is the one to beat:
//!
//! - *Chunked prefill* ([`baselines::chunked`], vLLM-1024 /
//!   SGLang-1024 / SGLang-2048): lock-step hybrid batches under a token
//!   budget.  Wins on decode-dominated steady state, where lock-step
//!   amortizes and TTFT pressure is low; loses TTFT whenever prompts
//!   must trickle through the chunk budget.
//! - *NanoFlow* ([`baselines::nanoflow`]): nano-batch overlap on top of
//!   chunked prefill.  Wins back intra-iteration idle time at high
//!   utilization; still inherits the chunk-budget TTFT floor.
//! - *Static split* ([`baselines::disagg::StaticSplitPolicy`],
//!   RAPID-Serve style, `--pd-split R`): a frozen disjoint SM
//!   partition.  Wins when the phase mix is stationary and known —
//!   dial the knob to the workload and nothing beats zero decision
//!   overhead; strands SMs the moment the mix shifts.
//! - *Proactive split* ([`baselines::disagg::ProactiveSplitPolicy`],
//!   Nexus style): repartitions ahead of the predicted phase mix using
//!   the same calibrated [`perf::PerfPredictor`] Bullet plans with.
//!   Wins under slow phase-mix swings (bursty arrivals, shifting
//!   prompt mixes); lacks per-request SLO slack, so it cannot
//!   prioritize the request that is about to miss.
//! - *Temporal mux* ([`baselines::disagg::TemporalMuxPolicy`]):
//!   all-SM prefill epochs alternating with all-SM decode epochs.
//!   Wins on single-phase extremes (pure-prefill or pure-decode
//!   traffic) where any static split wastes the other side's SMs;
//!   each phase's tail absorbs the other's epoch everywhere else.
//!
//! Bullet's spatial-temporal sharing subsumes the disaggregation
//! family: the partition moves like proactive, pauses like temporal
//! mux, and is driven by per-request SLO slack none of them see.  The
//! `bench` job's fig11/fig13 legs gate that ordering.
//!
//! **Hot-path caches** (`ServingConfig::memo`, default on).  Three
//! memoizations keep per-event work off the serving fast path: the
//! simulator's rate table ([`gpu::simulator`] — per-stream rates are a
//! pure function of active kernels, masks and the drift clock, so
//! steady-state stepping reuses one cached table, allocation-free),
//! the scheduler's hoisted per-cycle aggregates ([`sched::policy`] —
//! candidate-independent per-request terms computed once per cycle),
//! and the calibrated-prediction / router-probe memos ([`perf`],
//! [`cluster`] — predictions keyed behind a calibration epoch, the
//! slo-slack probe keyed on `(num_sms, contended)` against the frozen
//! fleet model).  All are pure accelerations: `--memo off` disables
//! every one and the parity suites (`tests/parallel_parity.rs`,
//! `tests/scenario_matrix.rs`) assert bit-identical output; hit/miss/
//! invalidation counters surface as observability (never
//! parity-compared), and `benches/perf_hotpath.rs` cases 8–10 record
//! the wins.
//!
//! **Performance modeling: offline profile → online calibration**
//! ([`perf`]).  Prediction is consumed through the
//! [`perf::PerfPredictor`] trait — [`sched::SloScheduler`] is generic
//! over it and never names a concrete model.  [`perf::PerfModel`] is
//! the frozen §3.2 offline-profiled implementation;
//! [`perf::OnlineCalibrator`] wraps it in a closed feedback loop: the
//! Bullet policy replays every lane-drain boundary as a
//! `(shape, partition, observed)` sample, per-cell correction ratios
//! EWMA-update with sample-count-gated confidence (cold cells fall
//! back to the offline grid bit-for-bit), and a residual-trend
//! detector widens the learning rate on regime changes.  The simulated
//! silicon can leave the profiled regime via [`config::DriftSpec`]
//! (thermal throttling and a phantom SM co-tenant stretch the compute
//! term — prefill feels them fully, memory-bound decode barely — plus
//! a per-device lottery), and cluster fleets go heterogeneous via
//! [`cluster::ClusterConfig`]`::replica_specs`; each replica
//! calibrates independently and the slo-slack router reads calibrated,
//! not nominal, replica speed.  All of it is off by default
//! (`--calibration on`, `--drift <regime>`), and
//! `examples/online_calibration.rs` asserts the calibrated-vs-frozen
//! win under drift.
//!
//! **The autoscaling loop** ([`cluster::autoscale`]).  The calibration
//! signals close a second, fleet-level loop on top of the per-GPU one:
//!
//! ```text
//!   calibrate ──► per-replica slowdown / drift events / residuals
//!       │                         │
//!       │                         ▼
//!       │   envelope: arrival-rate window × SLO headroom, priced in
//!       │   tokens/s via sched::policy::service_capacity_tokens_per_s
//!       │                         │
//!       ▼                         ▼
//!   capacity: Σ nominal/slowdown  ──►  Autoscaler (hysteresis:
//!       ▲                              separated thresholds + cool-downs)
//!       │                                │
//!       └── re-profile (grid refresh) ◄──┼──► scale out (spawn replica,
//!           when converged residual      │    inherited GpuSpec)
//!           stays high                   └──► scale in / retire (drain;
//!                                             prefix-affinity sessions
//!                                             re-home)
//! ```
//!
//! [`cluster::AutoscaleConfig`] (off by default — `serve_cluster` is
//! then bit-identical to the fixed-fleet path) rides
//! [`cluster::ClusterConfig`]; decisions land in
//! `ClusterOutput::scale_events`, the targeted replica's
//! `EngineOutput`/timeline, and the CLI (`--autoscale on
//! --min-replicas N --max-replicas N`).  `examples/autoscale.rs`
//! asserts the bars: an autoscaled fleet beats a fixed one on P90 TTFT
//! and goodput under a drift storm while consuming fewer replica-steps
//! than static max provisioning.
//!
//! **Live serving** ([`gateway`]).  [`gateway::serve_gateway`] is the
//! wall-clock front door over the same fleet: each trace arrival is
//! admitted at its instant on a pluggable [`gateway::GatewayClock`],
//! routed by the cluster's [`cluster::Dispatcher`], and streamed back
//! token-by-token over an in-tree mpsc channel
//! ([`gateway::StreamChunk`]).  Requests carry an optional lifecycle —
//! `Request::cancel_at` models the client disconnect (KV blocks decref
//! immediately, mid-decode) and `Request::deadline` is enforced inside
//! the engine ([`sched::deadline_should_drop`]); both are annotated onto
//! traces by [`workload::annotate_lifecycle`].  Failure injection
//! ([`cluster::FailureSpec`], also on the offline
//! [`cluster::ClusterConfig`]) crashes a replica at a chosen instant and
//! rides the retire machinery: prefix-affinity sessions re-home, cold
//! orphans re-queue on survivors (keeping their stream), in-flight work
//! is counted `Lost`, and accounting stays total —
//! `completed + cancelled + expired + lost == submitted`.  Under
//! [`gateway::VirtualClock`] the whole lifecycle is bit-deterministic
//! (CI asserts it); [`gateway::WallClock`] sleeps to the same instants
//! for real-time serving (`--live wall`, `examples/live_gateway.rs`).
//! All of it is off by default: lifecycle-free traces without failures
//! run bit-identically to the pre-gateway paths.
//!
//! **Session & prefix reuse** ([`kvcache`], [`workload::sessions`]).
//! The KV pool refcounts physical blocks, so sequences can share them:
//! [`kvcache::KvPool::fork`] clones a sequence copy-on-write and
//! [`kvcache::KvPool::adopt`] starts one on an already-cached prefix.
//! [`kvcache::prefix::PrefixIndex`] is a content-hash index over full
//! prompt blocks (chained hashes ⇒ block-granularity longest-prefix
//! match) with LRU eviction of cache-only blocks.  With
//! `ServingConfig::prefix_cache` on, [`engine::EngineCore`] matches each
//! arrival at admission, adopts the hit blocks, and charges only the
//! uncached suffix to the prefill path — the §3.2 estimator and the SM
//! partitioner see the reduced token count — then publishes the prompt's
//! blocks back to the index when its prefill completes; under memory
//! pressure `EngineCore::kv_room` first evicts LRU cached blocks, then
//! falls back to recompute (dropping idle adoptions).  The
//! `conversational` workload ([`workload::sessions`]) generates the
//! traffic that makes this pay — tenants with shared system prompts and
//! multi-turn sessions that re-send their history — and the
//! `prefix-affinity` router pins each session to the replica holding its
//! KV.  `examples/prefix_reuse.rs` demonstrates (and asserts) the
//! cache-on vs cache-off TTFT and goodput win; run metrics land in
//! `EngineOutput::prefix` (hit rate, cached-token ratio, tokens saved).
//!
//! **Observability** ([`obs`]).  Every simulated SM-second is charged to
//! exactly one category — prefill compute / prefill attention / decode /
//! wave-quantization padding / repartition transition / KV-blocked stall
//! / idle — in an [`obs::SmLedger`] accrued inside [`gpu::Simulator`]'s
//! advance path and finalized so the seven categories sum to
//! `num_sms × makespan` (a tested invariant in `tests/scenario_matrix.rs`
//! for every engine × workload cell).  The ledger surfaces per-engine on
//! `EngineOutput::ledger`, aggregates on `ClusterOutput::ledger()` /
//! `GatewayOutput::ledger()`, and prints as a CLI breakdown table for
//! every [`baselines::System`].  A structured span/event trace
//! ([`obs::TraceSpec`], off by default and bit-identical-off like the
//! memo caches) records request lifecycle spans and engine instants
//! (kernel launches, repartitions, KV stalls); `--trace out.json`
//! exports it as Chrome trace-event JSON ([`obs::export`], loadable in
//! Perfetto, byte-deterministic under fixed seed and any `sim_threads`),
//! and `tools/trace_summary.py` validates the file shape and replays
//! the ledger from the trace.
//!
//! ## Adding a serving policy (~100 lines)
//!
//! 1. Define a struct holding only your decision state (queues and KV
//!    live in the core).
//! 2. Implement [`engine::ServingPolicy`]: in `plan`, inspect
//!    `core.waiting` / `core.decode`, reserve KV via `core.kv`, and
//!    launch kernels with `core.submit(lane, stream, kernels)`; in
//!    `on_drain`, credit progress (`core.advance_decode_token()`,
//!    `core.finish_prefill(..)`).
//! 3. Wire it: add a [`baselines::System`] variant (one `policy()` match
//!    arm) and it runs in every experiment, test harness and the
//!    cluster for free.  See `rust/README.md` for a walkthrough.

pub mod util;
pub mod config;
pub mod obs;
pub mod gpu;
pub mod model;
pub mod perf;
pub mod kvcache;
pub mod sched;
pub mod resource;
pub mod engine;
pub mod coordinator;
pub mod baselines;
pub mod cluster;
pub mod gateway;
pub mod workload;
pub mod metrics;
pub mod runtime;
pub mod testing;
