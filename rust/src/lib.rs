//! Bullet: boosting GPU utilization for LLM serving via dynamic
//! spatial-temporal orchestration — a full reproduction of the paper's
//! system as a three-layer Rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L3 (this crate)**: the serving coordinator — SLO-aware scheduler,
//!   computational resource manager, concurrent prefill/decode engines —
//!   plus every substrate the paper depends on (an A100-like GPU simulator
//!   with SM-masked streams, paged KV cache, workload generators, and the
//!   chunked-prefill / NanoFlow / static-partition baselines).
//! - **L2 (python/compile/model.py)**: a Llama-style transformer in JAX,
//!   AOT-lowered to HLO text artifacts executed here via PJRT.
//! - **L1 (python/compile/kernels/)**: Pallas attention kernels called by
//!   L2, validated against a pure-jnp oracle.

pub mod util;
pub mod config;
pub mod gpu;
pub mod model;
pub mod perf;
pub mod kvcache;
pub mod sched;
pub mod resource;
pub mod engine;
pub mod coordinator;
pub mod baselines;
pub mod workload;
pub mod metrics;
pub mod runtime;
pub mod testing;
