//! System state S_k = (P_k, D_k, R_k) (§3.3.2).

use crate::resource::Partition;

/// A request known to the prefill side (queued or in the active batch).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PrefillReq {
    pub id: u64,
    pub arrival: f64,
    pub input_len: usize,
    pub output_len: usize,
    /// Prompt tokens already resident in the KV pool via a prefix-cache
    /// hit (block granularity, always < `input_len`).  The prefill
    /// engines charge only the `input_len - cached_len` suffix to the
    /// compute model; 0 with the cache off or on a miss.
    pub cached_len: usize,
}

/// P_k: the running prefill batch.
#[derive(Debug, Clone, Default)]
pub struct PrefillBatch {
    pub reqs: Vec<PrefillReq>,
    /// n_p: total tokens the batch must still compute (prefix-cached
    /// prompt tokens are excluded — the estimator and SM provisioning
    /// must see the reduced load).
    pub n_tokens: usize,
    /// Largest prefix-cached context across the batch: the suffix's
    /// attention reads this many cached KV tokens.
    pub ctx_cached: usize,
    /// l_k: layers already executed.
    pub layers_done: usize,
    /// Wall/virtual time the batch started executing.
    pub started_at: f64,
}

impl PrefillBatch {
    pub fn new(reqs: Vec<PrefillReq>, started_at: f64) -> PrefillBatch {
        let n_tokens = reqs.iter().map(|r| r.input_len - r.cached_len).sum();
        let ctx_cached = reqs.iter().map(|r| r.cached_len).max().unwrap_or(0);
        PrefillBatch {
            reqs,
            n_tokens,
            ctx_cached,
            layers_done: 0,
            started_at,
        }
    }
}

/// A queued request plus prefill progress — the shared waiting-queue
/// entry of the engine harness.  Chunk-based engines advance `done`
/// across iterations; whole-prompt engines (Bullet) leave it at 0 and
/// move the request into a [`PrefillBatch`] instead.  (This replaces the
/// private `Prefilling`/`PrefillProgress` structs the baselines used to
/// carry.)
#[derive(Debug, Clone, PartialEq)]
pub struct PrefillProgress {
    pub req: PrefillReq,
    /// Prompt tokens already prefilled.
    pub done: usize,
    /// Virtual time the first chunk/batch started executing (None while
    /// nothing has run — also the "KV reserved yet?" marker for chunk
    /// engines, which reserve at first launch).
    pub prefill_start: Option<f64>,
}

impl PrefillProgress {
    pub fn new(req: PrefillReq) -> PrefillProgress {
        PrefillProgress {
            req,
            done: 0,
            prefill_start: None,
        }
    }

    /// Prompt tokens still to prefill.
    pub fn remaining(&self) -> usize {
        self.req.input_len - self.done
    }
}

/// D_k entry: one request in the decode batch.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeReqState {
    pub id: u64,
    pub input_len: usize,
    /// Tokens of context currently cached (prompt + generated).
    pub ctx_len: usize,
    /// o_i: output tokens produced so far (including the first).
    pub tokens_out: usize,
    /// Target output length.
    pub output_len: usize,
    /// d_i: accumulated decode-phase time (since first token).
    pub decode_elapsed: f64,
}

impl DecodeReqState {
    /// Observed average TPOT so far (o_i / d_i of Algorithm 1, inverted
    /// to seconds per token).  Zero until a second token exists.
    pub fn observed_tpot(&self) -> f64 {
        if self.tokens_out <= 1 {
            0.0
        } else {
            self.decode_elapsed / (self.tokens_out - 1) as f64
        }
    }

    pub fn finished(&self) -> bool {
        self.tokens_out >= self.output_len
    }
}

/// A request in the decode batch plus the timing metadata every engine
/// tracks for it (unified from the engines' private `ActiveDecode` /
/// `Decoding` / `DecodeActive` structs).
#[derive(Debug, Clone, PartialEq)]
pub struct ActiveDecode {
    pub st: DecodeReqState,
    pub arrival: f64,
    pub prefill_start: f64,
    pub first_token_time: f64,
    /// Virtual time of this request's latest token — TPOT accounting
    /// charges the FULL gap between tokens (queueing, pauses, contention),
    /// as the paper's d_i does, so the scheduler cannot hide stalls.
    pub last_token_time: f64,
}

/// The full scheduler-visible state.
#[derive(Debug, Clone)]
pub struct SystemState {
    pub now: f64,
    pub prefill: Option<PrefillBatch>,
    pub decode: Vec<DecodeReqState>,
    /// w_k: requests waiting for prefill (scheduler may reorder).
    pub waiting: Vec<PrefillReq>,
    /// R_k: current SM allocation.
    pub partition: Partition,
    /// Model depth (layers to run per prefill).
    pub total_layers: usize,
}

impl SystemState {
    pub fn decode_batch_size(&self) -> usize {
        self.decode.len()
    }

    /// Mean context length of the decode batch (1 if empty, to keep
    /// estimator calls well-defined).
    pub fn decode_avg_ctx(&self) -> usize {
        if self.decode.is_empty() {
            return 1;
        }
        (self.decode.iter().map(|d| d.ctx_len).sum::<usize>() / self.decode.len()).max(1)
    }

    pub fn prefill_active(&self) -> bool {
        self.prefill.is_some()
    }

    pub fn phases_colocated(&self) -> bool {
        self.prefill.is_some() && !self.decode.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    #[test]
    fn batch_token_sum() {
        let b = PrefillBatch::new(
            vec![
                PrefillReq { id: 1, arrival: 0.0, input_len: 100, output_len: 10, cached_len: 0 },
                PrefillReq { id: 2, arrival: 0.1, input_len: 50, output_len: 10, cached_len: 0 },
            ],
            0.2,
        );
        assert_eq!(b.n_tokens, 150);
        assert_eq!(b.ctx_cached, 0);
        assert_eq!(b.layers_done, 0);
    }

    #[test]
    fn batch_charges_only_the_uncached_suffix() {
        let b = PrefillBatch::new(
            vec![
                PrefillReq { id: 1, arrival: 0.0, input_len: 100, output_len: 10, cached_len: 64 },
                PrefillReq { id: 2, arrival: 0.1, input_len: 50, output_len: 10, cached_len: 16 },
            ],
            0.2,
        );
        assert_eq!(b.n_tokens, 36 + 34);
        assert_eq!(b.ctx_cached, 64);
    }

    #[test]
    fn observed_tpot() {
        let mut d = DecodeReqState {
            id: 1,
            input_len: 10,
            ctx_len: 12,
            tokens_out: 1,
            output_len: 5,
            decode_elapsed: 0.0,
        };
        assert_eq!(d.observed_tpot(), 0.0);
        d.tokens_out = 3;
        d.decode_elapsed = 0.4;
        assert!((d.observed_tpot() - 0.2).abs() < 1e-12);
        assert!(!d.finished());
        d.tokens_out = 5;
        assert!(d.finished());
    }

    #[test]
    fn avg_ctx_handles_empty() {
        let st = SystemState {
            now: 0.0,
            prefill: None,
            decode: vec![],
            waiting: vec![],
            partition: crate::resource::Partition::split(&GpuSpec::a100(), 54),
            total_layers: 32,
        };
        assert_eq!(st.decode_avg_ctx(), 1);
        assert!(!st.phases_colocated());
    }
}
