//! SLO-aware task scheduling (§3.3): system-state tracking and the
//! Algorithm-1 policy that picks SM partitions each scheduling cycle.

pub mod policy;
pub mod state;

pub use policy::{deadline_should_drop, service_capacity_tokens_per_s, Decision, SloScheduler};
pub use state::{
    ActiveDecode, DecodeReqState, PrefillBatch, PrefillProgress, PrefillReq, SystemState,
};
