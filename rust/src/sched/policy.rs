//! Algorithm 1: SLO-aware scheduling.
//!
//! Each cycle the scheduler (a) estimates every tracked request's TTFT
//! and TPOT under the current partition, (b) reorders the waiting queue
//! by SLO slack, and (c) searches the partition space:
//!
//! - both P90s within budget   → `ReduceDecodeSM` (prioritize prefill —
//!   finishing prefill sooner grows the decode batch and throughput);
//! - both violated             → `SetBalancedSM` (minimize the worst
//!   violation ratio);
//! - only TPOT violated        → `ReducePrefillSM`;
//! - only TTFT violated        → `ReduceDecodeSM`, escalating to a
//!   temporary decode *pause* when even the minimum decode allocation
//!   cannot rescue TTFT while TPOT has slack (§3.3.3).
//!
//! Observability: the partition moves decided here are what the
//! SM-second ledger ([`crate::obs::SmLedger`]) prices — each
//! repartition's transition idle is charged to the `repartition`
//! category, and with tracing on the engine stamps a
//! `Repartition` instant per accepted move, so a Perfetto timeline of
//! the partition trace lines up against the attribution table.

use crate::config::ServingConfig;
use crate::perf::{PerfModel, PerfPredictor};
use crate::resource::Partition;
use crate::sched::state::SystemState;
use crate::util::stats;
use std::cell::RefCell;

/// Scheduler output for one cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub partition: Partition,
    /// Skip the next decode iteration entirely (borrow all SMs for prefill).
    pub pause_decode: bool,
}

/// Whole-GPU serving capacity in tokens/s under `perf`, for a workload
/// whose token mix is `prefill_frac` prefill (the rest decode): the
/// harmonic combination of the two phases' solo service rates at
/// reference shapes.  This is the unit the cluster autoscaler prices its
/// arrival-rate SLO envelope in — derived from the same [`PerfPredictor`]
/// Algorithm 1 schedules with, so a calibrated predictor yields a
/// calibrated envelope.  Deliberately optimistic (solo, full-GPU,
/// wave-aligned reference shapes): the autoscaler's utilization
/// thresholds, not this number, carry the latency headroom.
pub fn service_capacity_tokens_per_s<P: PerfPredictor>(
    perf: &P,
    cfg: &ServingConfig,
    prefill_frac: f64,
) -> f64 {
    let sms = cfg.gpu.num_sms;
    let layers = cfg.model.n_layers.max(1) as f64;
    let sl = 2048usize;
    let rate_p = sl as f64 / (perf.predict_prefill_layer(sl, 0, sms, false) * layers).max(1e-12);
    let bs = cfg.max_decode_batch.clamp(1, 64);
    let rate_d = bs as f64 / perf.predict_decode_step(bs, 2048, sms, false).max(1e-12);
    let f = if prefill_frac.is_finite() { prefill_frac.clamp(0.0, 1.0) } else { 0.5 };
    1.0 / (f / rate_p.max(1e-9) + (1.0 - f) / rate_d.max(1e-9))
}

/// Deadline-aware drop-or-serve decision: `true` when the request cannot
/// produce its first token before `deadline` and should be dropped as
/// `Expired` instead of consuming GPU work it can never convert into a
/// within-deadline answer.  `est_first_token_s` is the scheduler's
/// estimate of remaining time to first token (0 for a request already
/// decoding, where any elapsed deadline expires it immediately).
pub fn deadline_should_drop(now: f64, deadline: Option<f64>, est_first_token_s: f64) -> bool {
    match deadline {
        Some(d) => now + est_first_token_s.max(0.0) >= d,
        None => false,
    }
}

/// The SLO-aware scheduler.  Generic over the prediction source: the
/// frozen offline [`PerfModel`] (the default, and the pre-calibration
/// behavior) or any other [`PerfPredictor`] such as the feedback-driven
/// [`crate::perf::OnlineCalibrator`] — Algorithm 1 consults the trait,
/// never the concrete model.
pub struct SloScheduler<P: PerfPredictor = PerfModel> {
    pub cfg: ServingConfig,
    pub perf: P,
    /// Per-cycle hoisted TTFT terms + percentile scratch (memo on).
    /// `RefCell` keeps `schedule(&self)` — the scratch is interior
    /// state, never observable output; `Send` (not `Sync`) matches the
    /// one-policy-per-worker-thread cluster model.
    cycle: RefCell<TtftCycle>,
}

/// Request terms that are invariant across the candidate partitions of
/// one `schedule()` call, hoisted so each candidate evaluation replays
/// only the partition-dependent arithmetic (one `predict_prefill_layer`
/// plus an O(n) fold) instead of re-walking request structs and
/// re-deriving SLO budgets — and reads its percentile by in-place
/// selection instead of clone + sort.
#[derive(Debug, Default)]
struct TtftCycle {
    /// Hoisting happened for the current `schedule()` call (false when
    /// memo is off — evaluations then take the reference path).
    prepared: bool,
    /// Active-batch requests: (wait = now - arrival, clamped budget).
    batch: Vec<(f64, f64)>,
    /// Waiting queue, post-reorder: (wait, clamped budget, suffix tokens).
    waiting: Vec<(f64, f64, f64)>,
    /// Uncached suffix of the queue head (reference rate when no batch).
    head_r: usize,
    /// `st.total_layers as f64`.
    layers_f: f64,
    /// Percentile scratch for TTFT ratios.
    ratios: Vec<f64>,
    /// Percentile scratch for observed TPOTs.
    obs: Vec<f64>,
}

impl<P: PerfPredictor> SloScheduler<P> {
    pub fn new(cfg: ServingConfig, perf: P) -> SloScheduler<P> {
        SloScheduler { cfg, perf, cycle: RefCell::new(TtftCycle::default()) }
    }

    /// This scheduler's whole-GPU serving capacity in tokens/s for a
    /// `prefill_frac` token mix (see [`service_capacity_tokens_per_s`]).
    pub fn capacity_tokens_per_s(&self, prefill_frac: f64) -> f64 {
        service_capacity_tokens_per_s(&self.perf, &self.cfg, prefill_frac)
    }

    /// Predicted remaining prefill time for the active batch under `pm` SMs.
    fn rem_prefill_time(&self, st: &SystemState, pm: usize, contended: bool) -> f64 {
        match &st.prefill {
            None => 0.0,
            Some(b) => {
                let layers_left = st.total_layers.saturating_sub(b.layers_done);
                self.perf
                    .predict_prefill_remaining(b.n_tokens, 0, pm, layers_left, contended)
            }
        }
    }

    /// P90 TTFT violation ratio (>1 ⇒ violated) under a candidate `pm`.
    /// Covers the active batch AND the waiting queue (whose requests must
    /// first wait for the active batch — the cascading-congestion term).
    ///
    /// One `predict_prefill_layer` per candidate; each waiting request's
    /// own prefill time is scaled from that single prediction (per-token
    /// rate) rather than re-predicted — the queue estimate is coarse by
    /// nature (§3.3.2's q_i), and this keeps the decision microseconds.
    ///
    /// This is the REFERENCE evaluation (memo off): it re-walks every
    /// request and re-derives every budget per candidate.  The hot path
    /// is [`Self::ttft_ratio_p90_hoisted`], which replays this exact
    /// arithmetic over per-cycle hoisted terms — any edit here must be
    /// mirrored there or the bit-parity tests fail.
    fn ttft_ratio_p90(&self, st: &SystemState, pm: usize, contended: bool) -> f64 {
        let (rem, per_token_layer) = match &st.prefill {
            None => (0.0, {
                // No active batch: derive the per-token rate from the
                // head of the waiting queue (its uncached suffix is what
                // will actually run next).  A fixed 2048-token reference
                // mis-prices short-prompt workloads — attention cost is
                // quadratic in sl while wave-quantization penalties fall
                // with it, so no single reference size fits both ends.
                let r = st
                    .waiting
                    .first()
                    .map(|w| (w.input_len - w.cached_len).max(1))
                    .unwrap_or(2048);
                self.perf.predict_prefill_layer(r, 0, pm, contended) / r as f64
            }),
            Some(b) => {
                let layer = self.perf.predict_prefill_layer(b.n_tokens, 0, pm, contended);
                let layers_left = st.total_layers.saturating_sub(b.layers_done);
                (layer * layers_left as f64, layer / b.n_tokens.max(1) as f64)
            }
        };
        let mut ratios: Vec<f64> = Vec::with_capacity(
            st.prefill.as_ref().map(|b| b.reqs.len()).unwrap_or(0) + st.waiting.len(),
        );
        if let Some(b) = &st.prefill {
            for r in &b.reqs {
                let ttft = (st.now - r.arrival) + rem;
                ratios.push(ttft / self.cfg.slo.ttft_budget(r.input_len).max(1e-9));
            }
        }
        // Waiting requests queue behind the active batch, then run their
        // own prefill (scaled per-token estimate at this partition).
        // Prefix-cached tokens are already resident, so only the suffix
        // costs compute — the SLO budget still covers the full prompt.
        let mut queue_ahead = rem;
        for r in &st.waiting {
            let suffix = (r.input_len - r.cached_len).max(1);
            let own = per_token_layer * suffix as f64 * st.total_layers as f64;
            let ttft = (st.now - r.arrival) + queue_ahead + own;
            ratios.push(ttft / self.cfg.slo.ttft_budget(r.input_len).max(1e-9));
            queue_ahead += own;
        }
        if ratios.is_empty() {
            0.0
        } else {
            stats::percentile(&ratios, self.cfg.slo_percentile)
        }
    }

    /// Hoist this cycle's partition-invariant TTFT terms (no-op with
    /// memo off).  Must run after `reorder_waiting` — the queue order is
    /// part of the cascading-congestion accumulation.
    fn prepare_cycle(&self, st: &SystemState) {
        let mut cy = self.cycle.borrow_mut();
        let cy = &mut *cy;
        cy.prepared = self.cfg.memo;
        if !cy.prepared {
            return;
        }
        cy.batch.clear();
        if let Some(b) = &st.prefill {
            cy.batch.extend(
                b.reqs
                    .iter()
                    .map(|r| (st.now - r.arrival, self.cfg.slo.ttft_budget(r.input_len).max(1e-9))),
            );
        }
        cy.waiting.clear();
        cy.waiting.extend(st.waiting.iter().map(|r| {
            (
                st.now - r.arrival,
                self.cfg.slo.ttft_budget(r.input_len).max(1e-9),
                (r.input_len - r.cached_len).max(1) as f64,
            )
        }));
        cy.head_r = st.waiting.first().map(|w| (w.input_len - w.cached_len).max(1)).unwrap_or(2048);
        cy.layers_f = st.total_layers as f64;
    }

    /// Candidate TTFT evaluation over the hoisted terms: replays the
    /// exact arithmetic of [`Self::ttft_ratio_p90`] (same operations in
    /// the same order, so the result is bit-identical) but touches no
    /// request structs, performs no allocation, and takes the percentile
    /// by in-place selection.
    fn ttft_ratio_p90_hoisted(&self, st: &SystemState, pm: usize, contended: bool) -> f64 {
        let mut cy = self.cycle.borrow_mut();
        let cy = &mut *cy;
        let (rem, per_token_layer) = match &st.prefill {
            None => {
                let r = cy.head_r;
                (0.0, self.perf.predict_prefill_layer(r, 0, pm, contended) / r as f64)
            }
            Some(b) => {
                let layer = self.perf.predict_prefill_layer(b.n_tokens, 0, pm, contended);
                let layers_left = st.total_layers.saturating_sub(b.layers_done);
                (layer * layers_left as f64, layer / b.n_tokens.max(1) as f64)
            }
        };
        cy.ratios.clear();
        for &(wait, bud) in &cy.batch {
            cy.ratios.push((wait + rem) / bud);
        }
        let mut queue_ahead = rem;
        for &(wait, bud, suffix) in &cy.waiting {
            let own = per_token_layer * suffix * cy.layers_f;
            cy.ratios.push((wait + queue_ahead + own) / bud);
            queue_ahead += own;
        }
        if cy.ratios.is_empty() {
            0.0
        } else {
            stats::percentile_select(&mut cy.ratios, self.cfg.slo_percentile)
        }
    }

    /// Per-candidate TTFT ratio: the hoisted fast path when this cycle
    /// was prepared (memo on), the reference walk otherwise.
    fn ttft_ratio_p90_cycle(&self, st: &SystemState, pm: usize, contended: bool) -> f64 {
        if self.cycle.borrow().prepared {
            self.ttft_ratio_p90_hoisted(st, pm, contended)
        } else {
            self.ttft_ratio_p90(st, pm, contended)
        }
    }

    /// P90 of observed per-request TPOT (partition-independent; computed
    /// once per scheduling cycle).  Memo on reuses the percentile
    /// scratch and selects in place; memo off is the reference
    /// clone-and-sort.  Both are bit-identical.
    fn observed_tpot_p90(&self, st: &SystemState) -> f64 {
        if st.decode.is_empty() {
            return 0.0;
        }
        if self.cfg.memo {
            let mut cy = self.cycle.borrow_mut();
            let cy = &mut *cy;
            cy.obs.clear();
            cy.obs.extend(st.decode.iter().map(|d| d.observed_tpot()));
            stats::percentile_select(&mut cy.obs, self.cfg.slo_percentile)
        } else {
            let obs: Vec<f64> = st.decode.iter().map(|d| d.observed_tpot()).collect();
            stats::percentile(&obs, self.cfg.slo_percentile)
        }
    }

    /// P90 TPOT violation ratio under a candidate `dm`.  Blends the
    /// observed per-request TPOT (the past is already spent) with the
    /// predicted next-iteration time (what the partition controls).
    /// The projection is affine in the (constant) next-iteration time, so
    /// P90(projected) == 0.5*P90(observed) + 0.5*next — no per-candidate
    /// vector or sort.
    fn tpot_ratio_p90_with(&self, st: &SystemState, dm: usize, contended: bool, obs_p90: f64) -> f64 {
        if st.decode.is_empty() {
            return 0.0;
        }
        let bs = st.decode_batch_size();
        let cl = st.decode_avg_ctx();
        let next_iter = self.perf.predict_decode_step(bs, cl, dm, contended);
        let budget = self.cfg.slo.tpot_budget().max(1e-9);
        let projected = if obs_p90 > 0.0 {
            0.5 * obs_p90 + 0.5 * next_iter
        } else {
            next_iter
        };
        projected / budget
    }

    /// SLO slack of a waiting request at virtual time `now` (negative ⇒
    /// already past its TTFT budget).
    pub fn ttft_slack(&self, r: &crate::sched::state::PrefillReq, now: f64) -> f64 {
        self.cfg.slo.ttft_budget(r.input_len) - (now - r.arrival)
    }

    /// Reorder the waiting queue by SLO slack (most urgent first) —
    /// Algorithm 1 line 7.  `total_cmp` keeps the sort total even if a
    /// degenerate SLO budget produces NaN slack, so the scheduler can
    /// never panic here.
    pub fn reorder_waiting(&self, st: &mut SystemState) {
        let now = st.now;
        st.waiting.sort_by(|a, b| {
            self.ttft_slack(a, now).total_cmp(&self.ttft_slack(b, now))
        });
    }

    /// Candidate SM counts, descending from `from`, at mask granularity.
    /// Lazy iterator (captures only three integers) — no `Vec` per scan.
    /// Coarse `3 × granularity` steps keep the search O(#SMs/6), §3.3.3.
    fn steps_down(&self, from: usize, to_min: usize) -> impl Iterator<Item = usize> {
        let g = self.cfg.gpu.sm_granularity.max(1);
        let lo = self.cfg.gpu.quantize_sms(to_min);
        let mut x = self.cfg.gpu.quantize_sms(from);
        let mut done = x < lo;
        std::iter::from_fn(move || {
            if done {
                return None;
            }
            let cur = x;
            if x < g + lo {
                done = true;
            } else {
                x -= g * 3;
                if x < lo {
                    done = true;
                }
            }
            Some(cur)
        })
    }

    /// The main decision procedure (Algorithm 1).
    pub fn schedule(&self, st: &mut SystemState) -> Decision {
        let gpu_sms = self.cfg.gpu.num_sms;
        self.reorder_waiting(st);

        // Degenerate phases: hand the whole GPU to whoever is active.
        if !st.prefill_active() && st.waiting.is_empty() {
            return Decision {
                partition: Partition { prefill_sms: 0, decode_sms: gpu_sms },
                pause_decode: false,
            };
        }
        if st.decode.is_empty() {
            return Decision {
                partition: Partition { prefill_sms: gpu_sms, decode_sms: 0 },
                pause_decode: false,
            };
        }

        // Hoist the partition-invariant per-request terms once; every
        // candidate evaluation below is then O(n) folds over plain f64s
        // (memo off: evaluations re-walk the structs — the reference).
        self.prepare_cycle(st);

        let contended = true; // both phases active below this point
        let cur = st.partition;
        let cur_pm = cur.prefill_sms.max(self.cfg.min_prefill_sms);
        let cur_dm = cur.decode_sms.max(self.cfg.min_decode_sms);
        let obs_p90 = self.observed_tpot_p90(st);
        let ttft_viol = self.ttft_ratio_p90_cycle(st, cur_pm, contended) > 1.0;
        let tpot_viol = self.tpot_ratio_p90_with(st, cur_dm, contended, obs_p90) > 1.0;

        match (ttft_viol, tpot_viol) {
            (false, false) | (true, false) => self.reduce_decode_sm(st, obs_p90),
            (true, true) => self.set_balanced_sm(st, obs_p90),
            (false, true) => self.reduce_prefill_sm(st, obs_p90),
        }
    }

    /// Shrink decode's share to accelerate prefill, keeping TPOT legal;
    /// escalate to a decode pause if the minimum share still cannot save
    /// TTFT while TPOT has headroom.
    fn reduce_decode_sm(&self, st: &SystemState, obs_p90: f64) -> Decision {
        let gpu_sms = self.cfg.gpu.num_sms;
        // Prefill-first: find the SMALLEST decode share that keeps TPOT
        // legal — every SM freed accelerates prefill and, transitively,
        // throughput (the paper's primary objective when slack exists).
        let mut best: Option<(usize, usize)> = None;
        for dm in self.steps_down(gpu_sms - self.cfg.min_prefill_sms, self.cfg.min_decode_sms) {
            let pm = gpu_sms - dm;
            if pm < self.cfg.min_prefill_sms {
                continue;
            }
            if self.tpot_ratio_p90_with(st, dm, true, obs_p90) <= 1.0 {
                best = Some((pm, dm));
            } else if best.is_some() {
                break; // past the legal region; smaller dm only worsens TPOT
            }
        }
        if let Some((pm, dm)) = best {
            // TPOT fine at the floor but TTFT still violated → borrow all
            // SMs: pause decode for one cycle (§3.3.3, Fig. 8a-②).
            let still_violated = self.ttft_ratio_p90_cycle(st, pm, true) > 1.0;
            let tpot_headroom = self.tpot_ratio_p90_with(st, dm, true, obs_p90) <= 0.8;
            if still_violated && tpot_headroom {
                return Decision {
                    partition: Partition { prefill_sms: gpu_sms, decode_sms: dm },
                    pause_decode: true,
                };
            }
            return Decision {
                partition: Partition { prefill_sms: pm, decode_sms: dm },
                pause_decode: false,
            };
        }
        // Even the largest decode share violates TPOT — fall back to balance.
        self.set_balanced_sm(st, obs_p90)
    }

    /// Grow decode's share until TPOT is legal (or prefill hits its floor).
    fn reduce_prefill_sm(&self, st: &SystemState, obs_p90: f64) -> Decision {
        let gpu_sms = self.cfg.gpu.num_sms;
        for pm in self.steps_down(st.partition.prefill_sms.max(self.cfg.min_prefill_sms), self.cfg.min_prefill_sms) {
            let dm = gpu_sms - pm;
            if self.tpot_ratio_p90_with(st, dm, true, obs_p90) <= 1.0 {
                return Decision {
                    partition: Partition { prefill_sms: pm, decode_sms: dm },
                    pause_decode: false,
                };
            }
        }
        // TPOT unsatisfiable: give decode everything above prefill's floor.
        let pm = self.cfg.gpu.quantize_sms(self.cfg.min_prefill_sms);
        Decision {
            partition: Partition { prefill_sms: pm, decode_sms: gpu_sms - pm },
            pause_decode: false,
        }
    }

    /// Both phases violated: pick the split minimizing the worst ratio.
    fn set_balanced_sm(&self, st: &SystemState, obs_p90: f64) -> Decision {
        let gpu_sms = self.cfg.gpu.num_sms;
        let mut best = Partition::split(&self.cfg.gpu, gpu_sms / 2);
        let mut best_score = f64::INFINITY;
        let g = self.cfg.gpu.sm_granularity * 3;
        let mut pm = self.cfg.gpu.quantize_sms(self.cfg.min_prefill_sms);
        while pm + self.cfg.min_decode_sms <= gpu_sms {
            let dm = gpu_sms - pm;
            let score = self
                .ttft_ratio_p90_cycle(st, pm, true)
                .max(self.tpot_ratio_p90_with(st, dm, true, obs_p90));
            if score < best_score {
                best_score = score;
                best = Partition { prefill_sms: pm, decode_sms: dm };
            }
            pm += g;
        }
        Decision {
            partition: best,
            pause_decode: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec, ServingConfig};
    use crate::sched::state::{DecodeReqState, PrefillBatch, PrefillReq, SystemState};

    fn scheduler() -> SloScheduler {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        SloScheduler::new(cfg, perf)
    }

    fn state_with(
        prefill_tokens: usize,
        layers_done: usize,
        decode: Vec<DecodeReqState>,
        waiting: Vec<PrefillReq>,
        now: f64,
    ) -> SystemState {
        let prefill = if prefill_tokens > 0 {
            Some(PrefillBatch {
                reqs: vec![PrefillReq {
                    id: 1,
                    arrival: 0.0,
                    input_len: prefill_tokens,
                    output_len: 64,
                    ..Default::default()
                }],
                n_tokens: prefill_tokens,
                layers_done,
                started_at: 0.0,
                ..Default::default()
            })
        } else {
            None
        };
        SystemState {
            now,
            prefill,
            decode,
            waiting,
            partition: Partition::split(&GpuSpec::a100(), 54),
            total_layers: 32,
        }
    }

    fn decode_req(id: u64, ctx: usize, tpot: f64) -> DecodeReqState {
        DecodeReqState {
            id,
            input_len: ctx,
            ctx_len: ctx,
            tokens_out: 10,
            output_len: 100,
            decode_elapsed: tpot * 9.0,
        }
    }

    #[test]
    fn idle_prefill_gives_decode_everything() {
        let s = scheduler();
        let mut st = state_with(0, 0, vec![decode_req(1, 500, 0.02)], vec![], 1.0);
        let d = s.schedule(&mut st);
        assert_eq!(d.partition.decode_sms, 108);
        assert!(!d.pause_decode);
    }

    #[test]
    fn idle_decode_gives_prefill_everything() {
        let s = scheduler();
        let mut st = state_with(2048, 4, vec![], vec![], 0.1);
        let d = s.schedule(&mut st);
        assert_eq!(d.partition.prefill_sms, 108);
    }

    #[test]
    fn healthy_state_prioritizes_prefill() {
        // Both metrics easily within budget → ReduceDecodeSM: prefill
        // gets at least its current share, decode shrinks toward minimum.
        let s = scheduler();
        let mut st = state_with(1024, 16, vec![decode_req(1, 200, 0.02)], vec![], 0.05);
        let d = s.schedule(&mut st);
        assert!(d.partition.prefill_sms >= 54, "{:?}", d.partition);
        assert!(d.partition.decode_sms >= s.cfg.min_decode_sms);
    }

    #[test]
    fn ttft_pressure_shrinks_decode() {
        // A huge prefill that is already late, decode healthy.
        let s = scheduler();
        let mut st = state_with(16384, 0, vec![decode_req(1, 200, 0.02)], vec![], 30.0);
        let d = s.schedule(&mut st);
        // Either decode is squeezed hard, or (if hopeless) paused.
        assert!(
            d.partition.prefill_sms > 54 || d.pause_decode,
            "decision {d:?}"
        );
    }

    #[test]
    fn tpot_pressure_grows_decode() {
        // Decode with long contexts and observed TPOT over budget; prefill early.
        let s = scheduler();
        let decode: Vec<DecodeReqState> =
            (0..64).map(|i| decode_req(i, 8000, 0.3)).collect();
        let mut st = state_with(1024, 30, decode, vec![], 0.01);
        st.partition = Partition::split(&GpuSpec::a100(), 84); // decode squeezed
        let d = s.schedule(&mut st);
        assert!(
            d.partition.decode_sms > 24,
            "decode should gain SMs: {:?}",
            d.partition
        );
        assert!(!d.pause_decode);
    }

    #[test]
    fn empty_batch_rate_derived_from_queue_head() {
        // With no active batch, the TTFT estimate prices the queue at
        // the HEAD request's own per-token rate, not a fixed 2048-token
        // reference.
        let s = scheduler();
        let st = state_with(
            0,
            0,
            vec![decode_req(1, 500, 0.02)],
            vec![PrefillReq {
                id: 9,
                arrival: 0.0,
                input_len: 64,
                output_len: 8,
                ..Default::default()
            }],
            0.1,
        );
        let got = s.ttft_ratio_p90(&st, 54, true);
        let per_token = s.perf.predict_prefill_layer(64, 0, 54, true) / 64.0;
        let own = per_token * 64.0 * 32.0;
        let expect = (0.1 + own) / s.cfg.slo.ttft_budget(64);
        assert!(
            (got - expect).abs() / expect < 1e-9,
            "got {got} expect {expect}"
        );
        // and the head-derived rate genuinely differs from the old
        // reference rate, so the fix is observable
        let ref_rate = s.perf.predict_prefill_layer(2048, 0, 54, true) / 2048.0;
        assert!(
            (per_token - ref_rate).abs() / ref_rate > 1e-3,
            "head rate {per_token} vs reference {ref_rate}"
        );
    }

    #[test]
    fn reorder_puts_tightest_slack_first() {
        let s = scheduler();
        let mut st = state_with(0, 0, vec![], vec![
            PrefillReq { id: 1, arrival: 0.0, input_len: 4000, output_len: 1, ..Default::default() }, // big budget
            PrefillReq { id: 2, arrival: 0.0, input_len: 100, output_len: 1, ..Default::default() },  // tiny budget
        ], 0.2);
        s.reorder_waiting(&mut st);
        assert_eq!(st.waiting[0].id, 2);
    }

    #[test]
    fn reorder_survives_nan_budget() {
        // A degenerate SLO (NaN budget) must not panic the scheduler:
        // total_cmp gives NaN a fixed sort position.
        let mut cfg = ServingConfig::default();
        cfg.slo.norm_ttft_ms_per_token = f64::NAN;
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let s = SloScheduler::new(cfg, perf);
        let mut st = state_with(0, 0, vec![], vec![
            PrefillReq { id: 1, arrival: 0.0, input_len: 4000, output_len: 1, ..Default::default() },
            PrefillReq { id: 2, arrival: 0.1, input_len: 100, output_len: 1, ..Default::default() },
            PrefillReq { id: 3, arrival: 0.2, input_len: 900, output_len: 1, ..Default::default() },
        ], 0.5);
        s.reorder_waiting(&mut st); // must not panic
        assert_eq!(st.waiting.len(), 3);
    }

    #[test]
    fn pause_only_when_tpot_has_headroom() {
        let s = scheduler();
        // Late prefill + decode already at its TPOT limit → no pause.
        let decode: Vec<DecodeReqState> =
            (0..128).map(|i| decode_req(i, 6000, 0.145)).collect();
        let mut st = state_with(16384, 0, decode, vec![], 40.0);
        let d = s.schedule(&mut st);
        if d.pause_decode {
            panic!("must not pause decode when TPOT is near its budget: {d:?}");
        }
    }

    #[test]
    fn calibrated_predictor_shifts_partition_toward_decode() {
        // Same state, two predictors: the frozen model, and a calibrator
        // that has learned decode runs 3x slower than modeled.  The
        // scheduler (generic over the trait) must give calibrated decode
        // strictly more SMs.
        use crate::config::CalibrationConfig;
        use crate::perf::{OnlineCalibrator, PerfPredictor};
        let cfg = ServingConfig::default();
        let inner = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let frozen = SloScheduler::new(cfg.clone(), inner.clone());
        let mut cal = OnlineCalibrator::new(inner.clone(), CalibrationConfig::on());
        for dm in (12..=108).step_by(6) {
            let base = PerfModel::predict_decode_step(&inner, 96, 6000, dm, true);
            for _ in 0..6 {
                cal.observe_decode(96, 6000, dm, true, base * 3.0);
            }
        }
        // sanity: the learned cells inflate decode predictions
        let p_cal = PerfPredictor::predict_decode_step(&cal, 96, 6000, 54, true);
        let p_frozen = PerfModel::predict_decode_step(&inner, 96, 6000, 54, true);
        assert!(p_cal > 2.0 * p_frozen, "cal {p_cal} frozen {p_frozen}");
        let calibrated = SloScheduler::new(cfg, cal);

        let decode: Vec<DecodeReqState> = (0..96).map(|i| decode_req(i, 6000, 0.10)).collect();
        let mk = || state_with(4096, 0, decode.clone(), vec![], 0.05);
        let d_frozen = frozen.schedule(&mut mk());
        let d_cal = calibrated.schedule(&mut mk());
        assert!(
            d_cal.partition.decode_sms > d_frozen.partition.decode_sms,
            "calibrated {:?} vs frozen {:?}",
            d_cal.partition,
            d_frozen.partition
        );
    }

    #[test]
    fn service_capacity_sane_and_mix_sensitive() {
        let s = scheduler();
        let all_prefill = s.capacity_tokens_per_s(1.0);
        let all_decode = s.capacity_tokens_per_s(0.0);
        let mixed = s.capacity_tokens_per_s(0.7);
        for c in [all_prefill, all_decode, mixed] {
            assert!(c.is_finite() && c > 0.0, "capacity {c}");
        }
        // A100 + Llama-8B magnitudes: prefill O(10k) tok/s, decode
        // (weight-read-bound) slower — the mix lands between them.
        assert!(all_prefill > all_decode, "{all_prefill} vs {all_decode}");
        assert!(mixed < all_prefill && mixed > all_decode, "mixed {mixed}");
        assert!(all_prefill > 5_000.0 && all_prefill < 100_000.0, "{all_prefill}");
        // a predictor that learned a 2x slowdown halves the envelope
        use crate::config::CalibrationConfig;
        use crate::perf::OnlineCalibrator;
        let inner = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let mut cal = OnlineCalibrator::new(inner.clone(), CalibrationConfig::on());
        let bp = PerfModel::predict_prefill_layer(&inner, 2048, 0, 108, false);
        let bd = PerfModel::predict_decode_step(&inner, 64, 2048, 108, false);
        for _ in 0..40 {
            cal.observe_prefill(2048, 0, 108, false, 1, bp * 2.0);
            cal.observe_decode(64, 2048, 108, false, bd * 2.0);
        }
        let cfg = ServingConfig::default();
        let slow = service_capacity_tokens_per_s(&cal, &cfg, 0.7);
        let fast = service_capacity_tokens_per_s(&inner, &cfg, 0.7);
        assert!(
            slow < 0.7 * fast,
            "calibrated capacity {slow} must fall well below nominal {fast}"
        );
        // degenerate mixes are clamped, not propagated
        assert!(s.capacity_tokens_per_s(f64::NAN).is_finite());
    }

    #[test]
    fn deadline_drop_decision() {
        assert!(!deadline_should_drop(5.0, None, 100.0));
        assert!(!deadline_should_drop(5.0, Some(6.0), 0.5));
        assert!(deadline_should_drop(5.0, Some(6.0), 1.0));
        assert!(deadline_should_drop(7.0, Some(6.0), 0.0));
        // negative estimates are clamped, not allowed to rescue a late request
        assert!(deadline_should_drop(7.0, Some(6.0), -3.0));
    }

    #[test]
    fn hoisted_ttft_is_bit_identical_to_reference() {
        // Across candidate partitions, batch/no-batch states, cached
        // prefixes and a deep waiting queue, the hoisted evaluation must
        // reproduce the reference walk bit for bit.
        let s = scheduler();
        assert!(s.cfg.memo);
        let waiting: Vec<PrefillReq> = (0..64)
            .map(|i| PrefillReq {
                id: 100 + i,
                arrival: i as f64 * 0.013,
                input_len: 256 + (i as usize * 731) % 6000,
                output_len: 64,
                cached_len: if i % 3 == 0 { 128 } else { 0 },
                ..Default::default()
            })
            .collect();
        for prefill_tokens in [0usize, 4096] {
            let mut st = state_with(
                prefill_tokens,
                7,
                vec![decode_req(1, 900, 0.03)],
                waiting.clone(),
                2.0,
            );
            s.reorder_waiting(&mut st);
            s.prepare_cycle(&st);
            for pm in [24usize, 54, 84, 108] {
                let reference = s.ttft_ratio_p90(&st, pm, true);
                let hoisted = s.ttft_ratio_p90_hoisted(&st, pm, true);
                assert_eq!(
                    hoisted.to_bits(),
                    reference.to_bits(),
                    "pm={pm} prefill={prefill_tokens}: hoisted {hoisted} vs ref {reference}"
                );
            }
        }
    }

    #[test]
    fn schedule_is_bit_identical_memo_on_vs_off() {
        let on = scheduler();
        let off = SloScheduler::new(
            ServingConfig { memo: false, ..ServingConfig::default() },
            PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b()),
        );
        let waiting: Vec<PrefillReq> = (0..48)
            .map(|i| PrefillReq {
                id: 500 + i,
                arrival: i as f64 * 0.01,
                input_len: 512 + (i as usize * 977) % 8192,
                output_len: 128,
                ..Default::default()
            })
            .collect();
        // healthy, TTFT-violated, TPOT-violated, and both-violated states
        let states: Vec<SystemState> = vec![
            state_with(1024, 16, vec![decode_req(1, 200, 0.02)], waiting.clone(), 0.05),
            state_with(16384, 0, vec![decode_req(1, 200, 0.02)], waiting.clone(), 30.0),
            state_with(1024, 30, (0..64).map(|i| decode_req(i, 8000, 0.3)).collect(), vec![], 0.01),
            state_with(16384, 0, (0..128).map(|i| decode_req(i, 6000, 0.2)).collect(), waiting, 40.0),
        ];
        for (k, st) in states.into_iter().enumerate() {
            let da = on.schedule(&mut st.clone());
            let db = off.schedule(&mut st.clone());
            assert_eq!(da, db, "state {k}: memo-on {da:?} vs memo-off {db:?}");
            // the partition-independent observed-TPOT percentile too
            assert_eq!(
                on.observed_tpot_p90(&st).to_bits(),
                off.observed_tpot_p90(&st).to_bits(),
                "state {k}: observed TPOT p90 diverged"
            );
        }
    }

    #[test]
    fn steps_down_iterator_matches_legacy_sequence() {
        let s = scheduler();
        // legacy semantics: descend by 3*granularity, stop once within
        // one granule of the floor, never emit below the floor
        for (from, to_min) in [(96usize, 12usize), (108, 24), (13, 12), (12, 12), (10, 12)] {
            let got: Vec<usize> = s.steps_down(from, to_min).collect();
            let g = s.cfg.gpu.sm_granularity.max(1);
            let mut want = Vec::new();
            let mut x = s.cfg.gpu.quantize_sms(from);
            let lo = s.cfg.gpu.quantize_sms(to_min);
            while x >= lo {
                want.push(x);
                if x < g + lo {
                    break;
                }
                x -= g * 3;
            }
            assert_eq!(got, want, "from={from} to_min={to_min}");
        }
    }

    #[test]
    fn partitions_respect_granularity() {
        let s = scheduler();
        let decode: Vec<DecodeReqState> = (0..32).map(|i| decode_req(i, 2000, 0.1)).collect();
        let mut st = state_with(8192, 8, decode, vec![], 5.0);
        let d = s.schedule(&mut st);
        assert_eq!(d.partition.prefill_sms % 2, 0);
        assert_eq!(d.partition.decode_sms % 2, 0);
    }
}
