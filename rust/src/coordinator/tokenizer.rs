//! Byte-level tokenizer stand-in.
//!
//! The paper plugs into SGLang's tokenizer; serving text through the tiny
//! PJRT model only needs *a* stable invertible mapping, so we use byte
//! tokens with a small reserved-id prefix (pad/bos/eos).  Ids stay below
//! the tiny model's vocab (2048).

/// Reserved ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// First byte token id.
pub const BYTE_BASE: i32 = 3;

/// Tokenizer with a fixed vocab cap (ids >= cap are folded).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Tokenizer {
        assert!(vocab_size > BYTE_BASE as usize + 256, "vocab too small for byte tokens");
        Tokenizer { vocab_size }
    }

    /// Encode text to ids (BOS-prefixed).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        for b in text.bytes() {
            out.push(BYTE_BASE + b as i32);
        }
        out
    }

    /// Decode ids back to text (reserved ids skipped; non-byte ids become
    /// U+FFFD — the tiny random-weight model emits arbitrary ids).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len());
        for &id in ids {
            if id < BYTE_BASE {
                continue;
            }
            let b = id - BYTE_BASE;
            if (0..256).contains(&b) {
                bytes.push(b as u8);
            } else {
                bytes.extend_from_slice("\u{FFFD}".as_bytes());
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new(2048);
        let ids = t.encode("hello bullet");
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode(&ids), "hello bullet");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new(2048);
        let s = "héllo — 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn reserved_ids_skipped_in_decode() {
        let t = Tokenizer::new(2048);
        assert_eq!(t.decode(&[BOS, PAD, EOS]), "");
    }

    #[test]
    fn out_of_byte_ids_become_replacement() {
        let t = Tokenizer::new(2048);
        let s = t.decode(&[BYTE_BASE + 300]);
        assert_eq!(s, "\u{FFFD}");
    }

    #[test]
    #[should_panic(expected = "vocab too small")]
    fn rejects_tiny_vocab() {
        Tokenizer::new(100);
    }
}
