//! The top-level coordinator: what a downstream user instantiates.
//!
//! [`BulletServer`] bundles configuration, the offline profiling pass
//! (§3.2.2) and the serving engines behind one facade:
//!
//! ```ignore
//! let server = BulletServer::build(ServingConfig::default(), BuildOptions::default());
//! let out = server.serve(&trace);
//! println!("{}", summarize(&out.records, &server.cfg().slo, None).throughput_tok_s);
//! ```

pub mod tokenizer;

use crate::baselines::System;
use crate::cluster::{serve_cluster, ClusterConfig, ClusterOutput};
use crate::config::ServingConfig;
use crate::engine::sim_engine::{serve_bullet, EngineOutput, SimEngineOptions};
use crate::gpu::roofline::GroundTruth;
use crate::perf::{profile, PerfModel, ProfileSpec};
use crate::workload::{Dataset, Request};

pub use tokenizer::Tokenizer;

/// Build-time options.
#[derive(Debug, Clone)]
pub struct BuildOptions {
    /// Profiling grid; `None` = analytical model only (no profiling).
    pub profile: Option<ProfileSpec>,
    /// Ground-truth noise sigma for the simulated GPU.
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Default for BuildOptions {
    fn default() -> Self {
        BuildOptions {
            profile: None,
            noise_sigma: 0.03,
            seed: 0xB17,
        }
    }
}

impl BuildOptions {
    /// Paper-fidelity profiling (the §3.2.2 offline pass).
    pub fn with_paper_profiling(cfg: &ServingConfig) -> BuildOptions {
        BuildOptions {
            profile: Some(ProfileSpec::paper(&cfg.gpu)),
            ..Default::default()
        }
    }

    /// Coarse profiling for quick runs and tests.
    pub fn with_coarse_profiling(cfg: &ServingConfig) -> BuildOptions {
        BuildOptions {
            profile: Some(ProfileSpec::coarse(&cfg.gpu)),
            ..Default::default()
        }
    }
}

/// The assembled serving system (simulation mode).
pub struct BulletServer {
    cfg: ServingConfig,
    perf: PerfModel,
    gt: GroundTruth,
    opts: SimEngineOptions,
}

impl BulletServer {
    /// Assemble the system: construct the simulated GPU, optionally run
    /// the offline profiling pass, and wire the scheduler.
    pub fn build(cfg: ServingConfig, build: BuildOptions) -> BulletServer {
        let mut gt = GroundTruth::new(cfg.gpu.clone());
        gt.noise_sigma = build.noise_sigma;
        let perf = match &build.profile {
            Some(spec) => profile(&gt, &cfg.model, spec),
            None => PerfModel::analytical(cfg.gpu.clone(), cfg.model.clone()),
        };
        BulletServer {
            cfg,
            perf,
            gt,
            opts: SimEngineOptions {
                seed: build.seed,
                ..Default::default()
            },
        }
    }

    pub fn cfg(&self) -> &ServingConfig {
        &self.cfg
    }

    pub fn perf(&self) -> &PerfModel {
        &self.perf
    }

    pub fn ground_truth(&self) -> &GroundTruth {
        &self.gt
    }

    /// Enable timeline recording on subsequent serves.
    pub fn record_timeline(&mut self, on: bool) {
        self.opts.record_timeline = on;
    }

    /// Serve a prepared trace.
    pub fn serve(&self, trace: &[Request]) -> EngineOutput {
        serve_bullet(&self.cfg, &self.perf, &self.gt, trace, &self.opts)
    }

    /// Convenience: generate a Poisson trace from a dataset and serve it.
    pub fn serve_dataset(&self, dataset: &Dataset, rate: f64, n: usize, seed: u64) -> EngineOutput {
        let trace = crate::workload::generate_n_requests(dataset, rate, n, seed);
        self.serve(&trace)
    }

    /// Serve a trace on `cluster.replicas` Bullet instances behind the
    /// configured router (the scale-out path).
    pub fn serve_cluster(&self, trace: &[Request], cluster: &ClusterConfig) -> ClusterOutput {
        self.serve_system_cluster(System::Bullet, trace, cluster)
    }

    /// Scale out any cataloged system — baselines included — across
    /// replicas.  Replica simulators derive their seeds from the
    /// server's build seed (like [`BulletServer::serve`]); call
    /// [`crate::cluster::serve_cluster`] directly for per-run seeds.
    pub fn serve_system_cluster(
        &self,
        system: System,
        trace: &[Request],
        cluster: &ClusterConfig,
    ) -> ClusterOutput {
        serve_cluster(
            system,
            &self.cfg,
            &self.perf,
            &self.gt,
            trace,
            self.opts.seed,
            cluster,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::summarize;

    #[test]
    fn build_and_serve_analytical() {
        let server = BulletServer::build(ServingConfig::default(), BuildOptions::default());
        let out = server.serve_dataset(&Dataset::sharegpt(), 5.0, 15, 1);
        assert_eq!(out.records.len(), 15);
        let s = summarize(&out.records, &server.cfg().slo, None);
        assert!(s.throughput_tok_s > 0.0);
    }

    #[test]
    fn build_with_profiling_improves_or_matches() {
        let cfg = ServingConfig::default();
        let profiled = BulletServer::build(cfg.clone(), BuildOptions::with_coarse_profiling(&cfg));
        // the profiled model carries non-trivial correction data
        assert!(profiled.perf().p_b >= 1.0);
        let out = profiled.serve_dataset(&Dataset::sharegpt(), 5.0, 10, 2);
        assert_eq!(out.records.len(), 10);
    }

    #[test]
    fn cluster_serving_through_the_facade() {
        use crate::cluster::RouterPolicy;
        let server = BulletServer::build(ServingConfig::default(), BuildOptions::default());
        let trace = crate::workload::generate_n_requests(&Dataset::sharegpt(), 12.0, 12, 4);
        let out = server.serve_cluster(
            &trace,
            &ClusterConfig { replicas: 2, router: RouterPolicy::SloSlack, ..Default::default() },
        );
        assert_eq!(out.records.len(), 12);
        assert_eq!(out.per_replica.len(), 2);
        let s = summarize(&out.records, &server.cfg().slo, Some(out.virtual_duration));
        assert!(s.throughput_tok_s > 0.0);
    }

    #[test]
    fn timeline_toggle() {
        let mut server = BulletServer::build(ServingConfig::default(), BuildOptions::default());
        server.record_timeline(true);
        let out = server.serve_dataset(&Dataset::sharegpt(), 5.0, 8, 3);
        assert!(!out.timeline.is_empty());
    }
}
