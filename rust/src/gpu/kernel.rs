//! Kernel descriptors: the unit of work the simulator executes.

/// Operator class — determines partial-SM scaling behaviour and which
/// contention bucket a kernel falls into (compute-ish vs memory-ish).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// QKV projection GEMM (prefill).
    GemmQkv,
    /// Prefill self-attention (FlashAttention-style).
    AttnPrefill,
    /// Output-projection GEMM.
    GemmOProj,
    /// MLP GEMMs (gate/up/down fused accounting).
    GemmMlp,
    /// Decode attention (memory-bound KV sweep).
    AttnDecode,
    /// Decode-phase GEMMs (skinny, memory-bound at small batch).
    GemmDecode,
    /// Elementwise / norm / rope operators.
    Elementwise,
}

impl OpClass {
    /// Short label for tables.
    pub fn label(&self) -> &'static str {
        match self {
            OpClass::GemmQkv => "QKV",
            OpClass::AttnPrefill => "Attn",
            OpClass::GemmOProj => "OProj",
            OpClass::GemmMlp => "MLP",
            OpClass::AttnDecode => "DecAttn",
            OpClass::GemmDecode => "DecGemm",
            OpClass::Elementwise => "Elemwise",
        }
    }

    /// Whether this class belongs to the decode phase.
    pub fn is_decode(&self) -> bool {
        matches!(self, OpClass::AttnDecode | OpClass::GemmDecode)
    }
}

/// A kernel: pure work descriptor (no data).  The simulator turns this
/// into time; the PJRT runtime is the one that does real math.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    pub op: OpClass,
    /// Floating-point operations.
    pub flops: f64,
    /// Bytes moved to/from HBM.
    pub bytes: f64,
    /// Grid size in thread blocks (for wave quantization).
    pub grid: usize,
    /// Arbitrary tag for tracing (e.g. layer index).
    pub tag: u32,
}

impl KernelDesc {
    pub fn new(op: OpClass, flops: f64, bytes: f64, grid: usize) -> KernelDesc {
        KernelDesc {
            op,
            flops,
            bytes,
            grid,
            tag: 0,
        }
    }

    pub fn with_tag(mut self, tag: u32) -> KernelDesc {
        self.tag = tag;
        self
    }

    /// Arithmetic intensity, FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensity() {
        let k = KernelDesc::new(OpClass::GemmMlp, 2e12, 1e9, 512);
        assert!((k.intensity() - 2000.0).abs() < 1e-9);
        let z = KernelDesc::new(OpClass::Elementwise, 1.0, 0.0, 1);
        assert!(z.intensity().is_infinite());
    }

    #[test]
    fn labels_unique() {
        use OpClass::*;
        let all = [
            GemmQkv, AttnPrefill, GemmOProj, GemmMlp, AttnDecode, GemmDecode, Elementwise,
        ];
        let labels: std::collections::HashSet<_> = all.iter().map(|o| o.label()).collect();
        assert_eq!(labels.len(), all.len());
    }

    #[test]
    fn decode_classification() {
        assert!(OpClass::AttnDecode.is_decode());
        assert!(OpClass::GemmDecode.is_decode());
        assert!(!OpClass::GemmQkv.is_decode());
        assert!(!OpClass::Elementwise.is_decode());
    }
}
