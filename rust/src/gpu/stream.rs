//! SM-masked task queues — the simulator analog of CUDA streams tagged
//! with `libsmctrl_set_stream_mask` masks (§3.4.1).
//!
//! A mask is a bitset over SM indices with 2-SM allocation granularity.
//! The resource manager pre-builds a palette of masked streams and the
//! schedulers launch kernels onto them; kernels in one stream serialize,
//! kernels in different streams may overlap (concurrent kernel execution).

/// Bitmask over SMs (supports up to 192 SMs — A100's 108 and H100's 132 fit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SmMask {
    bits: [u64; 3],
}

impl SmMask {
    /// Empty mask (no SMs — kernels on it can never run).
    pub fn empty() -> SmMask {
        SmMask { bits: [0; 3] }
    }

    /// Mask of SMs `[lo, hi)`.
    pub fn range(lo: usize, hi: usize) -> SmMask {
        assert!(lo <= hi && hi <= 192, "SmMask::range({lo},{hi})");
        let mut m = SmMask::empty();
        for i in lo..hi {
            m.set(i);
        }
        m
    }

    /// First `n` SMs.
    pub fn first(n: usize) -> SmMask {
        SmMask::range(0, n)
    }

    /// Last `n` of `total` SMs.
    pub fn last(n: usize, total: usize) -> SmMask {
        assert!(n <= total);
        SmMask::range(total - n, total)
    }

    pub fn set(&mut self, i: usize) {
        assert!(i < 192);
        self.bits[i / 64] |= 1 << (i % 64);
    }

    pub fn contains(&self, i: usize) -> bool {
        if i >= 192 {
            return false;
        }
        self.bits[i / 64] & (1 << (i % 64)) != 0
    }

    /// Number of SMs in the mask.
    pub fn count(&self) -> usize {
        self.bits.iter().map(|b| b.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&b| b == 0)
    }

    pub fn intersect(&self, other: &SmMask) -> SmMask {
        SmMask {
            bits: [
                self.bits[0] & other.bits[0],
                self.bits[1] & other.bits[1],
                self.bits[2] & other.bits[2],
            ],
        }
    }

    pub fn union(&self, other: &SmMask) -> SmMask {
        SmMask {
            bits: [
                self.bits[0] | other.bits[0],
                self.bits[1] | other.bits[1],
                self.bits[2] | other.bits[2],
            ],
        }
    }

    /// SMs in self but not other.
    pub fn minus(&self, other: &SmMask) -> SmMask {
        SmMask {
            bits: [
                self.bits[0] & !other.bits[0],
                self.bits[1] & !other.bits[1],
                self.bits[2] & !other.bits[2],
            ],
        }
    }

    /// Number of SMs shared with `other`.
    pub fn overlap(&self, other: &SmMask) -> usize {
        self.intersect(other).count()
    }
}

/// Opaque stream handle issued by the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StreamId(pub usize);

/// A stream: an ordered queue of kernels bound to an SM mask.
#[derive(Debug, Clone)]
pub struct Stream {
    pub id: StreamId,
    pub mask: SmMask,
    /// Human label ("prefill-54sm" etc.) for traces.
    pub label: String,
}

impl Stream {
    pub fn new(id: StreamId, mask: SmMask, label: &str) -> Stream {
        Stream {
            id,
            mask,
            label: label.to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_count() {
        assert_eq!(SmMask::range(0, 108).count(), 108);
        assert_eq!(SmMask::range(10, 20).count(), 10);
        assert_eq!(SmMask::empty().count(), 0);
        assert!(SmMask::empty().is_empty());
    }

    #[test]
    fn first_last_disjoint_cover() {
        let total = 108;
        let p = SmMask::first(60);
        let d = SmMask::last(48, total);
        assert_eq!(p.overlap(&d), 0);
        assert_eq!(p.union(&d).count(), 108);
    }

    #[test]
    fn contains_boundaries() {
        let m = SmMask::range(64, 70); // crosses the u64 word boundary
        assert!(!m.contains(63));
        assert!(m.contains(64));
        assert!(m.contains(69));
        assert!(!m.contains(70));
        assert!(!m.contains(500));
    }

    #[test]
    fn set_operations() {
        let a = SmMask::range(0, 10);
        let b = SmMask::range(5, 15);
        assert_eq!(a.intersect(&b).count(), 5);
        assert_eq!(a.union(&b).count(), 15);
        assert_eq!(a.minus(&b).count(), 5);
        assert_eq!(a.overlap(&b), 5);
    }

    #[test]
    fn word_boundary_128() {
        let m = SmMask::range(120, 136);
        assert_eq!(m.count(), 16);
        assert!(m.contains(127) && m.contains(128) && m.contains(135));
    }
}
