//! Wave quantization (paper Eq. 1).
//!
//! A kernel with grid size `g` thread blocks on an `M`-SM GPU needs
//! `ceil(g/M)` waves; in the tail wave only `g mod M` SMs are busy.
//! Equation 1 gives the fraction of SM-cycles idled:
//!
//! ```text
//! s = 1 - g / (M * ceil(g / M))
//! ```
//!
//! Table 1 of the paper is this formula evaluated over Llama-3.1-8B's
//! per-operator grids; `table1_wave_quantization` regenerates it.

/// Idle-SM-cycle ratio `s` in [0, 1) per Eq. 1.
///
/// `grid` = number of thread blocks; `sms` = SMs visible to the kernel
/// (the *mask* size, not the whole GPU — a partitioned kernel quantizes
/// against its partition).
pub fn wave_quantization_idle_ratio(grid: usize, sms: usize) -> f64 {
    if grid == 0 || sms == 0 {
        return 0.0;
    }
    let waves = grid.div_ceil(sms);
    1.0 - grid as f64 / (sms as f64 * waves as f64)
}

/// Number of waves the kernel executes.
pub fn wave_count(grid: usize, sms: usize) -> usize {
    if sms == 0 {
        return 0;
    }
    grid.div_ceil(sms)
}

/// Effective slowdown factor from wave quantization: executing `grid`
/// blocks takes `ceil(g/M)` waves instead of the ideal `g/M`, i.e. time
/// inflates by `1 / (1 - s)`.
pub fn wave_slowdown(grid: usize, sms: usize) -> f64 {
    let s = wave_quantization_idle_ratio(grid, sms);
    1.0 / (1.0 - s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_multiple_no_idle() {
        assert_eq!(wave_quantization_idle_ratio(108, 108), 0.0);
        assert_eq!(wave_quantization_idle_ratio(216, 108), 0.0);
        assert_eq!(wave_quantization_idle_ratio(54, 54), 0.0);
    }

    #[test]
    fn single_block_worst_case() {
        // 1 block on 108 SMs: 107/108 idle.
        let s = wave_quantization_idle_ratio(1, 108);
        assert!((s - 107.0 / 108.0).abs() < 1e-12);
    }

    #[test]
    fn tail_wave() {
        // 128 blocks on 108 SMs: 2 waves, 1 - 128/216 = 0.407...
        let s = wave_quantization_idle_ratio(128, 108);
        assert!((s - (1.0 - 128.0 / 216.0)).abs() < 1e-12);
        assert_eq!(wave_count(128, 108), 2);
    }

    #[test]
    fn paper_qkv_1024() {
        // Table 1, QKV @ sl=1024: grid 1024/ (tokens per block 8?) —
        // the table reports 11.1%: that's 96 blocks on 108 SMs:
        // 1 - 96/108 = 0.111.
        let s = wave_quantization_idle_ratio(96, 108);
        assert!((s - 0.1111).abs() < 1e-3, "{s}");
    }

    #[test]
    fn slowdown_consistency() {
        for grid in [1usize, 13, 96, 108, 109, 250, 1024] {
            let s = wave_quantization_idle_ratio(grid, 108);
            let f = wave_slowdown(grid, 108);
            assert!((f - 1.0 / (1.0 - s)).abs() < 1e-12);
            assert!(f >= 1.0);
        }
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(wave_quantization_idle_ratio(0, 108), 0.0);
        assert_eq!(wave_quantization_idle_ratio(10, 0), 0.0);
        assert_eq!(wave_count(10, 0), 0);
    }

    #[test]
    fn monotone_in_partition_alignment() {
        // Idle ratio shrinks as grid approaches a full multiple.
        let a = wave_quantization_idle_ratio(109, 108);
        let b = wave_quantization_idle_ratio(160, 108);
        let c = wave_quantization_idle_ratio(215, 108);
        assert!(a > b && b > c);
    }
}
