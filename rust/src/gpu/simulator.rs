//! Fluid discrete-event GPU simulator with concurrent SM-masked streams.
//!
//! Physics:
//! - each stream runs at most one kernel at a time (head-of-line), kernels
//!   across streams co-run;
//! - a kernel's *exclusive* SMs are its stream mask minus other running
//!   streams' masks; SMs shared by `n` running kernels contribute `1/n`
//!   each (hardware CKE shares SMs round-robin — §2.2.2's unpredictability
//!   is exactly why Bullet prefers disjoint masks);
//! - co-running kernels contend for HBM bandwidth: if aggregate demand
//!   exceeds the peak, every kernel's memory term stretches by the
//!   oversubscription ratio;
//! - event boundaries (kernel start/finish, mask reconfiguration) trigger
//!   a rate recomputation; between events progress is linear.
//!
//! The simulator integrates achieved FLOPs and bytes over time, giving the
//! utilization counters behind Figs. 2, 4 and 12.

use crate::config::GpuSpec;
use crate::gpu::kernel::KernelDesc;
use crate::gpu::roofline::GroundTruth;
use crate::gpu::stream::{SmMask, Stream, StreamId};
use crate::gpu::wave::wave_quantization_idle_ratio;
use crate::obs::ledger::{GpuTimeCategory, SmLedger};
use crate::util::memo::MemoCounters;
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Which serving phase a stream's kernels belong to, for SM-second
/// attribution.  The resource manager tags its palette streams at
/// creation; untagged (`Auto`) streams fall back to classifying each
/// kernel by its [`crate::gpu::kernel::OpClass`].  Attribution only —
/// never consulted by the physics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamPhase {
    #[default]
    Auto,
    Prefill,
    Decode,
}

/// Why a fully-idle clock advance is happening, for SM-second
/// attribution.  `Free` (the default) charges nothing — plain idle is
/// derived as the finalize residual; the engine sets a non-`Free` tag
/// transiently around an idle jump it can attribute to a stall.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IdleTag {
    #[default]
    Free,
    KvBlocked,
    Repartition,
}

/// Ledger category for one running kernel (stream phase first, op
/// class as the `Auto` fallback — decode launches include elementwise
/// kernels, so op class alone cannot attribute them).
fn attrib_category(phase: StreamPhase, op: crate::gpu::kernel::OpClass) -> GpuTimeCategory {
    use crate::gpu::kernel::OpClass;
    match phase {
        StreamPhase::Decode => GpuTimeCategory::Decode,
        StreamPhase::Prefill => {
            if op == OpClass::AttnPrefill {
                GpuTimeCategory::PrefillAttention
            } else {
                GpuTimeCategory::PrefillCompute
            }
        }
        StreamPhase::Auto => {
            if op.is_decode() {
                GpuTimeCategory::Decode
            } else if op == OpClass::AttnPrefill {
                GpuTimeCategory::PrefillAttention
            } else {
                GpuTimeCategory::PrefillCompute
            }
        }
    }
}

/// A completed-kernel record.
#[derive(Debug, Clone)]
pub struct Completion {
    pub stream: StreamId,
    pub kernel: KernelDesc,
    pub start: f64,
    pub end: f64,
}

/// Utilization integrated over a window.
#[derive(Debug, Clone, Copy, Default)]
pub struct UtilSample {
    /// Window length, seconds.
    pub dt: f64,
    /// FLOPs executed in the window.
    pub flops: f64,
    /// Bytes moved in the window.
    pub bytes: f64,
    /// Integral of busy-SM count over time (SM·s).
    pub sm_busy: f64,
}

impl UtilSample {
    /// Achieved compute utilization vs whole-GPU peak.
    pub fn compute_util(&self, gpu: &GpuSpec) -> f64 {
        if self.dt <= 0.0 {
            return 0.0;
        }
        self.flops / self.dt / gpu.peak_flops
    }

    /// Achieved bandwidth utilization vs peak.
    pub fn bandwidth_util(&self, gpu: &GpuSpec) -> f64 {
        if self.dt <= 0.0 {
            return 0.0;
        }
        self.bytes / self.dt / gpu.peak_bandwidth
    }

    /// Mean fraction of SMs occupied.
    pub fn sm_occupancy(&self, gpu: &GpuSpec) -> f64 {
        if self.dt <= 0.0 {
            return 0.0;
        }
        self.sm_busy / self.dt / gpu.num_sms as f64
    }

    pub fn merge(&mut self, other: &UtilSample) {
        self.dt += other.dt;
        self.flops += other.flops;
        self.bytes += other.bytes;
        self.sm_busy += other.sm_busy;
    }
}

#[derive(Debug, Clone)]
struct Running {
    kernel: KernelDesc,
    start: f64,
    /// Remaining fraction of the kernel's work in [0,1].
    remaining: f64,
    /// Noise factor sampled at launch.
    noise: f64,
}

#[derive(Debug)]
struct StreamState {
    stream: Stream,
    queue: VecDeque<KernelDesc>,
    running: Option<Running>,
    /// Attribution phase tag (never consulted by the physics).
    phase: StreamPhase,
}

/// Solo-time row for one running kernel (first pass of the rate
/// computation); kept as reusable scratch in [`RateCache`].  The
/// trailing attribution fields (`eff`, `phase`, `op`, `grid`) feed the
/// ledger sidecar only.
#[derive(Debug, Clone, Copy)]
struct SoloRow {
    idx: usize,
    tc: f64,
    tb: f64,
    noise: f64,
    flops: f64,
    bytes: f64,
    eff: f64,
    phase: StreamPhase,
    op: crate::gpu::kernel::OpClass,
    grid: usize,
}

/// Ledger attribution for one rate-table row: where its `eff × dt`
/// SM-seconds go while the row is in flight.  Built alongside the rate
/// table (same rows, same order) and read by `advance_by`.
#[derive(Debug, Clone, Copy)]
struct AttribRow {
    cat: GpuTimeCategory,
    eff: f64,
    /// Wave-quantization idle fraction of the row's partition (0 for
    /// memory-bound rows).
    pad: f64,
}

/// Memoized rate table plus the scratch buffers behind it.
///
/// The table is a pure function of the *running set* (which streams
/// have a kernel in flight, their masks and launch noise) and — under a
/// drift regime — of the clock.  It is invalidated whenever a kernel
/// starts ([`Simulator::try_start`]), finishes (`advance_by`), or a
/// mask changes ([`Simulator::set_stream_mask`]); between those events
/// `step`/`run_for` reuse it, making steady-state stepping O(1) and
/// allocation-free instead of an O(running²) mask-overlap rescan with
/// fresh `Vec`s per step.  `busy_sms` folds the old double scan
/// (`rates()` + `busy_sms()` both walked `effective_sms`) into one.
#[derive(Debug, Default)]
struct RateCache {
    /// (stream idx, rate, flops_rate, bytes_rate) — same rows in the
    /// same order as the reference recomputation.
    rates: Vec<(usize, f64, f64, f64)>,
    /// Ledger attribution per rate row (same order as `rates`; stays in
    /// the cache while `rates` is lent out during an advance).
    attrib: Vec<AttribRow>,
    /// Sum of effective SMs over running kernels.
    busy_sms: f64,
    valid: bool,
    /// Clock the table was computed at; only consulted under a drift
    /// regime, where rates are time-varying.
    at_clock: f64,
    counters: MemoCounters,
    // reusable scratch for the recomputation
    running: Vec<usize>,
    eff: Vec<(usize, f64)>,
    solo: Vec<SoloRow>,
    demands: Vec<f64>,
    finished: Vec<usize>,
}

/// The simulator.
pub struct Simulator {
    pub gt: GroundTruth,
    clock: f64,
    streams: Vec<StreamState>,
    rng: Rng,
    /// Run-correlated slowdown factor (see GroundTruth::run_noise_sigma).
    run_noise: f64,
    /// Per-device lottery factor (DriftSpec::lottery_sigma; 1.0 when off).
    lottery: f64,
    completions: Vec<Completion>,
    window: UtilSample,
    total: UtilSample,
    /// Reuse the rate table between invalidating events (default on).
    /// Off recomputes every step — the reference path; both legs are
    /// bit-identical because the recomputation is the same code.
    memo: bool,
    cache: RateCache,
    /// SM-second attribution (busy categories + tagged stalls; idle is
    /// the engine-level finalize residual).  Pure side-channel: accrual
    /// never touches the physics or the rng stream.
    ledger: SmLedger,
    /// Attribution for the NEXT fully-idle clock advance (see
    /// [`IdleTag`]); reset to `Free` by the engine after each jump.
    idle_tag: IdleTag,
}

impl Simulator {
    pub fn new(gt: GroundTruth, seed: u64) -> Simulator {
        let mut rng = Rng::new(seed);
        let run_noise = if gt.run_noise_sigma > 0.0 {
            rng.lognormal(0.0, gt.run_noise_sigma)
        } else {
            1.0
        };
        // Drawn only when enabled, so a drift-free GT consumes the same
        // rng stream as before drift regimes existed (bit-identity).
        let lottery = if gt.drift.lottery_sigma > 0.0 {
            rng.lognormal(0.0, gt.drift.lottery_sigma)
        } else {
            1.0
        };
        Simulator {
            gt,
            clock: 0.0,
            streams: Vec::new(),
            rng,
            run_noise,
            lottery,
            completions: Vec::new(),
            window: UtilSample::default(),
            total: UtilSample::default(),
            memo: true,
            cache: RateCache::default(),
            ledger: SmLedger::default(),
            idle_tag: IdleTag::default(),
        }
    }

    /// Accrued (non-finalized) SM-second ledger: busy categories plus
    /// tagged stall time.  The engine finalizes a copy with
    /// `num_sms × makespan` at teardown.
    pub fn ledger(&self) -> SmLedger {
        self.ledger
    }

    /// Set how the NEXT fully-idle clock advance is attributed.  The
    /// engine brackets each idle jump with a tag and resets to
    /// [`IdleTag::Free`] afterwards so no stale tag can leak into the
    /// cluster layer's drained-replica fast-forward.
    pub fn set_idle_tag(&mut self, tag: IdleTag) {
        self.idle_tag = tag;
    }

    /// Tag a stream's kernels with their serving phase (attribution
    /// only; the physics never reads it).
    pub fn set_stream_phase(&mut self, id: StreamId, phase: StreamPhase) {
        self.streams[id.0].phase = phase;
    }

    /// Toggle rate-table memoization (`ServingConfig.memo`).  Off runs
    /// the reference recompute-every-step path; output is bit-identical
    /// either way.
    pub fn set_memo(&mut self, on: bool) {
        self.memo = on;
        self.invalidate_rates();
    }

    /// Rate-table reuse counters (hits = steps served from the cache).
    pub fn rate_memo_counters(&self) -> MemoCounters {
        self.cache.counters
    }

    /// Time-varying COMPUTE-side slowdown of the drift regime at virtual
    /// time `t` (exactly 1.0 when off).  Thermal throttling lowers SM
    /// clocks and a phantom co-tenant steals SM cycles — both stretch
    /// the compute term while HBM bandwidth stays intact, so memory-
    /// bound kernels (decode) barely feel what compute-bound kernels
    /// (prefill) feel fully.  That phase asymmetry is what a frozen
    /// uniform model cannot absorb.  Applied piecewise-constant per
    /// event segment; [`Simulator::step`]/[`Simulator::run_for`] insert
    /// an extra event at the step-interference boundary so the
    /// discontinuity never lands mid-segment.
    fn drift_compute_factor_at(&self, t: f64) -> f64 {
        let d = &self.gt.drift;
        let mut factor = 1.0;
        if d.throttle_floor < 1.0 {
            let frac = (t / d.throttle_ramp_s.max(1e-9)).clamp(0.0, 1.0);
            let speed = 1.0 - frac * (1.0 - d.throttle_floor);
            factor /= speed.max(1e-6);
        }
        if t >= d.step_at_s {
            factor *= d.step_factor;
        }
        factor
    }

    /// Cap an advance so it never crosses the step-interference boundary
    /// (the post-step rates get their own segment).
    fn cap_at_step_boundary(&self, dt: f64) -> f64 {
        let at = self.gt.drift.step_at_s;
        if self.gt.drift.step_factor > 1.0 && self.clock < at && self.clock + dt > at {
            at - self.clock
        } else {
            dt
        }
    }

    pub fn gpu(&self) -> &GpuSpec {
        &self.gt.gpu
    }

    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Create a stream bound to an SM mask; returns its handle.
    pub fn create_stream(&mut self, mask: SmMask, label: &str) -> StreamId {
        let id = StreamId(self.streams.len());
        self.streams.push(StreamState {
            stream: Stream::new(id, mask, label),
            queue: VecDeque::new(),
            running: None,
            phase: StreamPhase::Auto,
        });
        id
    }

    /// Re-mask a stream (pre-configured stream switching is modeled at the
    /// resource-manager level; this supports MPS-quota-style baselines).
    /// Applies to kernels *not yet started*.
    pub fn set_stream_mask(&mut self, id: StreamId, mask: SmMask) {
        self.streams[id.0].stream.mask = mask;
        self.invalidate_rates();
    }

    pub fn stream_mask(&self, id: StreamId) -> SmMask {
        self.streams[id.0].stream.mask
    }

    /// Enqueue a kernel.
    pub fn submit(&mut self, id: StreamId, kernel: KernelDesc) {
        self.streams[id.0].queue.push_back(kernel);
        self.try_start(id.0);
    }

    pub fn submit_all(&mut self, id: StreamId, kernels: impl IntoIterator<Item = KernelDesc>) {
        for k in kernels {
            self.submit(id, k);
        }
    }

    /// Is the stream fully drained (no queue, nothing running)?
    pub fn stream_idle(&self, id: StreamId) -> bool {
        let s = &self.streams[id.0];
        s.queue.is_empty() && s.running.is_none()
    }

    pub fn queue_len(&self, id: StreamId) -> usize {
        let s = &self.streams[id.0];
        s.queue.len() + s.running.is_some() as usize
    }

    /// Whether any work exists anywhere.
    pub fn idle(&self) -> bool {
        self.streams
            .iter()
            .all(|s| s.queue.is_empty() && s.running.is_none())
    }

    /// Drain accumulated completion records.  Draining an empty buffer
    /// is allocation-free (`mem::take` of an empty `Vec` never touches
    /// the heap), so idle polling costs nothing.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Utilization accumulated since the last call (windowed counter).
    pub fn take_util_window(&mut self) -> UtilSample {
        std::mem::replace(&mut self.window, UtilSample::default())
    }

    /// Utilization since simulator creation.
    pub fn total_util(&self) -> UtilSample {
        self.total
    }

    fn try_start(&mut self, idx: usize) {
        if self.streams[idx].running.is_none() {
            if let Some(k) = self.streams[idx].queue.pop_front() {
                let noise = if self.gt.noise_sigma > 0.0 {
                    self.rng.lognormal(0.0, self.gt.noise_sigma)
                } else {
                    1.0
                };
                self.streams[idx].running = Some(Running {
                    kernel: k,
                    start: self.clock,
                    remaining: 1.0,
                    noise,
                });
                self.invalidate_rates();
            }
        }
    }

    /// Drop the memoized rate table (the running set or a mask changed).
    fn invalidate_rates(&mut self) {
        if self.cache.valid {
            self.cache.valid = false;
            self.cache.counters.invalidations += 1;
        }
    }

    /// Ensure `self.cache` holds the rate table for the current state.
    /// Reuses the memoized table when nothing invalidated it (and, under
    /// a drift regime, only at the exact clock it was computed for —
    /// drift makes rates time-varying, so any clock motion recomputes).
    fn refresh_rates(&mut self) {
        let fresh = self.memo
            && self.cache.valid
            && (self.gt.drift.is_none() || self.cache.at_clock.to_bits() == self.clock.to_bits());
        if fresh {
            self.cache.counters.hits += 1;
            return;
        }
        self.cache.counters.misses += 1;
        self.compute_rates();
        self.cache.valid = true;
        self.cache.at_clock = self.clock;
    }

    /// Recompute the rate table into `self.cache` (scratch buffers, no
    /// allocation in steady state).  The arithmetic — every operation
    /// and its order — is exactly the pre-memo `effective_sms()` +
    /// `rates()` code, so a recompute-every-step run (memo off) and a
    /// memoized run produce bit-identical trajectories.
    fn compute_rates(&mut self) {
        // Drift: throttle/co-tenant stretch the COMPUTE term only; the
        // device lottery scales the whole kernel.  Both are exactly 1.0
        // with drift off, so multiplication is bit-identical.
        let drift_c = if self.gt.drift.is_none() {
            1.0
        } else {
            self.drift_compute_factor_at(self.clock)
        };
        let run_noise = self.run_noise;
        let lottery = self.lottery;
        let Simulator { gt, streams, cache, .. } = self;
        // Effective SM count for each running kernel given mask overlaps.
        cache.running.clear();
        cache.running.extend(
            streams.iter().enumerate().filter(|(_, s)| s.running.is_some()).map(|(i, _)| i),
        );
        cache.eff.clear();
        for &i in &cache.running {
            let mi = streams[i].stream.mask;
            // count sharers per SM: exclusive SMs count 1, shared count 1/n.
            let mut eff = mi.count() as f64;
            for &j in &cache.running {
                if j == i {
                    continue;
                }
                let shared = mi.overlap(&streams[j].stream.mask) as f64;
                // each shared SM is split; subtract the lost half (pairwise
                // approximation — exact for the two-phase case we model).
                eff -= shared * 0.5;
            }
            cache.eff.push((i, eff.max(1.0)));
        }
        cache.busy_sms = cache.eff.iter().map(|(_, s)| s).sum();
        cache.rates.clear();
        if cache.eff.is_empty() {
            cache.attrib.clear();
            return;
        }
        // First pass: solo times on effective SMs.
        cache.solo.clear();
        for &(i, sms) in &cache.eff {
            let r = streams[i].running.as_ref().unwrap();
            let sms_i = sms.round().max(1.0) as usize;
            let tc = gt.compute_time(&r.kernel, sms_i) + gt.gpu.launch_overhead;
            let tb = gt.memory_time(&r.kernel, sms_i);
            cache.solo.push(SoloRow {
                idx: i,
                tc,
                tb,
                noise: r.noise,
                flops: r.kernel.flops,
                bytes: r.kernel.bytes,
                eff: sms,
                phase: streams[i].phase,
                op: r.kernel.op,
                grid: r.kernel.grid,
            });
        }
        // Bandwidth contention: (a) hard cap — if aggregate demand exceeds
        // peak, everyone's memory term stretches by the oversubscription
        // ratio; (b) graded interference — even below the cap, concurrent
        // HBM/L2 traffic degrades each other (row-buffer conflicts,
        // partition camping): the memory term inflates by
        // `1 + GAMMA * other_demand / peak`.
        const GAMMA: f64 = 0.35;
        cache.demands.clear();
        cache.demands.extend(cache.solo.iter().map(|t| {
            let solo = t.tc.max(t.tb);
            if solo > 0.0 {
                t.bytes / solo
            } else {
                0.0
            }
        }));
        let total_demand: f64 = cache.demands.iter().sum();
        let bw_scale = if total_demand > gt.gpu.peak_bandwidth {
            gt.gpu.peak_bandwidth / total_demand
        } else {
            1.0
        };
        cache.attrib.clear();
        for (t, &demand) in cache.solo.iter().zip(&cache.demands) {
            let other = (total_demand - demand).max(0.0);
            let interference = 1.0 + GAMMA * other / gt.gpu.peak_bandwidth;
            let tb = t.tb * interference / bw_scale;
            let t_eff = ((t.tc * drift_c).max(tb)) * t.noise * run_noise * lottery;
            let rate = if t_eff > 0.0 { 1.0 / t_eff } else { f64::INFINITY };
            cache.rates.push((t.idx, rate, t.flops * rate, t.bytes * rate));
            // Attribution sidecar (same rows, same order as `rates`):
            // a compute-bound row idles `pad` of its partition to wave
            // quantization (Eq. 1); memory-bound rows pay none.  Never
            // feeds back into the rate arithmetic above.
            let pad = if t.tc * drift_c >= tb {
                wave_quantization_idle_ratio(t.grid, t.eff.round().max(1.0) as usize)
            } else {
                0.0
            };
            cache.attrib.push(AttribRow {
                cat: attrib_category(t.phase, t.op),
                eff: t.eff,
                pad,
            });
        }
    }

    /// Advance to the next kernel completion (or return false if idle).
    pub fn step(&mut self) -> bool {
        self.refresh_rates();
        if self.cache.rates.is_empty() {
            return false;
        }
        // Borrow dance: lend the table out of the cache for the advance,
        // then put the buffer back (capacity retained; the `valid` flag,
        // not the buffer, decides reuse).
        let rates = std::mem::take(&mut self.cache.rates);
        // Time until first completion.
        let mut dt = f64::INFINITY;
        for &(i, rate, _, _) in &rates {
            let rem = self.streams[i].running.as_ref().unwrap().remaining;
            if rate > 0.0 {
                dt = dt.min(rem / rate);
            }
        }
        assert!(dt.is_finite() && dt >= 0.0, "simulator stuck: dt={dt}");
        let dt = self.cap_at_step_boundary(dt);
        self.advance_by(dt, &rates);
        self.cache.rates = rates;
        true
    }

    /// Advance virtual time by exactly `dt_target` seconds (capped at the
    /// next completion repeatedly), processing completions on the way.
    pub fn run_for(&mut self, dt_target: f64) {
        let deadline = self.clock + dt_target;
        while self.clock < deadline - 1e-15 {
            self.refresh_rates();
            if self.cache.rates.is_empty() {
                // idle: jump straight to deadline
                if self.idle_tag != IdleTag::Free {
                    let cat = match self.idle_tag {
                        IdleTag::KvBlocked => GpuTimeCategory::KvBlocked,
                        _ => GpuTimeCategory::Repartition,
                    };
                    let span = (deadline - self.clock) * self.gt.gpu.num_sms as f64;
                    self.ledger.charge(cat, span);
                }
                self.clock = deadline;
                self.window.dt += 0.0;
                return;
            }
            let rates = std::mem::take(&mut self.cache.rates);
            let mut dt = deadline - self.clock;
            for &(i, rate, _, _) in &rates {
                let rem = self.streams[i].running.as_ref().unwrap().remaining;
                if rate > 0.0 {
                    dt = dt.min(rem / rate);
                }
            }
            let dt = self.cap_at_step_boundary(dt);
            self.advance_by(dt, &rates);
            self.cache.rates = rates;
        }
    }

    /// Jump an idle simulator's clock to the absolute instant `t` (no-op
    /// when the clock is already past it).  Unlike [`Simulator::run_for`],
    /// the resulting clock is a pure function of `t` — not of the current
    /// clock — so an idle engine that skipped intermediate horizons lands
    /// on bitwise-identical timestamps to one that visited every horizon.
    /// The engine's idle-time jumps (and the cluster layer's
    /// drained-replica fast-forward) rely on exactly this property.
    /// Idle time accrues no utilization, matching `run_for` while empty.
    pub fn advance_idle_to(&mut self, t: f64) {
        debug_assert!(self.idle(), "advance_idle_to on a busy simulator");
        if t > self.clock {
            // Tagged idle (kv-blocked / repartition) accrues to the
            // ledger; untagged idle stays unaccounted here and becomes
            // the finalize residual, keeping this jump history-free.
            if self.idle_tag != IdleTag::Free {
                let cat = match self.idle_tag {
                    IdleTag::KvBlocked => GpuTimeCategory::KvBlocked,
                    _ => GpuTimeCategory::Repartition,
                };
                self.ledger.charge(cat, (t - self.clock) * self.gt.gpu.num_sms as f64);
            }
            self.clock = t;
        }
    }

    /// Run until a specific stream is fully drained.
    pub fn run_until_stream_idle(&mut self, id: StreamId) {
        while !self.stream_idle(id) {
            if !self.step() {
                break;
            }
        }
    }

    /// Run until every stream is drained.
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    fn advance_by(&mut self, dt: f64, rates: &[(usize, f64, f64, f64)]) {
        // The fold of effective SMs was computed alongside the rate
        // table (same pre-advance state the old separate `busy_sms()`
        // scan read), so the double scan per step is gone.
        let busy = self.cache.busy_sms;
        let mut flops = 0.0;
        let mut bytes = 0.0;
        let mut finished = std::mem::take(&mut self.cache.finished);
        finished.clear();
        for &(i, rate, frate, brate) in rates {
            let r = self.streams[i].running.as_mut().unwrap();
            let progress = rate * dt;
            flops += frate * dt;
            bytes += brate * dt;
            r.remaining -= progress;
            if r.remaining <= 1e-12 {
                finished.push(i);
            }
        }
        // Ledger accrual: a pure side-channel over the attribution rows
        // built alongside the rate table (same rows, same order).  Each
        // row charges its effective SMs for `dt`, split between its
        // category and the wave-quantization padding share.
        for a in &self.cache.attrib {
            self.ledger.charge(a.cat, a.eff * dt * (1.0 - a.pad));
            if a.pad > 0.0 {
                self.ledger.charge(GpuTimeCategory::WaveQuant, a.eff * dt * a.pad);
            }
        }
        self.clock += dt;
        let sample = UtilSample {
            dt,
            flops,
            bytes,
            sm_busy: busy * dt,
        };
        self.window.merge(&sample);
        self.total.merge(&sample);
        if !finished.is_empty() {
            self.invalidate_rates();
        }
        for &i in &finished {
            let r = self.streams[i].running.take().unwrap();
            self.completions.push(Completion {
                stream: StreamId(i),
                kernel: r.kernel,
                start: r.start,
                end: self.clock,
            });
            self.try_start(i);
        }
        finished.clear();
        self.cache.finished = finished;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::kernel::OpClass;

    fn sim() -> Simulator {
        Simulator::new(GroundTruth::noiseless(GpuSpec::a100()), 1)
    }

    fn gemm(flops: f64) -> KernelDesc {
        KernelDesc::new(OpClass::GemmMlp, flops, flops / 300.0, 1080)
    }

    fn mem_kernel(bytes: f64) -> KernelDesc {
        KernelDesc::new(OpClass::AttnDecode, bytes, bytes, 108)
    }

    #[test]
    fn single_kernel_duration_matches_roofline() {
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "full");
        let k = gemm(4e12);
        let expect = s.gt.solo_time(&k, 108);
        s.submit(st, k);
        s.run_until_idle();
        let done = s.take_completions();
        assert_eq!(done.len(), 1);
        let dur = done[0].end - done[0].start;
        assert!((dur - expect).abs() / expect < 1e-9, "dur {dur} expect {expect}");
    }

    #[test]
    fn advance_idle_to_is_history_free() {
        // The jump must land on fl(t) no matter how many intermediate
        // horizons were visited — the property the cluster layer's
        // drained-replica skip depends on.
        let mut a = sim();
        let mut b = sim();
        for t in [0.1, 0.3, 0.7] {
            a.advance_idle_to(t);
        }
        b.advance_idle_to(0.7);
        assert_eq!(a.now().to_bits(), b.now().to_bits());
        // and it never rewinds
        a.advance_idle_to(0.2);
        assert_eq!(a.now().to_bits(), 0.7f64.to_bits());
        // work submitted after identical jumps completes identically
        let sa = a.create_stream(SmMask::first(108), "full");
        let sb = b.create_stream(SmMask::first(108), "full");
        a.submit(sa, gemm(1e12));
        b.submit(sb, gemm(1e12));
        a.run_until_idle();
        b.run_until_idle();
        let (ca, cb) = (a.take_completions(), b.take_completions());
        assert_eq!(ca[0].end.to_bits(), cb[0].end.to_bits());
    }

    #[test]
    fn stream_serializes() {
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "full");
        s.submit(st, gemm(1e12));
        s.submit(st, gemm(1e12));
        s.run_until_idle();
        let done = s.take_completions();
        assert_eq!(done.len(), 2);
        assert!(done[1].start >= done[0].end - 1e-12);
    }

    #[test]
    fn disjoint_streams_overlap() {
        let mut s = sim();
        let a = s.create_stream(SmMask::first(54), "a");
        let b = s.create_stream(SmMask::last(54, 108), "b");
        s.submit(a, gemm(2e12));
        s.submit(b, gemm(2e12));
        s.run_until_idle();
        let done = s.take_completions();
        assert_eq!(done.len(), 2);
        // Both started at t=0 (concurrent), rather than serialized.
        assert!(done[0].start == 0.0 && done[1].start == 0.0);
    }

    #[test]
    fn compute_kernels_on_disjoint_masks_dont_contend() {
        // High-intensity GEMMs barely touch bandwidth: co-running on
        // disjoint halves should cost ~= solo-on-half time.
        let mut s = sim();
        let a = s.create_stream(SmMask::first(54), "a");
        let b = s.create_stream(SmMask::last(54, 108), "b");
        let k = gemm(2e12);
        let solo_half = s.gt.solo_time(&k, 54);
        s.submit(a, k.clone());
        s.submit(b, k.clone());
        s.run_until_idle();
        let done = s.take_completions();
        for c in &done {
            let dur = c.end - c.start;
            assert!((dur - solo_half).abs() / solo_half < 0.05, "dur {dur} vs {solo_half}");
        }
    }

    #[test]
    fn bandwidth_contention_slows_memory_kernels() {
        let mut s = sim();
        let a = s.create_stream(SmMask::first(54), "a");
        let b = s.create_stream(SmMask::last(54, 108), "b");
        let k = mem_kernel(4e9);
        let solo_half = s.gt.solo_time(&k, 54);
        s.submit(a, k.clone());
        s.submit(b, k.clone());
        s.run_until_idle();
        let done = s.take_completions();
        for c in &done {
            let dur = c.end - c.start;
            assert!(dur > solo_half * 1.1, "expected contention: {dur} vs {solo_half}");
        }
    }

    #[test]
    fn shared_sms_halve_throughput() {
        // Two compute kernels on the SAME full mask co-run at ~half speed.
        let mut s = sim();
        let a = s.create_stream(SmMask::first(108), "a");
        let b = s.create_stream(SmMask::first(108), "b");
        let k = gemm(2e12);
        let solo_full = s.gt.solo_time(&k, 108);
        s.submit(a, k.clone());
        s.submit(b, k.clone());
        s.run_until_idle();
        for c in s.take_completions() {
            let dur = c.end - c.start;
            // each sees ~54 effective SMs → roughly solo(54)
            let expect = s.gt.solo_time(&k, 54);
            assert!((dur - expect).abs() / expect < 0.1, "dur {dur} expect {expect}");
            assert!(dur > solo_full * 1.5);
        }
    }

    #[test]
    fn run_for_advances_clock_when_idle() {
        let mut s = sim();
        s.run_for(0.5);
        assert!((s.now() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn utilization_accounting_conserves_work() {
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "full");
        let k = gemm(4e12);
        let flops = k.flops;
        let bytes = k.bytes;
        s.submit(st, k);
        s.run_until_idle();
        let u = s.total_util();
        assert!((u.flops - flops).abs() / flops < 1e-6);
        assert!((u.bytes - bytes).abs() / bytes < 1e-6);
        assert!(u.compute_util(s.gpu()) <= 0.92 + 1e-9);
    }

    #[test]
    fn window_counter_resets() {
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "full");
        s.submit(st, gemm(1e12));
        s.run_until_idle();
        let w1 = s.take_util_window();
        assert!(w1.flops > 0.0);
        let w2 = s.take_util_window();
        assert_eq!(w2.flops, 0.0);
        assert_eq!(w2.dt, 0.0);
    }

    #[test]
    fn remask_applies_to_next_kernel() {
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "x");
        let k = gemm(2e12);
        let t_full = s.gt.solo_time(&k, 108);
        let t_half = s.gt.solo_time(&k, 54);
        s.submit(st, k.clone());
        s.run_until_idle();
        s.set_stream_mask(st, SmMask::first(54));
        s.submit(st, k.clone());
        s.run_until_idle();
        let done = s.take_completions();
        let d0 = done[0].end - done[0].start;
        let d1 = done[1].end - done[1].start;
        assert!((d0 - t_full).abs() / t_full < 1e-9);
        assert!((d1 - t_half).abs() / t_half < 1e-9);
    }

    #[test]
    fn drift_none_is_bit_identical_to_no_drift() {
        use crate::config::DriftSpec;
        // An explicit `none` regime must not perturb anything — not the
        // rng stream, not the rates.
        let gt_plain = GroundTruth::new(GpuSpec::a100());
        let gt_none = GroundTruth::new(GpuSpec::a100()).with_drift(DriftSpec::none());
        let mut ends = Vec::new();
        for gt in [gt_plain, gt_none] {
            let mut s = Simulator::new(gt, 7);
            let st = s.create_stream(SmMask::first(108), "x");
            for _ in 0..4 {
                s.submit(st, gemm(1e12));
            }
            s.run_until_idle();
            ends.push(s.take_completions().iter().map(|c| c.end).collect::<Vec<_>>());
        }
        assert_eq!(ends[0], ends[1]);
    }

    #[test]
    fn throttle_slows_later_kernels() {
        use crate::config::DriftSpec;
        let drift = DriftSpec {
            throttle_floor: 0.5,
            throttle_ramp_s: 1.0,
            ..DriftSpec::none()
        };
        let gt = GroundTruth::noiseless(GpuSpec::a100()).with_drift(drift);
        let mut s = Simulator::new(gt, 1);
        let st = s.create_stream(SmMask::first(108), "x");
        let k = gemm(2e12);
        s.submit(st, k.clone());
        s.run_until_idle();
        let early = s.take_completions()[0].end;
        // push the clock past the ramp, then run the same kernel again
        s.run_for(2.0);
        let t0 = s.now();
        s.submit(st, k);
        s.run_until_idle();
        let late = s.take_completions()[0].end - t0;
        assert!(
            late > early * 1.7,
            "throttled kernel {late} not ~2x the cold one {early}"
        );
    }

    #[test]
    fn step_interference_lands_at_the_boundary() {
        use crate::config::DriftSpec;
        let drift = DriftSpec {
            step_at_s: 0.5,
            step_factor: 2.0,
            ..DriftSpec::none()
        };
        let gt = GroundTruth::noiseless(GpuSpec::a100()).with_drift(drift);
        let mut s = Simulator::new(gt, 1);
        let st = s.create_stream(SmMask::first(108), "x");
        let k = gemm(2e12);
        let solo = s.gt.solo_time(&k, 108);
        // before the step: unperturbed
        s.submit(st, k.clone());
        s.run_until_idle();
        let pre = s.take_completions()[0].end;
        assert!((pre - solo).abs() / solo < 1e-9, "pre-step {pre} vs {solo}");
        // after the step: exactly 2x
        s.run_for(1.0);
        let t0 = s.now();
        s.submit(st, k.clone());
        s.run_until_idle();
        let post = s.take_completions()[0].end - t0;
        assert!(
            (post - 2.0 * solo).abs() / solo < 1e-6,
            "post-step {post} vs {}",
            2.0 * solo
        );
        // a kernel SPANNING the boundary pays a blended price
        let mut s2 = Simulator::new(
            GroundTruth::noiseless(GpuSpec::a100()).with_drift(DriftSpec {
                step_at_s: solo * 0.5,
                step_factor: 2.0,
                ..DriftSpec::none()
            }),
            1,
        );
        let st2 = s2.create_stream(SmMask::first(108), "y");
        s2.submit(st2, k);
        s2.run_until_idle();
        let span = s2.take_completions()[0].end;
        assert!(
            span > solo * 1.2 && span < solo * 2.0,
            "spanning kernel {span} vs solo {solo}"
        );
    }

    #[test]
    fn compute_drift_spares_memory_bound_kernels() {
        use crate::config::DriftSpec;
        // The co-tenant steals SM cycles: a memory-bound decode sweep is
        // HBM-limited and must be (near-)immune, while a compute-bound
        // GEMM pays the full factor — the phase asymmetry calibration
        // exists to learn.
        let drift = DriftSpec {
            step_at_s: 0.0,
            step_factor: 2.0,
            ..DriftSpec::none()
        };
        let clean = GroundTruth::noiseless(GpuSpec::a100());
        let drifted = clean.clone().with_drift(drift);
        let run = |gt: &GroundTruth, k: &KernelDesc| {
            let mut s = Simulator::new(gt.clone(), 1);
            let st = s.create_stream(SmMask::first(108), "x");
            s.submit(st, k.clone());
            s.run_until_idle();
            s.take_completions()[0].end
        };
        let mem = mem_kernel(4e9);
        assert!(
            (run(&drifted, &mem) - run(&clean, &mem)).abs() / run(&clean, &mem) < 1e-9,
            "memory-bound kernel must not feel an SM co-tenant"
        );
        let c = gemm(2e12);
        assert!(run(&drifted, &c) > run(&clean, &c) * 1.8);
    }

    #[test]
    fn lottery_varies_by_seed_and_is_reproducible() {
        use crate::config::DriftSpec;
        let gt = GroundTruth::noiseless(GpuSpec::a100()).with_drift(DriftSpec {
            lottery_sigma: 0.3,
            ..DriftSpec::none()
        });
        let run = |seed| {
            let mut s = Simulator::new(gt.clone(), seed);
            let st = s.create_stream(SmMask::first(108), "x");
            s.submit(st, gemm(1e12));
            s.run_until_idle();
            s.take_completions()[0].end
        };
        assert_eq!(run(5), run(5), "lottery must be seed-deterministic");
        let draws: Vec<f64> = (0..8).map(run).collect();
        let distinct = draws
            .windows(2)
            .any(|w| (w[0] - w[1]).abs() / w[0] > 1e-6);
        assert!(distinct, "device lottery produced identical devices: {draws:?}");
    }

    #[test]
    fn memo_off_is_bit_identical_across_drift_regimes() {
        use crate::config::DriftSpec;
        // Overlapping masks, launch noise, a mid-run remask, mixed
        // step/run_for driving — the memoized run must reproduce the
        // recompute-every-step run bit for bit under every regime.
        let regimes: [(&str, DriftSpec); 4] = [
            ("none", DriftSpec::none()),
            ("throttle", DriftSpec::throttle()),
            ("step", DriftSpec { step_at_s: 0.002, step_factor: 1.8, ..DriftSpec::none() }),
            ("storm", DriftSpec::storm()),
        ];
        for (label, drift) in regimes {
            let gt = GroundTruth::new(GpuSpec::a100()).with_drift(drift);
            let run = |memo: bool| {
                let mut s = Simulator::new(gt.clone(), 11);
                s.set_memo(memo);
                let a = s.create_stream(SmMask::first(72), "a");
                let b = s.create_stream(SmMask::last(54, 108), "b");
                for i in 0..6 {
                    s.submit(a, gemm(5e11 + i as f64 * 1e10));
                    s.submit(b, mem_kernel(2e9));
                }
                s.run_for(0.001);
                s.set_stream_mask(a, SmMask::first(54));
                s.run_until_idle();
                let ends: Vec<u64> =
                    s.take_completions().iter().map(|c| c.end.to_bits()).collect();
                let u = s.total_util();
                (
                    ends,
                    u.flops.to_bits(),
                    u.bytes.to_bits(),
                    u.sm_busy.to_bits(),
                    s.now().to_bits(),
                )
            };
            assert_eq!(run(true), run(false), "memo parity broke under drift regime {label}");
        }
    }

    #[test]
    fn rate_table_reused_between_completions() {
        let mut s = sim();
        let a = s.create_stream(SmMask::first(54), "a");
        let b = s.create_stream(SmMask::last(54, 108), "b");
        s.submit(a, gemm(2e12));
        s.submit(b, mem_kernel(4e9));
        // fine-grained slicing: many segments share one rate table
        for _ in 0..200 {
            s.run_for(1e-5);
        }
        s.run_until_idle();
        let c = s.rate_memo_counters();
        assert!(c.hits > c.misses, "expected steady-state reuse, got {c:?}");
        assert!(c.invalidations > 0, "completions must invalidate: {c:?}");
        // memo off: every refresh recomputes (counted as a miss)
        let mut s2 = sim();
        s2.set_memo(false);
        let st = s2.create_stream(SmMask::first(108), "x");
        s2.submit(st, gemm(1e12));
        for _ in 0..50 {
            s2.run_for(1e-5);
        }
        s2.run_until_idle();
        let c2 = s2.rate_memo_counters();
        assert_eq!(c2.hits, 0, "memo off must never hit: {c2:?}");
        assert!(c2.misses >= 50);
    }

    #[test]
    fn noise_reproducible_by_seed() {
        let gt = GroundTruth::new(GpuSpec::a100());
        let mut s1 = Simulator::new(gt.clone(), 99);
        let mut s2 = Simulator::new(gt, 99);
        for s in [&mut s1, &mut s2] {
            let st = s.create_stream(SmMask::first(108), "x");
            s.submit(st, gemm(1e12));
            s.run_until_idle();
        }
        let a = s1.take_completions()[0].end;
        let b = s2.take_completions()[0].end;
        assert_eq!(a, b);
    }

    #[test]
    fn ledger_conserves_and_routes_phases() {
        let mut s = sim();
        let p = s.create_stream(SmMask::first(54), "prefill");
        let d = s.create_stream(SmMask::last(54, 108), "decode");
        s.set_stream_phase(p, StreamPhase::Prefill);
        s.set_stream_phase(d, StreamPhase::Decode);
        s.submit(p, KernelDesc::new(OpClass::AttnPrefill, 2e12, 2e9, 54));
        s.submit(p, gemm(2e12));
        s.submit(d, mem_kernel(4e9));
        s.run_until_idle();
        let mut l = s.ledger();
        l.finalize(108.0 * s.now());
        assert!(l.prefill_compute > 0.0, "gemm on prefill stream: {l:?}");
        assert!(l.prefill_attention > 0.0, "attn-prefill op: {l:?}");
        assert!(l.decode > 0.0, "decode-phase stream: {l:?}");
        assert!(l.conserved(1e-9), "sum {} vs total {}", l.sum(), l.total);
    }

    #[test]
    fn wave_quantization_charged_when_tail_wave_exists() {
        // grid 1080 on 108 SMs: 10 exact waves, zero padding; grid 1081
        // spills one block into an 11th wave and pays Eq. 1's idle share.
        let mut s = sim();
        let st = s.create_stream(SmMask::first(108), "full");
        s.submit(st, KernelDesc::new(OpClass::GemmMlp, 4e12, 4e12 / 300.0, 1080));
        s.run_until_idle();
        assert_eq!(s.ledger().wave_quant, 0.0, "exact waves must pay nothing");
        let mut s2 = sim();
        let st2 = s2.create_stream(SmMask::first(108), "full");
        s2.submit(st2, KernelDesc::new(OpClass::GemmMlp, 4e12, 4e12 / 300.0, 1081));
        s2.run_until_idle();
        assert!(s2.ledger().wave_quant > 0.0, "tail wave must charge: {:?}", s2.ledger());
    }

    #[test]
    fn tagged_idle_accrues_and_free_idle_does_not() {
        let mut s = sim();
        s.run_for(0.25); // untagged idle: stays residual
        s.set_idle_tag(IdleTag::KvBlocked);
        s.run_for(0.5);
        s.set_idle_tag(IdleTag::Repartition);
        s.advance_idle_to(1.0);
        s.set_idle_tag(IdleTag::Free);
        s.advance_idle_to(1.5);
        let l = s.ledger();
        assert!((l.kv_blocked - 0.5 * 108.0).abs() < 1e-9, "{l:?}");
        assert!((l.repartition - 0.25 * 108.0).abs() < 1e-9, "{l:?}");
        assert_eq!(l.accrued(), l.kv_blocked + l.repartition);
    }

    #[test]
    fn ledger_is_bit_identical_across_memo_settings() {
        let run = |memo: bool| {
            let mut s = Simulator::new(GroundTruth::new(GpuSpec::a100()), 7);
            s.set_memo(memo);
            let a = s.create_stream(SmMask::first(60), "a");
            let b = s.create_stream(SmMask::last(48, 108), "b");
            s.set_stream_phase(a, StreamPhase::Prefill);
            s.set_stream_phase(b, StreamPhase::Decode);
            for _ in 0..4 {
                s.submit(a, gemm(2e12));
                s.submit(b, mem_kernel(2e9));
            }
            for _ in 0..100 {
                s.run_for(1e-4);
            }
            s.run_until_idle();
            let mut l = s.ledger();
            l.finalize(108.0 * s.now());
            l.to_bits()
        };
        assert_eq!(run(true), run(false));
    }
}
