//! Ground-truth kernel timing: the "silicon" of the simulated A100.
//!
//! Solo (uncontended) kernel time follows an extended roofline:
//!
//! ```text
//! t = max( flops / (C·ceil_c·eff_c(r)),  bytes / (B·ceil_b·eff_b(r)) )
//!       · wave_slowdown(grid, m)  [compute term only]
//!       + launch_overhead
//! ```
//!
//! where `r = m/M` is the SM fraction, `ceil_*` are per-op-class achieved
//! ceilings (MLP GEMMs reach ~92% of peak, PagedAttention-style kernels
//! far less — §2.2.3), and `eff_*` are the *nonlinear* partial-SM scaling
//! curves of Fig. 7: compute scales slightly sub-linearly, bandwidth
//! saturates (a half-GPU partition still draws ~80% of HBM bandwidth).
//!
//! These constants are the simulator's hidden ground truth.  The
//! performance estimator (`perf::`) must *fit* its simpler Eq. 2 model to
//! profiles of this module — mirroring the paper's analytical-model-plus-
//! profiling methodology, and giving Fig. 15 a non-vacuous error to show.

use crate::config::{DriftSpec, GpuSpec};
use crate::gpu::kernel::{KernelDesc, OpClass};
use crate::gpu::wave::wave_slowdown;

/// Per-op-class ground-truth scaling parameters.
#[derive(Debug, Clone, Copy)]
pub struct ClassParams {
    /// Fraction of peak FLOPs this class can achieve at best.
    pub ceil_c: f64,
    /// Fraction of peak bandwidth this class can achieve at best.
    pub ceil_b: f64,
    /// Compute partial-SM exponent: eff_c(r) = r^alpha (alpha >= 1 ⇒
    /// sub-linear speedup for compute-bound kernels, Fig. 7).
    pub alpha_c: f64,
    /// Bandwidth saturation constant: eff_b(r) = r(1+k)/(rk+1)
    /// (k > 0 ⇒ super-linear speedup for memory-bound kernels, Fig. 7).
    pub sat_b: f64,
}

/// Ground-truth timing model over a [`GpuSpec`].
#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub gpu: GpuSpec,
    /// Lognormal noise sigma applied per kernel launch (0 disables).
    pub noise_sigma: f64,
    /// Run-correlated noise sigma: one lognormal factor drawn per
    /// simulator instance and applied to every kernel in that run —
    /// models clock/thermal/co-tenant drift that per-kernel noise
    /// averages out but real deployments do not (the dominant source of
    /// the paper's ~19% estimator error).
    pub run_noise_sigma: f64,
    /// Non-stationary regime (throttling / step interference / device
    /// lottery).  `DriftSpec::none()` by default: the time-varying
    /// slowdown factor is then exactly 1.0 and every run is
    /// bit-identical to a drift-unaware simulator.
    pub drift: DriftSpec,
}

impl GroundTruth {
    pub fn new(gpu: GpuSpec) -> GroundTruth {
        GroundTruth {
            gpu,
            noise_sigma: 0.03,
            run_noise_sigma: 0.10,
            drift: DriftSpec::none(),
        }
    }

    /// Noise-free variant (profiling tests, property tests).
    pub fn noiseless(gpu: GpuSpec) -> GroundTruth {
        GroundTruth {
            gpu,
            noise_sigma: 0.0,
            run_noise_sigma: 0.0,
            drift: DriftSpec::none(),
        }
    }

    /// Attach a drift regime (builder style, for deployment-time GTs
    /// that diverge from the clean GT the profiler saw).
    pub fn with_drift(mut self, drift: DriftSpec) -> GroundTruth {
        self.drift = drift;
        self
    }

    /// Hidden per-class constants (the estimator never reads these).
    pub fn class_params(op: OpClass) -> ClassParams {
        match op {
            // Big square-ish GEMMs: near-peak compute, mild sub-linearity.
            OpClass::GemmMlp => ClassParams {
                ceil_c: 0.92,
                ceil_b: 0.85,
                alpha_c: 1.04,
                sat_b: 1.2,
            },
            OpClass::GemmQkv => ClassParams {
                ceil_c: 0.88,
                ceil_b: 0.85,
                alpha_c: 1.05,
                sat_b: 1.2,
            },
            OpClass::GemmOProj => ClassParams {
                ceil_c: 0.86,
                ceil_b: 0.85,
                alpha_c: 1.05,
                sat_b: 1.2,
            },
            // FlashAttention with paged KV: irregular access keeps the
            // achieved compute ceiling low (§2.2.3: attention sustains
            // much less than linear layers).
            OpClass::AttnPrefill => ClassParams {
                ceil_c: 0.62,
                ceil_b: 0.80,
                alpha_c: 1.10,
                sat_b: 1.6,
            },
            // Decode attention: pure KV-cache bandwidth sweep.
            OpClass::AttnDecode => ClassParams {
                ceil_c: 0.30,
                ceil_b: 0.88,
                alpha_c: 1.00,
                sat_b: 3.5,
            },
            // Skinny decode GEMMs: weight-streaming, memory-bound.
            OpClass::GemmDecode => ClassParams {
                ceil_c: 0.55,
                ceil_b: 0.90,
                alpha_c: 1.00,
                sat_b: 3.0,
            },
            OpClass::Elementwise => ClassParams {
                ceil_c: 0.10,
                ceil_b: 0.92,
                alpha_c: 1.00,
                sat_b: 2.5,
            },
        }
    }

    /// Compute-term time on `sms` SMs (wave quantization included).
    pub fn compute_time(&self, k: &KernelDesc, sms: usize) -> f64 {
        if k.flops <= 0.0 || sms == 0 {
            return 0.0;
        }
        let p = Self::class_params(k.op);
        let r = sms as f64 / self.gpu.num_sms as f64;
        let eff = r.powf(p.alpha_c);
        let base = k.flops / (self.gpu.peak_flops * p.ceil_c * eff);
        base * wave_slowdown(k.grid, sms)
    }

    /// Memory-term time on `sms` SMs.
    pub fn memory_time(&self, k: &KernelDesc, sms: usize) -> f64 {
        if k.bytes <= 0.0 || sms == 0 {
            return 0.0;
        }
        let p = Self::class_params(k.op);
        let r = sms as f64 / self.gpu.num_sms as f64;
        let eff = r * (1.0 + p.sat_b) / (r * p.sat_b + 1.0);
        k.bytes / (self.gpu.peak_bandwidth * p.ceil_b * eff)
    }

    /// Solo (uncontended) duration on `sms` SMs, noise-free.
    pub fn solo_time(&self, k: &KernelDesc, sms: usize) -> f64 {
        if sms == 0 {
            return f64::INFINITY;
        }
        self.compute_time(k, sms).max(self.memory_time(k, sms)) + self.gpu.launch_overhead
    }

    /// Fraction of the solo time that is memory-bound (0 = pure compute).
    pub fn memory_boundness(&self, k: &KernelDesc, sms: usize) -> f64 {
        let tc = self.compute_time(k, sms);
        let tb = self.memory_time(k, sms);
        let t = tc.max(tb);
        if t <= 0.0 {
            0.0
        } else {
            tb / t
        }
    }

    /// Achieved-vs-peak compute utilization of a kernel running alone on
    /// `sms` SMs (normalized to the WHOLE GPU's peak — Fig. 2's y-axis).
    pub fn solo_compute_utilization(&self, k: &KernelDesc, sms: usize) -> f64 {
        let t = self.solo_time(k, sms);
        if t <= 0.0 {
            return 0.0;
        }
        k.flops / t / self.gpu.peak_flops
    }

    /// Achieved-vs-peak bandwidth utilization (whole-GPU normalization).
    pub fn solo_bandwidth_utilization(&self, k: &KernelDesc, sms: usize) -> f64 {
        let t = self.solo_time(k, sms);
        if t <= 0.0 {
            return 0.0;
        }
        k.bytes / t / self.gpu.peak_bandwidth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm(flops: f64, bytes: f64, grid: usize) -> KernelDesc {
        KernelDesc::new(OpClass::GemmMlp, flops, bytes, grid)
    }

    fn decode_attn(bytes: f64) -> KernelDesc {
        KernelDesc::new(OpClass::AttnDecode, bytes * 2.0, bytes, 64)
    }

    #[test]
    fn full_gpu_gemm_near_ceiling() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        // Large MLP GEMM, grid a multiple of 108 → no wave quantization.
        let k = gemm(4e12, 4e9, 1080);
        let util = gt.solo_compute_utilization(&k, 108);
        assert!(util > 0.85 && util <= 0.92, "util {util}");
    }

    #[test]
    fn compute_sublinear_scaling() {
        // Fig. 7: compute-bound speedup below linear.
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let k = gemm(4e12, 4e9, 1080);
        let t_full = gt.solo_time(&k, 108);
        let t_half = gt.solo_time(&k, 54);
        let speedup = t_full / t_half; // relative throughput at half SMs
        assert!(speedup < 0.5, "speedup {speedup} not sub-linear");
        assert!(speedup > 0.40);
    }

    #[test]
    fn memory_superlinear_scaling() {
        // Fig. 7: memory-bound speedup above linear.
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let k = decode_attn(4e9);
        let t_full = gt.solo_time(&k, 108);
        let t_half = gt.solo_time(&k, 54);
        let speedup = t_full / t_half;
        assert!(speedup > 0.5, "speedup {speedup} not super-linear");
        assert!(speedup < 1.0);
    }

    #[test]
    fn wave_quantization_slows_compute() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let aligned = gemm(4e12, 1e9, 108 * 4);
        let misaligned = gemm(4e12, 1e9, 108 * 3 + 1); // 4 waves, tail of 1
        let ta = gt.solo_time(&aligned, 108);
        let tm = gt.solo_time(&misaligned, 108);
        assert!(tm > ta * 1.2, "ta {ta} tm {tm}");
    }

    #[test]
    fn memory_boundness_classification() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let c = gemm(4e12, 1e8, 1080);
        let m = decode_attn(4e9);
        assert!(gt.memory_boundness(&c, 108) < 0.2);
        assert!(gt.memory_boundness(&m, 108) > 0.9);
    }

    #[test]
    fn zero_sms_is_infinite() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        assert!(gt.solo_time(&gemm(1e12, 1e9, 100), 0).is_infinite());
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let k = gemm(1.0, 1.0, 1);
        assert!(gt.solo_time(&k, 108) >= gt.gpu.launch_overhead);
    }

    #[test]
    fn bandwidth_utilization_bounded() {
        let gt = GroundTruth::noiseless(GpuSpec::a100());
        let k = decode_attn(8e9);
        let u = gt.solo_bandwidth_utilization(&k, 108);
        assert!(u > 0.5 && u <= 0.88 + 1e-9, "{u}");
    }
}
