//! GPU substrate: an A100-like simulator with SM-masked streams.
//!
//! The paper's testbed — an A100 with MPS plus `libsmctrl` SM masking —
//! does not exist in this environment, so we build it: a fluid
//! discrete-event simulator in which kernels are (flops, bytes, grid)
//! descriptors, streams serialize their kernels, SM masks restrict where
//! a kernel's thread blocks may run, wave quantization (Eq. 1) idles tail
//! SMs, partial-SM scaling follows the saturating curves of Fig. 7, and
//! co-resident kernels contend for HBM bandwidth and shared SMs.
//!
//! Everything the Bullet scheduler observes (per-layer latencies under a
//! given partition, utilization counters) comes out of this module; the
//! performance *estimator* (`perf::`) never reads the simulator's ground
//! truth constants — it must fit them by profiling, exactly as §3.2.2.

pub mod kernel;
pub mod roofline;
pub mod simulator;
pub mod stream;
pub mod wave;

pub use kernel::{KernelDesc, OpClass};
pub use simulator::{Simulator, UtilSample};
pub use stream::{SmMask, StreamId};
pub use wave::wave_quantization_idle_ratio;
