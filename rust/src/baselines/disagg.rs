//! Intra-GPU prefill/decode disaggregation baselines: the strongest
//! published competitors to Bullet's spatial-temporal sharing (§2.3.2,
//! PAPERS.md — RAPID-Serve, Nexus, prefill-decode multiplexing).
//!
//! All three share Bullet's decoupled two-lane execution on the shared
//! serving core — prefill and decode run concurrently on SM-masked
//! streams — but differ in how the SM boundary between the phases is
//! chosen:
//!
//! - [`StaticSplitPolicy`] (RAPID-Serve style): one fixed disjoint
//!   partition for the whole run, the split ratio a config knob
//!   (`ServingConfig::pd_split`, CLI `--pd-split R`).  Zero decision
//!   overhead, but any phase-mix shift strands SMs on the quiet side.
//! - [`ProactiveSplitPolicy`] (Nexus style): repartitions *ahead* of
//!   the predicted phase mix — at every planning boundary it prices the
//!   queued-but-unlaunched prefill work against the resident decode
//!   batch's remaining work through the same [`PerfPredictor`] the
//!   Bullet scheduler uses (an [`OnlineCalibrator`], so `--calibration
//!   on` applies to the competitor too) and moves the boundary toward
//!   the phase that is about to need it.  Unlike Bullet it knows only
//!   the phase mix, not per-request SLO slack, and it never pauses
//!   decode.
//! - [`TemporalMuxPolicy`]: time-sliced alternation — whole-prompt
//!   all-SM prefill epochs alternate with bounded all-SM decode epochs,
//!   and the phases NEVER co-schedule.  No SM is ever idle while the
//!   active phase runs, but each phase's latency absorbs the other's
//!   epoch (TTFT waits out decode epochs, TPOT waits out prompts).
//!
//! Riding the shared core means prefix caching, lifecycle/cancellation
//! and hot-path memoization compose with every policy here for free,
//! so evaluation differences are *decisions only*.

use crate::config::ServingConfig;
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, Lane, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::model::phases::{decode_all_layers, prefill_layer_kernels, PhaseShape};
use crate::perf::{OnlineCalibrator, PerfModel, PerfPredictor};
use crate::resource::Partition;
use crate::sched::{PrefillBatch, PrefillReq};
use crate::workload::Request;

/// The fixed disjoint P/D partition `cfg.pd_split` asks for: the
/// prefill share of the GPU, clamped into
/// `[min_prefill_sms, num_sms - min_decode_sms]` and quantized to the
/// mask granularity.
pub fn split_partition(cfg: &ServingConfig) -> Partition {
    let sms = cfg.gpu.num_sms;
    let frac = if cfg.pd_split.is_finite() { cfg.pd_split.clamp(0.0, 1.0) } else { 0.5 };
    let lo = cfg.min_prefill_sms.min(sms);
    let hi = sms.saturating_sub(cfg.min_decode_sms).max(lo);
    let pm = ((frac * sms as f64).round() as usize).clamp(lo, hi);
    Partition::split(&cfg.gpu, pm)
}

/// Whole-prompt FCFS prefill admission, shared by the disaggregation
/// policies: KV-reserved (input + output minus the prefix-cached
/// prefix), TTFT-first batching under `prefill_batch_tokens` — the same
/// admission contract as the Bullet engine, minus the SLO-slack
/// reorder.  Panics loudly when the head request can never fit (nothing
/// in flight could free the pool), like every other engine here.
fn form_prefill_batch(core: &mut EngineCore) -> Option<PrefillBatch> {
    if core.waiting.is_empty() {
        return None;
    }
    let now = core.now();
    let mut batch_reqs: Vec<PrefillReq> = Vec::new();
    let mut tokens = 0usize;
    let mut i = 0;
    while i < core.waiting.len() {
        let r = core.waiting[i].req.clone();
        // charge only the uncached suffix (prefix-cache adoption)
        let suffix = r.input_len - r.cached_len;
        let reserve = r.input_len + r.output_len - r.cached_len;
        let fits_policy =
            batch_reqs.is_empty() || tokens + suffix <= core.cfg.prefill_batch_tokens;
        if fits_policy && tokens + suffix <= core.cfg.max_prefill_tokens && core.kv_room(r.id, reserve)
        {
            core.kv.grow(r.id, reserve).expect("kv reserve");
            tokens += suffix;
            core.waiting.remove(i);
            batch_reqs.push(r);
        } else if batch_reqs.is_empty() && core.decode.is_empty() && core.pending_join.is_empty() {
            // nothing running that could free memory (and `kv_room`
            // already evicted every reclaimable cached block)
            panic!(
                "request {} needs {} KV tokens but pool holds {}",
                r.id,
                reserve,
                core.kv.capacity_tokens()
            );
        } else {
            i += 1;
        }
    }
    if batch_reqs.is_empty() {
        None
    } else {
        Some(PrefillBatch::new(batch_reqs, now))
    }
}

/// Launch one decode iteration over the resident batch on `stream`'s
/// SMs; returns `(bs, cl)` for callers that record launch shapes.
fn launch_decode_iteration(core: &mut EngineCore, stream_sms: Option<usize>) -> (usize, usize) {
    let bs = core.decode.len();
    let cl = (core.decode.iter().map(|d| d.st.ctx_len).sum::<usize>() / bs).max(1);
    let kernels = decode_all_layers(&core.cfg.model, PhaseShape { tokens: bs, context: cl });
    let stream = match stream_sms {
        Some(sms) => core.rm.decode_stream_for(sms),
        None => core.rm.decode_stream(),
    };
    core.submit(Lane::Decode, stream, kernels);
    (bs, cl)
}

/// Kernels for `layers` prefill layers of the active batch's shape.
fn prefill_layers_kernels(
    core: &EngineCore,
    b: &PrefillBatch,
    layers: usize,
) -> Vec<crate::gpu::kernel::KernelDesc> {
    let shape = PhaseShape { tokens: b.n_tokens, context: b.ctx_cached };
    let mut kernels = Vec::new();
    for _ in 0..layers {
        kernels.extend(prefill_layer_kernels(&core.cfg.model, shape));
    }
    kernels
}

// ---------------------------------------------------------------------------
// Static split (RAPID-Serve style)
// ---------------------------------------------------------------------------

/// Fixed prefill/decode SM partition: the boundary is chosen once from
/// `cfg.pd_split` and never moves.  Both lanes run concurrently on
/// their disjoint masks; prompts prefill whole (all layers in one
/// launch — with a frozen partition there is no decision to revisit at
/// group boundaries).
pub struct StaticSplitPolicy {
    split: Partition,
    applied: bool,
    active_prefill: Option<PrefillBatch>,
}

impl StaticSplitPolicy {
    pub fn new(cfg: &ServingConfig) -> StaticSplitPolicy {
        StaticSplitPolicy {
            split: split_partition(cfg),
            applied: false,
            active_prefill: None,
        }
    }

    /// The partition this policy pins (test/observability hook).
    pub fn partition(&self) -> Partition {
        self.split
    }

    fn prefill_cycle(&mut self, core: &mut EngineCore) {
        let total = core.cfg.model.n_layers;
        if self
            .active_prefill
            .as_ref()
            .map(|b| b.layers_done >= total)
            .unwrap_or(false)
        {
            let b = self.active_prefill.take().unwrap();
            for r in &b.reqs {
                core.finish_prefill(r.clone(), b.started_at);
            }
        }
        if self.active_prefill.is_none() {
            self.active_prefill = form_prefill_batch(core);
        }
        if let Some(b) = &self.active_prefill {
            core.sample_timeline(b.n_tokens);
            let kernels = prefill_layers_kernels(core, b, total - b.layers_done);
            let stream = core.rm.prefill_stream();
            core.submit(Lane::Prefill, stream, kernels);
        }
    }
}

impl ServingPolicy for StaticSplitPolicy {
    fn label(&self) -> String {
        "Static-Split".into()
    }

    fn plan(&mut self, core: &mut EngineCore) {
        if !self.applied {
            // the one and only reconfiguration (a no-op when the knob
            // matches the resource manager's initial 50/50 split)
            core.rm.reconfigure(self.split);
            self.applied = true;
        }
        if core.lane_idle(Lane::Prefill) {
            self.prefill_cycle(core);
        }
        if core.lane_idle(Lane::Decode) {
            core.join_pending(core.cfg.max_decode_batch);
            if !core.decode.is_empty() {
                launch_decode_iteration(core, None);
            }
        }
    }

    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
        match lane {
            Lane::Prefill => {
                if let Some(b) = &mut self.active_prefill {
                    b.layers_done = core.cfg.model.n_layers;
                }
            }
            Lane::Decode => core.advance_decode_token(),
        }
    }

    fn has_private_work(&self) -> bool {
        self.active_prefill.is_some()
    }

    fn private_backlog_tokens(&self) -> usize {
        self.active_prefill.as_ref().map(|b| b.n_tokens).unwrap_or(0)
    }

    fn probe_prefill_sms(&self) -> Option<usize> {
        Some(self.split.prefill_sms)
    }
}

// ---------------------------------------------------------------------------
// Proactive split (Nexus style)
// ---------------------------------------------------------------------------

/// Prefill launch shape in flight, replayed at the drain boundary as a
/// calibration sample (mirrors the Bullet policy's feedback loop).
#[derive(Debug, Clone, Copy)]
struct PrefillShape {
    sl: usize,
    ctx: usize,
    pm: usize,
    contended: bool,
    layers: usize,
}

/// Decode launch shape in flight.
#[derive(Debug, Clone, Copy)]
struct DecodeShape {
    bs: usize,
    cl: usize,
    dm: usize,
    contended: bool,
}

/// Nexus-style proactive repartitioning: at every planning boundary the
/// policy predicts the *imminent* phase mix — queued-but-unlaunched
/// prefill work versus the decode batch's remaining work, both priced
/// in full-GPU seconds by the shared [`PerfPredictor`] — and moves the
/// SM boundary toward the phase that is about to need it, before that
/// phase's kernels launch.  Prefill runs in layer groups (like Bullet)
/// so mid-prompt group boundaries can pick the move up.
pub struct ProactiveSplitPolicy {
    perf: OnlineCalibrator,
    current: Partition,
    active_prefill: Option<PrefillBatch>,
    group_size: usize,
    prefill_launch: Option<PrefillShape>,
    decode_launch: Option<DecodeShape>,
}

impl ProactiveSplitPolicy {
    pub fn new(cfg: &ServingConfig, perf: &PerfModel) -> ProactiveSplitPolicy {
        let mut calibrator = OnlineCalibrator::new(perf.clone(), cfg.calibration.clone());
        calibrator.set_memo(cfg.memo);
        ProactiveSplitPolicy {
            perf: calibrator,
            current: split_partition(cfg),
            active_prefill: None,
            group_size: 0,
            prefill_launch: None,
            decode_launch: None,
        }
    }

    /// Predicted prefill share of the imminent phase mix, in [0, 1]:
    /// full-GPU seconds of pending prefill work (queue + active batch
    /// remainder — work that has not run yet, which is what makes the
    /// split *proactive*) over total pending work, with the decode side
    /// priced as the resident batch's mean remaining tokens.
    pub fn phase_mix_share(&self, core: &EngineCore) -> f64 {
        let sms = core.cfg.gpu.num_sms;
        let total_layers = core.cfg.model.n_layers;
        let queued: usize = core
            .waiting
            .iter()
            .map(|w| (w.req.input_len - w.req.cached_len).saturating_sub(w.done))
            .sum();
        let active = self
            .active_prefill
            .as_ref()
            .map(|b| b.n_tokens * total_layers.saturating_sub(b.layers_done) / total_layers.max(1))
            .unwrap_or(0);
        let prefill_tokens = queued + active;
        let prefill_work = if prefill_tokens == 0 {
            0.0
        } else {
            self.perf
                .predict_prefill_remaining(prefill_tokens, 0, sms, total_layers, false)
        };
        let decode_members = core.decode.iter().chain(core.pending_join.iter());
        let (mut bs, mut remaining, mut ctx) = (0usize, 0usize, 0usize);
        for d in decode_members {
            bs += 1;
            remaining += d.st.output_len.saturating_sub(d.st.tokens_out);
            ctx += d.st.ctx_len;
        }
        let decode_work = if bs == 0 || remaining == 0 {
            0.0
        } else {
            let cl = (ctx / bs).max(1);
            let steps = (remaining as f64 / bs as f64).ceil();
            self.perf.predict_decode_step(bs, cl, sms, false) * steps
        };
        if prefill_work + decode_work <= 0.0 {
            0.0
        } else {
            prefill_work / (prefill_work + decode_work)
        }
    }

    /// The partition the predicted phase mix asks for (clamped and
    /// quantized like [`split_partition`]).
    pub fn target_partition(&self, core: &EngineCore) -> Partition {
        let cfg = &core.cfg;
        let sms = cfg.gpu.num_sms;
        let lo = cfg.min_prefill_sms.min(sms);
        let hi = sms.saturating_sub(cfg.min_decode_sms).max(lo);
        let pm = ((self.phase_mix_share(core) * sms as f64).round() as usize).clamp(lo, hi);
        Partition::split(&cfg.gpu, pm)
    }

    fn prefill_cycle(&mut self, core: &mut EngineCore) {
        let total = core.cfg.model.n_layers;
        if self
            .active_prefill
            .as_ref()
            .map(|b| b.layers_done >= total)
            .unwrap_or(false)
        {
            let b = self.active_prefill.take().unwrap();
            for r in &b.reqs {
                core.finish_prefill(r.clone(), b.started_at);
            }
        }
        if self.active_prefill.is_none() {
            self.active_prefill = form_prefill_batch(core);
        }
        if let Some(b) = &self.active_prefill {
            core.sample_timeline(b.n_tokens);
            let layers = core
                .cfg
                .prefill_layer_group
                .max(1)
                .min(total - b.layers_done);
            let kernels = prefill_layers_kernels(core, b, layers);
            let stream = core.rm.prefill_stream();
            let (sl, ctx) = (b.n_tokens, b.ctx_cached);
            core.submit(Lane::Prefill, stream, kernels);
            self.group_size = layers;
            self.prefill_launch = Some(PrefillShape {
                sl,
                ctx,
                pm: core.rm.partition().prefill_sms,
                contended: !core.decode.is_empty(),
                layers,
            });
        }
    }
}

impl ServingPolicy for ProactiveSplitPolicy {
    fn label(&self) -> String {
        "Proactive-Split".into()
    }

    fn plan(&mut self, core: &mut EngineCore) {
        // Repartition AHEAD of the predicted mix, before either lane
        // launches.  In-flight kernels keep their old masks until they
        // drain (the §3.4.2 transition-overlap semantics Bullet also
        // uses); `reconfigure` counts only actual moves.
        let target = self.target_partition(core);
        core.rm.reconfigure(target);
        self.current = core.rm.partition();
        if core.lane_idle(Lane::Prefill) {
            self.prefill_cycle(core);
        }
        if core.lane_idle(Lane::Decode) {
            core.join_pending(core.cfg.max_decode_batch);
            if !core.decode.is_empty() {
                let contended = self.active_prefill.is_some();
                let (bs, cl) = launch_decode_iteration(core, None);
                self.decode_launch = Some(DecodeShape {
                    bs,
                    cl,
                    dm: core.rm.partition().decode_sms,
                    contended,
                });
            }
        }
        core.stats.predict_memo = self.perf.memo_counters();
    }

    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
        // Close the calibration loop exactly like the Bullet policy:
        // the drain instant gives the observed duration of the shape
        // recorded at launch (no-op samples with calibration off).
        match lane {
            Lane::Prefill => {
                if let Some(l) = self.prefill_launch.take() {
                    let observed = core.lane_busy_span(Lane::Prefill);
                    let fed = self
                        .perf
                        .observe_prefill(l.sl, l.ctx, l.pm, l.contended, l.layers, observed);
                    if fed.is_some() {
                        core.note_calibration(self.perf.stats());
                    }
                }
                if let Some(b) = &mut self.active_prefill {
                    b.layers_done += self.group_size;
                }
            }
            Lane::Decode => {
                if let Some(l) = self.decode_launch.take() {
                    let observed = core.lane_busy_span(Lane::Decode);
                    let fed = self.perf.observe_decode(l.bs, l.cl, l.dm, l.contended, observed);
                    if fed.is_some() {
                        core.note_calibration(self.perf.stats());
                    }
                }
                core.advance_decode_token();
            }
        }
        core.stats.predict_memo = self.perf.memo_counters();
    }

    fn has_private_work(&self) -> bool {
        self.active_prefill.is_some()
    }

    fn private_backlog_tokens(&self) -> usize {
        self.active_prefill.as_ref().map(|b| b.n_tokens).unwrap_or(0)
    }

    fn predictor(&self) -> Option<&dyn PerfPredictor> {
        Some(&self.perf)
    }

    fn reprofile(&mut self) -> bool {
        if !self.perf.enabled() {
            return false;
        }
        self.perf.reprofile();
        true
    }

    fn probe_prefill_sms(&self) -> Option<usize> {
        Some(self.current.prefill_sms)
    }
}

// ---------------------------------------------------------------------------
// Temporal multiplexing
// ---------------------------------------------------------------------------

/// Time-sliced P/D alternation: whole-prompt all-SM prefill epochs
/// alternate with decode epochs of `cfg.decode_epoch_iters` iterations
/// (CLI `--decode-epoch N`), and the two phases never run concurrently
/// (plans only when ALL lanes are idle, and launches at most one lane
/// per plan).  Small epochs favor TTFT, large epochs favor TPOT — the
/// sweep test below pins that trade-off down.
pub struct TemporalMuxPolicy {
    active_prefill: Option<PrefillBatch>,
    /// Decode iterations per epoch (`cfg.decode_epoch_iters`, >= 1).
    epoch_iters: usize,
    /// Decode iterations left in the current decode epoch.
    decode_epoch_left: usize,
}

impl TemporalMuxPolicy {
    pub fn new(cfg: &ServingConfig) -> TemporalMuxPolicy {
        TemporalMuxPolicy {
            active_prefill: None,
            epoch_iters: cfg.decode_epoch_iters.max(1),
            decode_epoch_left: 0,
        }
    }
}

impl ServingPolicy for TemporalMuxPolicy {
    fn label(&self) -> String {
        "Temporal-Mux".into()
    }

    fn plan(&mut self, core: &mut EngineCore) {
        if !core.all_idle() {
            return; // strict temporal multiplexing: one phase at a time
        }
        let total = core.cfg.model.n_layers;
        if self
            .active_prefill
            .as_ref()
            .map(|b| b.layers_done >= total)
            .unwrap_or(false)
        {
            let b = self.active_prefill.take().unwrap();
            for r in &b.reqs {
                core.finish_prefill(r.clone(), b.started_at);
            }
            // a finished prefill epoch hands the GPU to decode
            self.decode_epoch_left = self.epoch_iters;
        }
        core.join_pending(core.cfg.max_decode_batch);
        let sms = core.cfg.gpu.num_sms;
        let prefill_pending = self.active_prefill.is_some() || !core.waiting.is_empty();
        // Decode epoch: consume the budget, or run freely while no
        // prefill is pending.
        if !core.decode.is_empty() && (self.decode_epoch_left > 0 || !prefill_pending) {
            if self.decode_epoch_left == 0 {
                self.decode_epoch_left = self.epoch_iters;
            }
            launch_decode_iteration(core, Some(sms));
            self.decode_epoch_left -= 1;
            return;
        }
        // Prefill epoch: one whole-prompt batch on every SM.
        if self.active_prefill.is_none() {
            self.active_prefill = form_prefill_batch(core);
        }
        if let Some(b) = &self.active_prefill {
            core.sample_timeline(b.n_tokens);
            let kernels = prefill_layers_kernels(core, b, total - b.layers_done);
            let stream = core.rm.prefill_stream_for(sms);
            core.submit(Lane::Prefill, stream, kernels);
            return;
        }
        // Admission blocked on KV: let decode run another epoch to
        // drain the pool (it is the only thing that can free blocks).
        if !core.decode.is_empty() {
            self.decode_epoch_left = self.epoch_iters - 1;
            launch_decode_iteration(core, Some(sms));
        }
    }

    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
        match lane {
            Lane::Prefill => {
                if let Some(b) = &mut self.active_prefill {
                    b.layers_done = core.cfg.model.n_layers;
                }
            }
            Lane::Decode => core.advance_decode_token(),
        }
    }

    fn has_private_work(&self) -> bool {
        self.active_prefill.is_some()
    }

    fn private_backlog_tokens(&self) -> usize {
        self.active_prefill.as_ref().map(|b| b.n_tokens).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Serve wrappers
// ---------------------------------------------------------------------------

/// Serve `trace` under a fixed P/D SM split (`cfg.pd_split`).
pub fn serve_static_split(
    cfg: &ServingConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let opts = CoreOptions { seed, ..CoreOptions::default() };
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts);
    let mut policy = StaticSplitPolicy::new(cfg);
    core.run(&mut policy);
    core.into_output()
}

/// Serve `trace` under Nexus-style proactive P/D repartitioning.
pub fn serve_proactive_split(
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let opts = CoreOptions { seed, ..CoreOptions::default() };
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts);
    let mut policy = ProactiveSplitPolicy::new(cfg, perf);
    core.run(&mut policy);
    core.into_output()
}

/// Serve `trace` under time-sliced P/D multiplexing.
pub fn serve_temporal_mux(
    cfg: &ServingConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let opts = CoreOptions { seed, ..CoreOptions::default() };
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts);
    let mut policy = TemporalMuxPolicy::new(cfg);
    core.run(&mut policy);
    core.into_output()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::workload::{generate_bursty_trace, generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let gt = GroundTruth::new(GpuSpec::a100());
        let perf = PerfModel::analytical(cfg.gpu.clone(), cfg.model.clone());
        (cfg, perf, gt)
    }

    #[test]
    fn split_partition_clamps_and_quantizes() {
        let cfg = ServingConfig::default();
        let p = split_partition(&cfg);
        assert_eq!(p.prefill_sms, 54); // 0.5 of 108
        assert_eq!(p.decode_sms, 54);
        let quarter = ServingConfig { pd_split: 0.25, ..ServingConfig::default() };
        assert_eq!(split_partition(&quarter).prefill_sms, 26); // 27 quantized down
        let zero = ServingConfig { pd_split: 0.0, ..ServingConfig::default() };
        assert_eq!(split_partition(&zero).prefill_sms, 24); // min_prefill_sms floor
        let one = ServingConfig { pd_split: 1.0, ..ServingConfig::default() };
        assert_eq!(split_partition(&one).prefill_sms, 96); // num_sms - min_decode_sms
        let nan = ServingConfig { pd_split: f64::NAN, ..ServingConfig::default() };
        assert_eq!(split_partition(&nan).prefill_sms, 54); // NaN falls back to 0.5
    }

    #[test]
    fn static_split_serves_all_and_never_repartitions() {
        let (cfg, _, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 25, 17);
        let mut core = EngineCore::new(cfg.clone(), gt, trace, &CoreOptions::default());
        let mut policy = StaticSplitPolicy::new(&cfg);
        core.run(&mut policy);
        let expected = policy.partition();
        assert_eq!(core.rm.partition(), expected, "partition pinned for the whole run");
        // the one initial reconfigure is a no-op at the default 50/50
        assert_eq!(core.rm.reconfig_count(), 0, "static split must never move");
        let out = core.into_output();
        assert_eq!(out.records.len(), 25);
    }

    #[test]
    fn static_split_honors_pd_split_knob() {
        let (cfg, _, gt) = setup();
        let cfg = ServingConfig { pd_split: 0.75, ..cfg };
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 10, 17);
        let mut core = EngineCore::new(cfg.clone(), gt, trace, &CoreOptions::default());
        let mut policy = StaticSplitPolicy::new(&cfg);
        core.run(&mut policy);
        assert_eq!(core.rm.partition().prefill_sms, 80); // 81 quantized down
        assert_eq!(core.rm.reconfig_count(), 1, "one move from the initial 50/50, then pinned");
    }

    #[test]
    fn static_split_is_deterministic() {
        let (cfg, _, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 29);
        let a = serve_static_split(&cfg, &gt, &trace, 3);
        let b = serve_static_split(&cfg, &gt, &trace, 3);
        assert_eq!(a.records, b.records);
        assert_eq!(a.reconfigs, b.reconfigs);
    }

    #[test]
    fn proactive_split_tracks_the_phase_mix_estimate() {
        let (cfg, perf, gt) = setup();
        // all-prefill pending state: the target must sit at the prefill
        // ceiling (num_sms - min_decode_sms)
        let trace = generate_n_requests(&Dataset::azure_code(), 50.0, 8, 5);
        let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace, &CoreOptions::default());
        let mut policy = ProactiveSplitPolicy::new(&cfg, &perf);
        core.sim.run_for(1.0);
        core.admit_arrivals();
        assert!(!core.waiting.is_empty());
        assert!((policy.phase_mix_share(&core) - 1.0).abs() < 1e-12);
        assert_eq!(policy.target_partition(&core).prefill_sms, 96);
        // after the run drains there is no pending prefill: the applied
        // partition must have followed the estimate down to the floor
        core.run(&mut policy);
        assert_eq!(policy.phase_mix_share(&core), 0.0);
        assert_eq!(core.rm.partition(), policy.target_partition(&core));
        assert_eq!(core.rm.partition().prefill_sms, cfg.min_prefill_sms);
        assert!(core.rm.reconfig_count() > 1, "proactive split must move with the mix");
    }

    #[test]
    fn proactive_split_serves_all_and_is_deterministic() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 25, 31);
        let a = serve_proactive_split(&cfg, &perf, &gt, &trace, 3);
        let b = serve_proactive_split(&cfg, &perf, &gt, &trace, 3);
        assert_eq!(a.records.len(), 25);
        assert_eq!(a.records, b.records);
        assert_eq!(a.reconfigs, b.reconfigs);
    }

    #[test]
    fn proactive_split_feeds_its_calibrator() {
        use crate::config::CalibrationConfig;
        let (mut cfg, perf, gt) = setup();
        cfg.calibration = CalibrationConfig::on();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 15, 13);
        let out = serve_proactive_split(&cfg, &perf, &gt, &trace, 7);
        assert_eq!(out.records.len(), 15);
        assert!(out.calibration.samples > 10, "{:?}", out.calibration);
    }

    #[test]
    fn proactive_beats_static_on_bursty_p90_ttft() {
        // the fig13-style claim the bench gates: under a bursty trace a
        // boundary that moves ahead of the phase mix clears prefill
        // surges that a frozen 50/50 split queues behind.
        use crate::metrics::summarize;
        let (cfg, perf, gt) = setup();
        let trace = generate_bursty_trace(&Dataset::sharegpt(), 2.0, 12.0, 4.0, 1.5, 1.0, 11);
        let n = trace.len();
        let st = serve_static_split(&cfg, &gt, &trace, 3);
        let pr = serve_proactive_split(&cfg, &perf, &gt, &trace, 3);
        assert_eq!(st.records.len(), n);
        assert_eq!(pr.records.len(), n);
        let s = summarize(&st.records, &cfg.slo, Some(st.virtual_duration));
        let p = summarize(&pr.records, &cfg.slo, Some(pr.virtual_duration));
        assert!(
            p.p90_ttft < s.p90_ttft,
            "proactive p90 ttft {} vs static {}",
            p.p90_ttft,
            s.p90_ttft
        );
    }

    /// Delegating wrapper that asserts the phases never co-schedule:
    /// at every policy callback at most one lane may be in flight.
    struct AssertExclusive(TemporalMuxPolicy);

    impl AssertExclusive {
        fn check(core: &EngineCore) {
            assert!(
                core.lane_idle(Lane::Prefill) || core.lane_idle(Lane::Decode),
                "temporal mux co-scheduled prefill and decode"
            );
        }
    }

    impl ServingPolicy for AssertExclusive {
        fn label(&self) -> String {
            self.0.label()
        }
        fn plan(&mut self, core: &mut EngineCore) {
            Self::check(core);
            self.0.plan(core);
            Self::check(core);
        }
        fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
            Self::check(core);
            self.0.on_drain(lane, core);
        }
        fn has_private_work(&self) -> bool {
            self.0.has_private_work()
        }
        fn private_backlog_tokens(&self) -> usize {
            self.0.private_backlog_tokens()
        }
    }

    #[test]
    fn temporal_mux_never_coschedules_phases() {
        let (cfg, _, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 30, 23);
        let mut core = EngineCore::new(cfg.clone(), gt, trace, &CoreOptions::default());
        let mut policy = AssertExclusive(TemporalMuxPolicy::new(&cfg));
        core.run(&mut policy);
        let out = core.into_output();
        assert_eq!(out.records.len(), 30);
    }

    #[test]
    fn temporal_mux_is_deterministic() {
        let (cfg, _, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 20, 37);
        let a = serve_temporal_mux(&cfg, &gt, &trace, 3);
        let b = serve_temporal_mux(&cfg, &gt, &trace, 3);
        assert_eq!(a.records, b.records);
    }

    #[test]
    fn decode_epoch_sweep_trades_ttft_against_tpot() {
        // The knob's whole point: short decode epochs let queued
        // prefills in sooner (TTFT down) at the cost of interrupting
        // decode more often (TPOT up); long epochs do the reverse.
        // Assert the endpoints of a {2, 8, 32} sweep on a contended
        // trace move in opposite directions.
        use crate::metrics::summarize;
        let (cfg, _, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 10.0, 40, 23);
        let run = |iters: usize| {
            let cfg = ServingConfig { decode_epoch_iters: iters, ..cfg.clone() };
            let out = serve_temporal_mux(&cfg, &gt, &trace, 3);
            assert_eq!(out.records.len(), trace.len());
            summarize(&out.records, &cfg.slo, Some(out.virtual_duration))
        };
        let short = run(2);
        let mid = run(8);
        let long = run(32);
        assert!(
            short.mean_ttft < long.mean_ttft,
            "short epochs must win TTFT: {} vs {}",
            short.mean_ttft,
            long.mean_ttft
        );
        assert!(
            short.mean_tpot > long.mean_tpot,
            "long epochs must win TPOT: {} vs {}",
            short.mean_tpot,
            long.mean_tpot
        );
        // the default sits between the endpoints on at least one axis
        assert!(mid.mean_ttft <= long.mean_ttft || mid.mean_tpot <= short.mean_tpot);
    }
}
