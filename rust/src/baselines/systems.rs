//! Unified runner over every evaluated system — the x-axis of Figs. 11,
//! 13 and 14 — plus the policy factory the cluster layer uses to scale
//! any of them across replicas.

use crate::baselines::chunked::{serve_chunked_output, ChunkedConfig, ChunkedPolicy};
use crate::baselines::disagg::{
    serve_proactive_split, serve_static_split, serve_temporal_mux, ProactiveSplitPolicy,
    StaticSplitPolicy, TemporalMuxPolicy,
};
use crate::baselines::nanoflow::{serve_nanoflow_output, NanoflowPolicy};
use crate::config::ServingConfig;
use crate::engine::core::{EngineOutput, ServingPolicy};
use crate::engine::sim_engine::{serve_bullet, BulletPolicy, Features, SimEngineOptions};
use crate::gpu::roofline::GroundTruth;
use crate::metrics::RequestRecord;
use crate::perf::PerfModel;
use crate::workload::Request;

/// Every serving system the evaluation compares.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Bullet,
    Vllm1024,
    Sglang1024,
    Sglang2048,
    Nanoflow,
    /// Fixed intra-GPU P/D disaggregation: a pinned prefill/decode SM
    /// split (RAPID-Serve style); the ratio comes from `cfg.pd_split`.
    StaticSplit,
    /// Nexus-style proactive P/D repartitioning ahead of the predicted
    /// phase mix (same calibrated predictor as Bullet).
    ProactiveSplit,
    /// Time-sliced P/D alternation: all-SM prefill epochs alternate
    /// with all-SM decode epochs; phases never co-schedule.
    TemporalMux,
    /// Fixed prefill SM quota, decode on the whole GPU (Fig. 13 / MuxServe-like).
    FixedSm(usize),
    /// Ablations (Fig. 14).
    Naive,
    WithPartition,
    WithScheduler,
}

impl System {
    pub fn label(&self) -> String {
        match self {
            System::Bullet => "Bullet".into(),
            System::Vllm1024 => "vLLM-1024".into(),
            System::Sglang1024 => "SGLang-1024".into(),
            System::Sglang2048 => "SGLang-2048".into(),
            System::Nanoflow => "NanoFlow".into(),
            System::StaticSplit => "Static-Split".into(),
            System::ProactiveSplit => "Proactive-Split".into(),
            System::TemporalMux => "Temporal-Mux".into(),
            System::FixedSm(n) => format!("SM-{n}"),
            System::Naive => "Naive".into(),
            System::WithPartition => "w/Partition".into(),
            System::WithScheduler => "w/Scheduler".into(),
        }
    }

    /// CLI name → system.
    pub fn by_name(name: &str) -> Option<System> {
        match name {
            "bullet" => Some(System::Bullet),
            "vllm-1024" => Some(System::Vllm1024),
            "sglang-1024" => Some(System::Sglang1024),
            "sglang-2048" => Some(System::Sglang2048),
            "nanoflow" => Some(System::Nanoflow),
            "static-split" => Some(System::StaticSplit),
            "proactive-split" => Some(System::ProactiveSplit),
            "temporal-mux" => Some(System::TemporalMux),
            _ => None,
        }
    }

    /// The paper's Fig. 11 comparison set.
    pub fn evaluation_set() -> Vec<System> {
        vec![
            System::Vllm1024,
            System::Sglang1024,
            System::Sglang2048,
            System::Nanoflow,
            System::StaticSplit,
            System::ProactiveSplit,
            System::TemporalMux,
            System::Bullet,
        ]
    }

    /// The Fig. 14 ablation set.
    pub fn ablation_set() -> Vec<System> {
        vec![
            System::Naive,
            System::WithPartition,
            System::WithScheduler,
            System::Bullet,
        ]
    }

    /// The Bullet feature mask this system corresponds to, if it runs on
    /// the Bullet policy.
    fn bullet_features(&self) -> Option<Features> {
        match self {
            System::Bullet => Some(Features::default()),
            System::Naive => Some(Features::naive()),
            System::WithPartition => Some(Features::partition_only()),
            System::WithScheduler => Some(Features::scheduler_only()),
            System::FixedSm(n) => Some(Features::fixed(*n)),
            _ => None,
        }
    }

    /// Instantiate this system's decision logic for one engine instance.
    /// Every system — Bullet, its ablations, the static-partition
    /// configurations, chunked prefill and NanoFlow — is a policy over
    /// the same serving core, so the cluster layer can scale any of them.
    pub fn policy(&self, cfg: &ServingConfig, perf: &PerfModel) -> Box<dyn ServingPolicy> {
        if let Some(features) = self.bullet_features() {
            return Box::new(BulletPolicy::new(cfg, perf, features));
        }
        match self {
            System::Vllm1024 => Box::new(ChunkedPolicy::new(ChunkedConfig::vllm_1024())),
            System::Sglang1024 => Box::new(ChunkedPolicy::new(ChunkedConfig::sglang_1024())),
            System::Sglang2048 => Box::new(ChunkedPolicy::new(ChunkedConfig::sglang_2048())),
            System::Nanoflow => Box::new(NanoflowPolicy::new(ChunkedConfig::sglang_1024())),
            System::StaticSplit => Box::new(StaticSplitPolicy::new(cfg)),
            System::ProactiveSplit => Box::new(ProactiveSplitPolicy::new(cfg, perf)),
            System::TemporalMux => Box::new(TemporalMuxPolicy::new(cfg)),
            _ => unreachable!("bullet-family systems handled above"),
        }
    }
}

/// Run a system over a trace and return the full [`EngineOutput`]
/// (records, prefix-cache counters, utilization) — every system runs on
/// the shared core, so every system reports the same counters.
pub fn run_system_output(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let bullet_opts = |features: Features| SimEngineOptions {
        seed,
        features,
        ..Default::default()
    };
    match system {
        System::Bullet => serve_bullet(cfg, perf, gt, trace, &bullet_opts(Features::default())),
        System::Naive => serve_bullet(cfg, perf, gt, trace, &bullet_opts(Features::naive())),
        System::WithPartition => {
            serve_bullet(cfg, perf, gt, trace, &bullet_opts(Features::partition_only()))
        }
        System::WithScheduler => {
            serve_bullet(cfg, perf, gt, trace, &bullet_opts(Features::scheduler_only()))
        }
        System::FixedSm(n) => serve_bullet(cfg, perf, gt, trace, &bullet_opts(Features::fixed(n))),
        System::Vllm1024 => serve_chunked_output(cfg, &ChunkedConfig::vllm_1024(), gt, trace, seed),
        System::Sglang1024 => {
            serve_chunked_output(cfg, &ChunkedConfig::sglang_1024(), gt, trace, seed)
        }
        System::Sglang2048 => {
            serve_chunked_output(cfg, &ChunkedConfig::sglang_2048(), gt, trace, seed)
        }
        System::Nanoflow => {
            serve_nanoflow_output(cfg, &ChunkedConfig::sglang_1024(), gt, trace, seed)
        }
        System::StaticSplit => serve_static_split(cfg, gt, trace, seed),
        System::ProactiveSplit => serve_proactive_split(cfg, perf, gt, trace, seed),
        System::TemporalMux => serve_temporal_mux(cfg, gt, trace, seed),
    }
}

/// Run a system over a trace and return per-request records.  (Thin
/// wrapper over [`run_system_output`].)
pub fn run_system(
    system: System,
    cfg: &ServingConfig,
    perf: &PerfModel,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> Vec<RequestRecord> {
    run_system_output(system, cfg, perf, gt, trace, seed).records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuSpec, ModelSpec};
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, PerfModel, GroundTruth) {
        let cfg = ServingConfig::default();
        let perf = PerfModel::analytical(GpuSpec::a100(), ModelSpec::llama31_8b());
        let gt = GroundTruth::new(GpuSpec::a100());
        (cfg, perf, gt)
    }

    #[test]
    fn all_systems_complete_the_trace() {
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 4.0, 12, 81);
        for sys in [
            System::Bullet,
            System::Vllm1024,
            System::Sglang1024,
            System::Sglang2048,
            System::Nanoflow,
            System::StaticSplit,
            System::ProactiveSplit,
            System::TemporalMux,
            System::FixedSm(84),
            System::Naive,
            System::WithPartition,
            System::WithScheduler,
        ] {
            let recs = run_system(sys, &cfg, &perf, &gt, &trace, 1);
            assert_eq!(recs.len(), 12, "{}", sys.label());
        }
    }

    #[test]
    fn bullet_beats_chunked_on_ttft() {
        // The paper's headline: Bullet's TTFT is far below chunked
        // prefill's because prefill is never budget-starved.
        let (cfg, perf, gt) = setup();
        let trace = generate_n_requests(&Dataset::azure_code(), 4.0, 30, 91);
        let b = summarize(
            &run_system(System::Bullet, &cfg, &perf, &gt, &trace, 2),
            &cfg.slo,
            None,
        );
        let s = summarize(
            &run_system(System::Sglang1024, &cfg, &perf, &gt, &trace, 2),
            &cfg.slo,
            None,
        );
        assert!(
            b.mean_ttft < s.mean_ttft,
            "bullet {} sglang {}",
            b.mean_ttft,
            s.mean_ttft
        );
    }

    #[test]
    fn labels_unique() {
        let mut labels: Vec<String> = System::evaluation_set()
            .into_iter()
            .chain(System::ablation_set())
            .map(|s| s.label())
            .collect();
        labels.sort();
        let before = labels.len();
        labels.dedup();
        assert_eq!(labels.len(), before - 1); // Bullet appears in both sets
    }

    #[test]
    fn policy_factory_labels_match() {
        let (cfg, perf, _) = setup();
        // the factory builds the system the label says it builds —
        // including the ablations and fixed-quota configurations
        for sys in System::evaluation_set()
            .into_iter()
            .chain(System::ablation_set())
            .chain([System::FixedSm(84)])
        {
            let p = sys.policy(&cfg, &perf);
            assert_eq!(p.label(), sys.label(), "{:?}", sys);
        }
    }
}
