//! NanoFlow-style nano-batch overlap (§2.4, Fig. 3b).
//!
//! NanoFlow keeps the chunked-prefill hybrid batch but splits each
//! iteration into nano-batches pinned to different streams so that
//! compute-bound, memory-bound and (in the original) network operators
//! from DIFFERENT nano-batches overlap.  The pipeline is *static*: chunk
//! size and grid partitioning are fixed offline, so the growing attention
//! duration of later chunks eventually starves the overlap (§2.4).
//!
//! Model: per iteration the decode-side kernels and the prefill-chunk
//! kernels are issued on two concurrent full-GPU streams (the simulator's
//! CKE + bandwidth-contention physics produce the partial overlap), with
//! a barrier per iteration — the fixed-pipeline synchronization.
//!
//! As a [`ServingPolicy`]: batch building and end-of-iteration lifecycle
//! are shared with the chunked policy; the only difference is kernel
//! issue (two overlapped lanes) and the drain barrier (`on_drain` waits
//! for BOTH lanes before completing the iteration).

use crate::baselines::chunked::{
    build_hybrid_batch, complete_hybrid_iteration, hybrid_stall, ChunkedConfig, HybridBatch,
};
use crate::config::ServingConfig;
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, Lane, ServingPolicy};
use crate::gpu::roofline::GroundTruth;
use crate::metrics::RequestRecord;
use crate::model::phases::{decode_all_layers, prefill_all_layers, PhaseShape};
use crate::workload::Request;

/// NanoFlow decision logic: hybrid batches with nano-batch overlap.
pub struct NanoflowPolicy {
    ccfg: ChunkedConfig,
    batch: Option<HybridBatch>,
}

impl NanoflowPolicy {
    /// NanoFlow config = chunked config (chunk 1024 in the paper's setup).
    pub fn new(ccfg: ChunkedConfig) -> NanoflowPolicy {
        NanoflowPolicy { ccfg, batch: None }
    }
}

impl ServingPolicy for NanoflowPolicy {
    fn label(&self) -> String {
        "NanoFlow".into()
    }

    fn plan(&mut self, core: &mut EngineCore) {
        if !core.all_idle() {
            return; // fixed pipeline: one hybrid iteration at a time
        }
        core.join_pending(usize::MAX);
        let batch = build_hybrid_batch(core, self.ccfg.chunk_size);
        if batch.empty() {
            return;
        }
        // Nano-batch overlap: the two halves co-run on concurrent
        // full-GPU streams (barrier at the end).
        let full = core.cfg.gpu.num_sms;
        if batch.chunk_tokens > 0 {
            // attention reads reload + cached context alike (resident
            // KV re-read per chunk); ctx_max is exactly their sum
            let kernels = prefill_all_layers(
                &core.cfg.model,
                PhaseShape { tokens: batch.chunk_tokens, context: batch.ctx_max },
            );
            let stream = core.rm.prefill_stream_for(full);
            core.submit(Lane::Prefill, stream, kernels);
        }
        if batch.ds > 0 {
            let kernels = decode_all_layers(
                &core.cfg.model,
                PhaseShape { tokens: batch.ds, context: batch.cl },
            );
            let stream = core.rm.decode_stream_for(full);
            core.submit(Lane::Decode, stream, kernels);
        }
        self.batch = Some(batch);
    }

    fn on_drain(&mut self, _lane: Lane, core: &mut EngineCore) {
        // Pipeline barrier: the iteration completes only when BOTH
        // nano-batch lanes have drained.
        if !core.all_idle() {
            return;
        }
        let batch = self.batch.take().expect("drain without an iteration");
        complete_hybrid_iteration(core, &batch, self.ccfg.iteration_overhead(&batch));
    }

    fn on_stall(&mut self, core: &mut EngineCore) -> bool {
        hybrid_stall(core)
    }

    fn has_private_work(&self) -> bool {
        self.batch.is_some()
    }

    // the in-flight batch's assignments index into `core.waiting`
    fn waiting_locked(&self) -> bool {
        self.batch.is_some()
    }
}

/// Serve `trace` with the NanoFlow engine and return the full engine
/// output (records + prefix-cache counters + utilization).
pub fn serve_nanoflow_output(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let opts = CoreOptions {
        seed,
        // the pre-refactor baseline loops had no virtual-time cap
        max_virtual_time: f64::INFINITY,
        ..CoreOptions::default()
    };
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts);
    let mut policy = NanoflowPolicy::new(ccfg.clone());
    core.run(&mut policy);
    core.into_output()
}

/// Serve `trace` with the NanoFlow engine.  (Thin wrapper over
/// [`serve_nanoflow_output`].)
pub fn serve_nanoflow(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> Vec<RequestRecord> {
    serve_nanoflow_output(cfg, ccfg, gt, trace, seed).records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::baselines::chunked::serve_chunked;
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, GroundTruth) {
        (ServingConfig::default(), GroundTruth::new(GpuSpec::a100()))
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 20, 61);
        let recs = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 1);
        assert_eq!(recs.len(), 20);
    }

    #[test]
    fn overlap_beats_lockstep_throughput() {
        // NanoFlow's whole point: overlapping the decode (memory) and
        // prefill (compute) halves shortens the iteration vs lock-step.
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 71);
        let nano = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let lock = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let sn = summarize(&nano, &cfg.slo, None);
        let sl = summarize(&lock, &cfg.slo, None);
        assert!(
            sn.mean_e2e < sl.mean_e2e * 1.05,
            "nano {} lockstep {}",
            sn.mean_e2e,
            sl.mean_e2e
        );
    }

    #[test]
    fn still_chunk_limited_ttft() {
        // A long prompt still pays the chunk pipeline: TTFT scales with
        // chunk count even under overlap.
        let (cfg, gt) = setup();
        let long = vec![Request { id: 0, arrival: 0.0, input_len: 12288, output_len: 2, ..Default::default() }];
        let short = vec![Request { id: 0, arrival: 0.0, input_len: 1024, output_len: 2, ..Default::default() }];
        let rl = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &long, 3);
        let rs = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &short, 3);
        assert!(rl[0].ttft() > 8.0 * rs[0].ttft());
    }
}
