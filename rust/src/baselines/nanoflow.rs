//! NanoFlow-style nano-batch overlap (§2.4, Fig. 3b).
//!
//! NanoFlow keeps the chunked-prefill hybrid batch but splits each
//! iteration into nano-batches pinned to different streams so that
//! compute-bound, memory-bound and (in the original) network operators
//! from DIFFERENT nano-batches overlap.  The pipeline is *static*: chunk
//! size and grid partitioning are fixed offline, so the growing attention
//! duration of later chunks eventually starves the overlap (§2.4).
//!
//! Model: per iteration the decode-side kernels and the prefill-chunk
//! kernels are issued on two concurrent full-GPU streams (the simulator's
//! CKE + bandwidth-contention physics produce the partial overlap), with
//! a barrier per iteration — the fixed-pipeline synchronization.

use crate::baselines::chunked::ChunkedConfig;
use crate::config::ServingConfig;
use crate::gpu::roofline::GroundTruth;
use crate::gpu::simulator::Simulator;
use crate::gpu::stream::SmMask;
use crate::kvcache::KvPool;
use crate::metrics::RequestRecord;
use crate::model::phases::{decode_all_layers, prefill_all_layers, PhaseShape};
use crate::workload::Request;

struct Prefilling {
    id: u64,
    arrival: f64,
    input_len: usize,
    output_len: usize,
    done: usize,
    prefill_start: Option<f64>,
}

struct Decoding {
    id: u64,
    arrival: f64,
    input_len: usize,
    output_len: usize,
    ctx_len: usize,
    tokens_out: usize,
    prefill_start: f64,
    first_token_time: f64,
}

/// NanoFlow config = chunked config (chunk 1024 in the paper's setup).
pub fn serve_nanoflow(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> Vec<RequestRecord> {
    let mut sim = Simulator::new(gt.clone(), seed);
    let full = cfg.gpu.num_sms;
    let s_prefill = sim.create_stream(SmMask::first(full), "nano-prefill");
    let s_decode = sim.create_stream(SmMask::first(full), "nano-decode");
    let mut kv = KvPool::new(cfg.kv_capacity_tokens);

    let mut waiting: Vec<Prefilling> = Vec::new();
    let mut decode: Vec<Decoding> = Vec::new();
    let mut records: Vec<RequestRecord> = Vec::new();
    let mut next_arrival = 0usize;
    let expected = trace.len();

    while records.len() < expected {
        let now = sim.now();
        while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
            let r = &trace[next_arrival];
            waiting.push(Prefilling {
                id: r.id,
                arrival: r.arrival,
                input_len: r.input_len,
                output_len: r.output_len,
                done: 0,
                prefill_start: None,
            });
            next_arrival += 1;
        }

        if waiting.is_empty() && decode.is_empty() {
            if next_arrival < trace.len() {
                let dt = (trace[next_arrival].arrival - now).max(0.0) + 1e-9;
                sim.run_for(dt);
                continue;
            }
            unreachable!("work exhausted with records missing");
        }

        // Hybrid-batch budget accounting identical to chunked prefill.
        let ds = decode.len().min(ccfg.chunk_size);
        let mut budget = ccfg.chunk_size - ds;
        let mut assignments: Vec<(usize, usize, usize)> = Vec::new();
        for (i, w) in waiting.iter_mut().enumerate() {
            if budget == 0 {
                break;
            }
            let remaining = w.input_len - w.done;
            let take = remaining.min(budget);
            if take == 0 {
                continue;
            }
            if w.done == 0 {
                let reserve = w.input_len + w.output_len;
                if !kv.can_grow(w.id, reserve) {
                    continue;
                }
                kv.grow(w.id, reserve).unwrap();
                w.prefill_start = Some(now);
            }
            assignments.push((i, take, w.done));
            budget -= take;
        }

        let chunk_tokens: usize = assignments.iter().map(|a| a.1).sum();
        let ctx_max = assignments.iter().map(|a| a.2).max().unwrap_or(0);
        let cl = if ds > 0 {
            (decode.iter().map(|d| d.ctx_len).sum::<usize>() / ds).max(1)
        } else {
            1
        };
        if chunk_tokens == 0 && ds == 0 {
            sim.run_for(1e-3);
            continue;
        }

        // Nano-batch overlap: the two halves co-run (barrier at the end).
        if chunk_tokens > 0 {
            sim.submit_all(
                s_prefill,
                prefill_all_layers(&cfg.model, PhaseShape { tokens: chunk_tokens, context: ctx_max }),
            );
        }
        if ds > 0 {
            sim.submit_all(
                s_decode,
                decode_all_layers(&cfg.model, PhaseShape { tokens: ds, context: cl }),
            );
        }
        sim.run_until_idle(); // pipeline barrier
        sim.run_for(ccfg.iter_overhead);
        let iter_end = sim.now();
        sim.take_completions();

        let mut i = 0;
        while i < decode.len() {
            let d = &mut decode[i];
            d.tokens_out += 1;
            d.ctx_len += 1;
            if d.tokens_out >= d.output_len {
                let d = decode.remove(i);
                records.push(RequestRecord {
                    id: d.id,
                    arrival: d.arrival,
                    input_len: d.input_len,
                    output_len: d.output_len,
                    first_token_time: d.first_token_time,
                    finish_time: iter_end,
                    prefill_start: d.prefill_start,
                });
                kv.release(d.id).unwrap();
            } else {
                i += 1;
            }
        }

        let mut finished_idx: Vec<usize> = Vec::new();
        for &(i, take, _) in &assignments {
            waiting[i].done += take;
            if waiting[i].done >= waiting[i].input_len {
                finished_idx.push(i);
            }
        }
        finished_idx.sort_unstable_by(|a, b| b.cmp(a));
        for i in finished_idx {
            let w = waiting.remove(i);
            let ps = w.prefill_start.unwrap();
            if w.output_len <= 1 {
                records.push(RequestRecord {
                    id: w.id,
                    arrival: w.arrival,
                    input_len: w.input_len,
                    output_len: w.output_len,
                    first_token_time: iter_end,
                    finish_time: iter_end,
                    prefill_start: ps,
                });
                kv.release(w.id).unwrap();
            } else {
                decode.push(Decoding {
                    id: w.id,
                    arrival: w.arrival,
                    input_len: w.input_len,
                    output_len: w.output_len,
                    ctx_len: w.input_len,
                    tokens_out: 1,
                    prefill_start: ps,
                    first_token_time: iter_end,
                });
            }
        }
    }

    records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::baselines::chunked::serve_chunked;
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, GroundTruth) {
        (ServingConfig::default(), GroundTruth::new(GpuSpec::a100()))
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 20, 61);
        let recs = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 1);
        assert_eq!(recs.len(), 20);
    }

    #[test]
    fn overlap_beats_lockstep_throughput() {
        // NanoFlow's whole point: overlapping the decode (memory) and
        // prefill (compute) halves shortens the iteration vs lock-step.
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 8.0, 40, 71);
        let nano = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let lock = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let sn = summarize(&nano, &cfg.slo, None);
        let sl = summarize(&lock, &cfg.slo, None);
        assert!(
            sn.mean_e2e < sl.mean_e2e * 1.05,
            "nano {} lockstep {}",
            sn.mean_e2e,
            sl.mean_e2e
        );
    }

    #[test]
    fn still_chunk_limited_ttft() {
        // A long prompt still pays the chunk pipeline: TTFT scales with
        // chunk count even under overlap.
        let (cfg, gt) = setup();
        let long = vec![Request { id: 0, arrival: 0.0, input_len: 12288, output_len: 2 }];
        let short = vec![Request { id: 0, arrival: 0.0, input_len: 1024, output_len: 2 }];
        let rl = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &long, 3);
        let rs = serve_nanoflow(&cfg, &ChunkedConfig::sglang_1024(), &gt, &short, 3);
        assert!(rl[0].ttft() > 8.0 * rs[0].ttft());
    }
}
