//! Baseline serving systems the paper compares against (§4.1), all
//! running on the same simulated GPU:
//!
//! - [`chunked`]: chunked-prefill hybrid batching — vLLM-1024,
//!   SGLang-1024, SGLang-2048 (token-budget lock-step execution with
//!   KV-reload costs);
//! - [`nanoflow`]: NanoFlow-style nano-batch overlap on top of chunked
//!   prefill;
//! - [`disagg`]: intra-GPU prefill/decode disaggregation — a fixed SM
//!   split (RAPID-Serve style), Nexus-style proactive repartitioning
//!   ahead of the predicted phase mix, and strict temporal
//!   multiplexing;
//! - fixed-quota spatial sharing (MuxServe-like) and the Fig. 14
//!   ablations are expressed through [`crate::engine::sim_engine::Features`]
//!   (see [`systems`]).

pub mod chunked;
pub mod disagg;
pub mod nanoflow;
pub mod systems;

pub use chunked::{serve_chunked, serve_chunked_output, ChunkedConfig, ChunkedPolicy};
pub use disagg::{
    serve_proactive_split, serve_static_split, serve_temporal_mux, ProactiveSplitPolicy,
    StaticSplitPolicy, TemporalMuxPolicy,
};
pub use nanoflow::{serve_nanoflow, serve_nanoflow_output, NanoflowPolicy};
pub use systems::{run_system, run_system_output, System};
