//! Chunked-prefill hybrid batching (Sarathi/vLLM/SGLang — §2.3.1).
//!
//! Every iteration builds one hybrid batch under a fixed token budget
//! `cs`: active decode requests claim `ds` token slots first, the
//! remaining `cs - ds` go to prefill chunks of the waiting queue (FCFS;
//! sequences longer than the residual budget are split across
//! iterations).  The batch executes in LOCK-STEP on the whole GPU: one
//! fused pass per layer, so decode tokens wait for the chunk's attention
//! and vice versa.  Chunked attention must RELOAD the KV of all previous
//! chunks — the N(N+1)/2 cost of §2.3.1 — which `prefill_layer_kernels`
//! models through the `context` field.

use crate::config::ServingConfig;
use crate::gpu::kernel::KernelDesc;
use crate::gpu::roofline::GroundTruth;
use crate::gpu::simulator::Simulator;
use crate::gpu::stream::SmMask;
use crate::kvcache::KvPool;
use crate::metrics::RequestRecord;
use crate::model::phases::{decode_layer_kernels, prefill_layer_kernels, PhaseShape};
use crate::workload::Request;

/// Chunked-prefill system parameters.
#[derive(Debug, Clone)]
pub struct ChunkedConfig {
    /// Token budget per hybrid batch (the "chunk size").
    pub chunk_size: usize,
    /// Fixed CPU scheduling overhead added per iteration, seconds.
    /// Calibration knob for the engine-implementation gap the paper
    /// observes between vLLM V1 and SGLang at equal chunk size.
    pub iter_overhead: f64,
    pub label: &'static str,
}

impl ChunkedConfig {
    /// vLLM V1, chunk 1024 (higher per-iteration control-plane overhead).
    pub fn vllm_1024() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 1024,
            iter_overhead: 4e-3,
            label: "vLLM-1024",
        }
    }

    pub fn sglang_1024() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 1024,
            iter_overhead: 1e-3,
            label: "SGLang-1024",
        }
    }

    pub fn sglang_2048() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 2048,
            iter_overhead: 1e-3,
            label: "SGLang-2048",
        }
    }
}

/// §2.3.1: iterations needed to prefill `sl` tokens when each hybrid
/// batch carries `ds` decode tokens under budget `cs`.
pub fn chunk_iterations(sl: usize, cs: usize, ds: usize) -> usize {
    let residual = cs.saturating_sub(ds).max(1);
    sl.div_ceil(residual)
}

/// §2.3.1: total KV-prefix reloads across an `n`-chunk prefill is the
/// triangular number n(n+1)/2 (each chunk re-reads all prior chunks).
pub fn kv_reload_factor(n_chunks: usize) -> usize {
    n_chunks * (n_chunks + 1) / 2
}

struct PrefillProgress {
    id: u64,
    arrival: f64,
    input_len: usize,
    output_len: usize,
    /// Tokens already prefilled (the reload context of the next chunk).
    done: usize,
    prefill_start: Option<f64>,
}

struct DecodeActive {
    id: u64,
    arrival: f64,
    input_len: usize,
    output_len: usize,
    ctx_len: usize,
    tokens_out: usize,
    prefill_start: f64,
    first_token_time: f64,
}

/// One hybrid-batch layer pass: fused GEMMs over (ds + chunk) rows plus
/// the two attention kernels, serialized (lock-step).
fn hybrid_iteration_kernels(
    cfg: &ServingConfig,
    chunk: usize,
    ctx: usize,
    ds: usize,
    cl: usize,
) -> Vec<KernelDesc> {
    let model = &cfg.model;
    let mut out = Vec::new();
    for layer in 0..model.n_layers {
        if chunk > 0 {
            // the fused pass: GEMM rows = chunk + ds handled by issuing
            // the prefill-side GEMMs at (chunk + ds) tokens...
            for k in prefill_layer_kernels(model, PhaseShape { tokens: chunk + ds, context: ctx }) {
                // ...but attention splits: replace the unified attention
                // with chunk-attention only; decode attention added below.
                out.push(k.with_tag(layer as u32));
            }
        } else if ds > 0 {
            for k in prefill_layer_kernels(model, PhaseShape { tokens: ds, context: 0 }) {
                out.push(k.with_tag(layer as u32));
            }
        }
        if ds > 0 {
            // decode attention over each sequence's cache (not part of
            // the prefill attention above).
            let attn = decode_layer_kernels(model, PhaseShape { tokens: ds, context: cl })
                .into_iter()
                .nth(1)
                .unwrap();
            out.push(attn.with_tag(layer as u32));
        }
    }
    out
}

/// Serve `trace` with a chunked-prefill engine; same record format as
/// the Bullet engine so summaries are directly comparable.
pub fn serve_chunked(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> Vec<RequestRecord> {
    let mut sim = Simulator::new(gt.clone(), seed);
    let stream = sim.create_stream(SmMask::first(cfg.gpu.num_sms), "hybrid");
    let mut kv = KvPool::new(cfg.kv_capacity_tokens);

    let mut waiting: Vec<PrefillProgress> = Vec::new();
    let mut decode: Vec<DecodeActive> = Vec::new();
    let mut records: Vec<RequestRecord> = Vec::new();
    let mut next_arrival = 0usize;
    let expected = trace.len();

    while records.len() < expected {
        let now = sim.now();
        while next_arrival < trace.len() && trace[next_arrival].arrival <= now {
            let r = &trace[next_arrival];
            waiting.push(PrefillProgress {
                id: r.id,
                arrival: r.arrival,
                input_len: r.input_len,
                output_len: r.output_len,
                done: 0,
                prefill_start: None,
            });
            next_arrival += 1;
        }

        if waiting.is_empty() && decode.is_empty() {
            if next_arrival < trace.len() {
                let dt = (trace[next_arrival].arrival - now).max(0.0) + 1e-9;
                sim.run_for(dt);
                continue;
            }
            unreachable!("work exhausted with records missing");
        }

        // Build the hybrid batch: decode first (token each), then chunks.
        let ds = decode.len().min(ccfg.chunk_size);
        let mut budget = ccfg.chunk_size - ds;
        let mut assignments: Vec<(usize, usize, usize)> = Vec::new(); // (idx, take, ctx)
        for (i, w) in waiting.iter_mut().enumerate() {
            if budget == 0 {
                break;
            }
            let remaining = w.input_len - w.done;
            let take = remaining.min(budget);
            if take == 0 {
                continue;
            }
            // KV reservation at first chunk (input + output, see engine docs).
            if w.done == 0 {
                let reserve = w.input_len + w.output_len;
                if !kv.can_grow(w.id, reserve) {
                    continue; // waits for memory
                }
                kv.grow(w.id, reserve).unwrap();
                w.prefill_start = Some(now);
            }
            assignments.push((i, take, w.done));
            budget -= take;
        }

        // Lock-step execution of the fused pass.
        let chunk_tokens: usize = assignments.iter().map(|a| a.1).sum();
        let ctx_max = assignments.iter().map(|a| a.2).max().unwrap_or(0);
        let cl = if ds > 0 {
            (decode.iter().map(|d| d.ctx_len).sum::<usize>() / ds).max(1)
        } else {
            1
        };
        if chunk_tokens == 0 && ds == 0 {
            // memory-stalled: wait for a decode to finish... but decode is
            // empty here only if waiting couldn't reserve; jump time.
            sim.run_for(1e-3);
            continue;
        }
        sim.submit_all(
            stream,
            hybrid_iteration_kernels(cfg, chunk_tokens, ctx_max, ds, cl),
        );
        sim.run_until_stream_idle(stream);
        sim.run_for(ccfg.iter_overhead);
        let iter_end = sim.now();
        sim.take_completions();

        // Decode side: one token each.
        let mut i = 0;
        while i < decode.len() {
            let d = &mut decode[i];
            d.tokens_out += 1;
            d.ctx_len += 1;
            if d.tokens_out >= d.output_len {
                let d = decode.remove(i);
                records.push(RequestRecord {
                    id: d.id,
                    arrival: d.arrival,
                    input_len: d.input_len,
                    output_len: d.output_len,
                    first_token_time: d.first_token_time,
                    finish_time: iter_end,
                    prefill_start: d.prefill_start,
                });
                kv.release(d.id).unwrap();
            } else {
                i += 1;
            }
        }

        // Prefill side: credit progress; completed prompts emit their
        // first token at this iteration's end and join decode.
        let mut finished_idx: Vec<usize> = Vec::new();
        for &(i, take, _) in &assignments {
            waiting[i].done += take;
            if waiting[i].done >= waiting[i].input_len {
                finished_idx.push(i);
            }
        }
        finished_idx.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
        for i in finished_idx {
            let w = waiting.remove(i);
            let ps = w.prefill_start.unwrap();
            if w.output_len <= 1 {
                records.push(RequestRecord {
                    id: w.id,
                    arrival: w.arrival,
                    input_len: w.input_len,
                    output_len: w.output_len,
                    first_token_time: iter_end,
                    finish_time: iter_end,
                    prefill_start: ps,
                });
                kv.release(w.id).unwrap();
            } else {
                decode.push(DecodeActive {
                    id: w.id,
                    arrival: w.arrival,
                    input_len: w.input_len,
                    output_len: w.output_len,
                    ctx_len: w.input_len,
                    tokens_out: 1,
                    prefill_start: ps,
                    first_token_time: iter_end,
                });
            }
        }
    }

    records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, GroundTruth) {
        (
            ServingConfig::default(),
            GroundTruth::new(GpuSpec::a100()),
        )
    }

    #[test]
    fn chunk_iteration_formula() {
        // N = ceil(sl / (cs - ds))
        assert_eq!(chunk_iterations(4096, 1024, 0), 4);
        assert_eq!(chunk_iterations(4096, 1024, 512), 8);
        assert_eq!(chunk_iterations(1, 1024, 0), 1);
        assert_eq!(chunk_iterations(4096, 1024, 1024), 4096); // fully starved
    }

    #[test]
    fn kv_reload_triangular() {
        assert_eq!(kv_reload_factor(1), 1);
        assert_eq!(kv_reload_factor(4), 10);
        assert_eq!(kv_reload_factor(16), 136);
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 25, 21);
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 1);
        assert_eq!(recs.len(), 25);
        for r in &recs {
            assert!(r.first_token_time >= r.arrival);
            assert!(r.finish_time >= r.first_token_time);
        }
    }

    #[test]
    fn long_prompts_split_into_chunks() {
        let (cfg, gt) = setup();
        // one 8k prompt: with cs=1024 needs 8 iterations minimum.
        let trace = vec![Request { id: 0, arrival: 0.0, input_len: 8192, output_len: 2 }];
        let r1024 = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let r2048 = serve_chunked(&cfg, &ChunkedConfig::sglang_2048(), &gt, &trace, 2);
        // larger chunks finish prefill sooner (fewer reloads + fewer passes)
        assert!(
            r2048[0].ttft() < r1024[0].ttft(),
            "2048 {} vs 1024 {}",
            r2048[0].ttft(),
            r1024[0].ttft()
        );
    }

    #[test]
    fn decode_tokens_consume_budget() {
        // With a decode batch present, prefill gets less budget per
        // iteration — TTFT of a later request inflates.
        let (cfg, gt) = setup();
        let mut trace = vec![];
        // long-decode requests arrive first and occupy slots
        for i in 0..64 {
            trace.push(Request { id: i, arrival: 0.0, input_len: 64, output_len: 400 });
        }
        trace.push(Request { id: 64, arrival: 1.0, input_len: 4096, output_len: 2 });
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 3);
        let solo = serve_chunked(
            &cfg,
            &ChunkedConfig::sglang_1024(),
            &gt,
            &[Request { id: 0, arrival: 0.0, input_len: 4096, output_len: 2 }],
            3,
        );
        let busy_ttft = recs.iter().find(|r| r.id == 64).unwrap().ttft();
        assert!(
            busy_ttft > 1.1 * solo[0].ttft(),
            "busy {busy_ttft} solo {}",
            solo[0].ttft()
        );
    }

    #[test]
    fn tpot_stable_under_small_chunks() {
        // The selling point of chunked prefill: decode latency stays
        // bounded because each iteration is budget-capped.
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 4.0, 30, 31);
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 4);
        let s = summarize(&recs, &cfg.slo, None);
        assert!(s.mean_tpot < 0.5, "tpot {}", s.mean_tpot);
    }

    #[test]
    fn vllm_overhead_worse_than_sglang() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 30, 41);
        let v = serve_chunked(&cfg, &ChunkedConfig::vllm_1024(), &gt, &trace, 5);
        let s = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 5);
        let sv = summarize(&v, &cfg.slo, None);
        let ss = summarize(&s, &cfg.slo, None);
        assert!(sv.mean_ttft > ss.mean_ttft, "vllm {} sglang {}", sv.mean_ttft, ss.mean_ttft);
    }
}
