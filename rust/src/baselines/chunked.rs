//! Chunked-prefill hybrid batching (Sarathi/vLLM/SGLang — §2.3.1).
//!
//! Every iteration builds one hybrid batch under a fixed token budget
//! `cs`: active decode requests claim `ds` token slots first, the
//! remaining `cs - ds` go to prefill chunks of the waiting queue (FCFS;
//! sequences longer than the residual budget are split across
//! iterations).  The batch executes in LOCK-STEP on the whole GPU: one
//! fused pass per layer, so decode tokens wait for the chunk's attention
//! and vice versa.  Chunked attention must RELOAD the KV of all previous
//! chunks — the N(N+1)/2 cost of §2.3.1 — which `prefill_layer_kernels`
//! models through the `context` field.
//!
//! Expressed as a [`ServingPolicy`] over the shared serving core: the
//! policy plans only when *all* lanes are idle (lock-step) and performs
//! the whole iteration's lifecycle update when its single lane drains.

use crate::config::ServingConfig;
use crate::engine::core::{CoreOptions, EngineCore, EngineOutput, Lane, ServingPolicy};
use crate::gpu::kernel::KernelDesc;
use crate::gpu::roofline::GroundTruth;
use crate::kvcache::BLOCK_TOKENS;
use crate::metrics::RequestRecord;
use crate::model::phases::{decode_layer_kernels, prefill_layer_kernels, PhaseShape};
use crate::workload::Request;

/// Chunked-prefill system parameters.
#[derive(Debug, Clone)]
pub struct ChunkedConfig {
    /// Token budget per hybrid batch (the "chunk size").
    pub chunk_size: usize,
    /// Fixed CPU scheduling overhead added per iteration, seconds.
    /// Calibration knob for the engine-implementation gap the paper
    /// observes between vLLM V1 and SGLang at equal chunk size.
    pub iter_overhead: f64,
    /// SGLang-style radix-tree walk cost per prefix-cache block adopted
    /// (hash + tree-node traversal), charged once when the adopting
    /// request starts its first chunk.  Free cache hits are a fiction —
    /// a faithful radix baseline pays the lookup in TTFT.  Only bites
    /// with `prefix_cache` on (no adoptions ⇒ zero charge), so every
    /// cache-off run stays bit-identical.
    pub radix_lookup_per_block: f64,
    pub label: &'static str,
}

impl ChunkedConfig {
    /// vLLM V1, chunk 1024 (higher per-iteration control-plane overhead).
    pub fn vllm_1024() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 1024,
            iter_overhead: 4e-3,
            radix_lookup_per_block: 3e-6,
            label: "vLLM-1024",
        }
    }

    pub fn sglang_1024() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 1024,
            iter_overhead: 1e-3,
            radix_lookup_per_block: 3e-6,
            label: "SGLang-1024",
        }
    }

    pub fn sglang_2048() -> ChunkedConfig {
        ChunkedConfig {
            chunk_size: 2048,
            iter_overhead: 1e-3,
            radix_lookup_per_block: 3e-6,
            label: "SGLang-2048",
        }
    }

    /// Per-iteration CPU cost: the fixed scheduling overhead plus the
    /// radix walk for blocks this iteration's new requests adopted from
    /// the prefix cache.
    pub(crate) fn iteration_overhead(&self, batch: &HybridBatch) -> f64 {
        self.iter_overhead + self.radix_lookup_per_block * batch.radix_blocks as f64
    }
}

/// §2.3.1: iterations needed to prefill `sl` tokens when each hybrid
/// batch carries `ds` decode tokens under budget `cs`.
pub fn chunk_iterations(sl: usize, cs: usize, ds: usize) -> usize {
    let residual = cs.saturating_sub(ds).max(1);
    sl.div_ceil(residual)
}

/// §2.3.1: total KV-prefix reloads across an `n`-chunk prefill is the
/// triangular number n(n+1)/2 (each chunk re-reads all prior chunks).
pub fn kv_reload_factor(n_chunks: usize) -> usize {
    n_chunks * (n_chunks + 1) / 2
}

/// One hybrid iteration's shape, shared by the chunked and NanoFlow
/// policies: decode slots first, then prefill chunks under the budget.
///
/// Context is tracked in two parts the budget can see separately: the
/// RELOAD context this engine computed in earlier chunks (the §2.3.1
/// triangular re-read) and the CACHED context adopted from the radix
/// index (resident KV the chunk attends but never recomputed here, and
/// whose lookup is charged via `radix_blocks`).  Attention reads both,
/// so `ctx_reload() + ctx_cached` is what the kernels price — identical
/// to the old single `ctx_max`, keeping cache-off runs bit-identical.
#[derive(Debug, Clone, Default)]
pub(crate) struct HybridBatch {
    /// Decode token slots this iteration.
    pub ds: usize,
    /// Prefill chunk tokens this iteration.
    pub chunk_tokens: usize,
    /// Largest TOTAL prior context (reload + cached) across the chunks.
    pub ctx_max: usize,
    /// Largest prefix-cache–adopted context across the chunks.
    pub ctx_cached: usize,
    /// Cache blocks whose radix lookup is charged this iteration
    /// (requests starting their first chunk with an adopted prefix).
    pub radix_blocks: usize,
    /// Mean decode context length.
    pub cl: usize,
    /// (waiting index, tokens taken, prior context) per chunk.
    pub assignments: Vec<(usize, usize, usize)>,
}

impl HybridBatch {
    pub fn empty(&self) -> bool {
        self.chunk_tokens == 0 && self.ds == 0
    }

    /// Batch-level reload residual: the largest prior context minus the
    /// largest adopted context.  In a mixed batch the two maxima can
    /// come from different requests, so this is an aggregate accounting
    /// view (what the budget sees), not a per-request attribution.
    pub fn ctx_reload(&self) -> usize {
        self.ctx_max.saturating_sub(self.ctx_cached)
    }
}

/// Build the iteration's hybrid batch against the core's queues,
/// reserving KV for requests starting their first chunk (input + output
/// minus any prefix-cached tokens; `prefill_start` doubles as the
/// "reserved?" marker — a prefix hit starts `done` above zero).
pub(crate) fn build_hybrid_batch(core: &mut EngineCore, chunk_size: usize) -> HybridBatch {
    let now = core.now();
    let ds = core.decode.len().min(chunk_size);
    let mut budget = chunk_size - ds;
    let mut assignments: Vec<(usize, usize, usize)> = Vec::new();
    let mut ctx_cached = 0usize;
    let mut radix_blocks = 0usize;
    for i in 0..core.waiting.len() {
        if budget == 0 {
            break;
        }
        let (take, reserved, id, reserve, done, cached) = {
            let w = &core.waiting[i];
            (
                w.remaining().min(budget),
                w.prefill_start.is_some(),
                w.req.id,
                w.req.input_len + w.req.output_len - w.req.cached_len,
                w.done,
                w.req.cached_len,
            )
        };
        if take == 0 {
            continue;
        }
        if !reserved {
            // `kv_room` is the evict-vs-recompute hook: it may reclaim
            // cache-only blocks (and idle adoptions of OTHER requests —
            // never entry `i`'s own, so `done` stays valid).
            if !core.kv_room(id, reserve) {
                continue; // waits for memory
            }
            core.kv.grow(id, reserve).unwrap();
            core.waiting[i].prefill_start = Some(now);
            // first chunk of an adopted prefix: charge the radix walk
            radix_blocks += cached / BLOCK_TOKENS;
        }
        ctx_cached = ctx_cached.max(cached);
        assignments.push((i, take, done));
        budget -= take;
    }
    let chunk_tokens = assignments.iter().map(|a| a.1).sum();
    let ctx_max = assignments.iter().map(|a| a.2).max().unwrap_or(0);
    let cl = if ds > 0 {
        (core.decode.iter().map(|d| d.st.ctx_len).sum::<usize>() / ds).max(1)
    } else {
        1
    };
    HybridBatch {
        ds,
        chunk_tokens,
        ctx_max,
        // done >= cached per request, so max(done) >= max(cached):
        // ctx_cached can never exceed ctx_max
        ctx_cached,
        radix_blocks,
        cl,
        assignments,
    }
}

/// Shared stall handling for the chunk-budget engines.  A stall with
/// work waiting means nothing is in flight that could ever free the
/// pool — a non-empty decode batch or pending join always yields
/// `ds >= 1` and a launchable hybrid iteration — so every waiting
/// request is unreserved and failed its reservation against a pool
/// `kv_room` had already stripped of every reclaimable cached block:
/// the head request can never fit.  Fail loudly like the Bullet
/// admission path.
pub(crate) fn hybrid_stall(core: &EngineCore) -> bool {
    if core.waiting.is_empty() {
        return false;
    }
    let w = &core.waiting[0];
    panic!(
        "request {} needs {} KV tokens but pool holds {}",
        w.req.id,
        w.req.input_len + w.req.output_len - w.req.cached_len,
        core.kv.capacity_tokens()
    );
}

/// End-of-iteration lifecycle, shared by the chunked and NanoFlow
/// policies: charge the CPU overhead, credit a token to every decode
/// member, credit chunk progress, and migrate finished prefills.
pub(crate) fn complete_hybrid_iteration(
    core: &mut EngineCore,
    batch: &HybridBatch,
    iter_overhead: f64,
) {
    core.sim.run_for(iter_overhead);
    // Decode side: one token each (joins happen at the NEXT boundary, so
    // this iteration's finishers are exactly the pre-iteration batch).
    core.advance_decode_token();
    // Prefill side: credit progress; completed prompts emit their first
    // token at this iteration's end and migrate to decode.
    let mut finished_idx: Vec<usize> = Vec::new();
    for &(i, take, _) in &batch.assignments {
        core.waiting[i].done += take;
        if core.waiting[i].done >= core.waiting[i].req.input_len {
            finished_idx.push(i);
        } else {
            // Chunk-boundary publication (SGLang-style radix insert):
            // the blocks this chunk just computed become visible NOW,
            // so a mid-prompt arrival sharing the prefix can hit them
            // instead of waiting for full-prompt completion.
            let (id, done) = (core.waiting[i].req.id, core.waiting[i].done);
            core.publish_progress(id, done);
        }
    }
    finished_idx.sort_unstable_by(|a, b| b.cmp(a)); // remove back-to-front
    for i in finished_idx {
        let w = core.waiting.remove(i);
        let ps = w.prefill_start.expect("chunked request ran without start");
        core.finish_prefill(w.req, ps);
    }
}

/// One hybrid-batch layer pass: fused GEMMs over (ds + chunk) rows plus
/// the two attention kernels, serialized (lock-step).  `ctx` is the
/// TOTAL prior context (reload + cached): attention reads both alike —
/// the resident KV is re-read by every chunk either way — so pricing
/// takes the sum; the budget layer accounts the parts separately via
/// `HybridBatch::ctx_cached` / `ctx_reload()` (the cached part was
/// never computed here and paid a radix lookup instead).
fn hybrid_iteration_kernels(
    cfg: &ServingConfig,
    chunk: usize,
    ctx: usize,
    ds: usize,
    cl: usize,
) -> Vec<KernelDesc> {
    let model = &cfg.model;
    let mut out = Vec::new();
    for layer in 0..model.n_layers {
        if chunk > 0 {
            // the fused pass: GEMM rows = chunk + ds handled by issuing
            // the prefill-side GEMMs at (chunk + ds) tokens...
            for k in prefill_layer_kernels(model, PhaseShape { tokens: chunk + ds, context: ctx }) {
                // ...but attention splits: replace the unified attention
                // with chunk-attention only; decode attention added below.
                out.push(k.with_tag(layer as u32));
            }
        } else if ds > 0 {
            for k in prefill_layer_kernels(model, PhaseShape { tokens: ds, context: 0 }) {
                out.push(k.with_tag(layer as u32));
            }
        }
        if ds > 0 {
            // decode attention over each sequence's cache (not part of
            // the prefill attention above).
            let attn = decode_layer_kernels(model, PhaseShape { tokens: ds, context: cl })
                .into_iter()
                .nth(1)
                .unwrap();
            out.push(attn.with_tag(layer as u32));
        }
    }
    out
}

/// Chunked-prefill decision logic as a [`ServingPolicy`]: lock-step
/// hybrid batches on one whole-GPU lane.
pub struct ChunkedPolicy {
    ccfg: ChunkedConfig,
    /// The iteration currently in flight (None between iterations).
    batch: Option<HybridBatch>,
}

impl ChunkedPolicy {
    pub fn new(ccfg: ChunkedConfig) -> ChunkedPolicy {
        ChunkedPolicy { ccfg, batch: None }
    }
}

impl ServingPolicy for ChunkedPolicy {
    fn label(&self) -> String {
        self.ccfg.label.to_string()
    }

    fn plan(&mut self, core: &mut EngineCore) {
        if !core.all_idle() {
            return; // lock-step: plan only at iteration boundaries
        }
        // Finished prefills join decode right at the boundary (chunked
        // engines have no decode-batch cap beyond the token budget).
        core.join_pending(usize::MAX);
        let batch = build_hybrid_batch(core, self.ccfg.chunk_size);
        if batch.empty() {
            return; // idle or memory-stalled; pump handles the wait
        }
        let kernels = hybrid_iteration_kernels(
            &core.cfg,
            batch.chunk_tokens,
            batch.ctx_max,
            batch.ds,
            batch.cl,
        );
        // Lock-step execution of the fused pass on the full-GPU stream.
        let stream = core.rm.prefill_stream_for(core.cfg.gpu.num_sms);
        core.submit(Lane::Prefill, stream, kernels);
        self.batch = Some(batch);
    }

    fn on_drain(&mut self, lane: Lane, core: &mut EngineCore) {
        if lane != Lane::Prefill {
            return;
        }
        let batch = self.batch.take().expect("drain without an iteration");
        complete_hybrid_iteration(core, &batch, self.ccfg.iteration_overhead(&batch));
    }

    fn on_stall(&mut self, core: &mut EngineCore) -> bool {
        hybrid_stall(core)
    }

    fn has_private_work(&self) -> bool {
        self.batch.is_some()
    }

    // the in-flight batch's assignments index into `core.waiting`
    fn waiting_locked(&self) -> bool {
        self.batch.is_some()
    }
}

/// Serve `trace` with a chunked-prefill engine and return the full
/// engine output (records + prefix-cache counters + utilization).
pub fn serve_chunked_output(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> EngineOutput {
    let opts = CoreOptions {
        seed,
        // the pre-refactor baseline loops had no virtual-time cap
        max_virtual_time: f64::INFINITY,
        ..CoreOptions::default()
    };
    let mut core = EngineCore::new(cfg.clone(), gt.clone(), trace.to_vec(), &opts);
    let mut policy = ChunkedPolicy::new(ccfg.clone());
    core.run(&mut policy);
    core.into_output()
}

/// Serve `trace` with a chunked-prefill engine; same record format as
/// the Bullet engine so summaries are directly comparable.  (Thin
/// wrapper over [`serve_chunked_output`].)
pub fn serve_chunked(
    cfg: &ServingConfig,
    ccfg: &ChunkedConfig,
    gt: &GroundTruth,
    trace: &[Request],
    seed: u64,
) -> Vec<RequestRecord> {
    serve_chunked_output(cfg, ccfg, gt, trace, seed).records
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::metrics::summarize;
    use crate::workload::{generate_n_requests, Dataset};

    fn setup() -> (ServingConfig, GroundTruth) {
        (
            ServingConfig::default(),
            GroundTruth::new(GpuSpec::a100()),
        )
    }

    #[test]
    fn chunk_iteration_formula() {
        // N = ceil(sl / (cs - ds))
        assert_eq!(chunk_iterations(4096, 1024, 0), 4);
        assert_eq!(chunk_iterations(4096, 1024, 512), 8);
        assert_eq!(chunk_iterations(1, 1024, 0), 1);
        assert_eq!(chunk_iterations(4096, 1024, 1024), 4096); // fully starved
    }

    #[test]
    fn kv_reload_triangular() {
        assert_eq!(kv_reload_factor(1), 1);
        assert_eq!(kv_reload_factor(4), 10);
        assert_eq!(kv_reload_factor(16), 136);
    }

    #[test]
    fn serves_all_requests() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 5.0, 25, 21);
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 1);
        assert_eq!(recs.len(), 25);
        for r in &recs {
            assert!(r.first_token_time >= r.arrival);
            assert!(r.finish_time >= r.first_token_time);
        }
    }

    #[test]
    fn long_prompts_split_into_chunks() {
        let (cfg, gt) = setup();
        // one 8k prompt: with cs=1024 needs 8 iterations minimum.
        let trace = vec![Request { id: 0, arrival: 0.0, input_len: 8192, output_len: 2, ..Default::default() }];
        let r1024 = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 2);
        let r2048 = serve_chunked(&cfg, &ChunkedConfig::sglang_2048(), &gt, &trace, 2);
        // larger chunks finish prefill sooner (fewer reloads + fewer passes)
        assert!(
            r2048[0].ttft() < r1024[0].ttft(),
            "2048 {} vs 1024 {}",
            r2048[0].ttft(),
            r1024[0].ttft()
        );
    }

    #[test]
    fn decode_tokens_consume_budget() {
        // With a decode batch present, prefill gets less budget per
        // iteration — TTFT of a later request inflates.
        let (cfg, gt) = setup();
        let mut trace = vec![];
        // long-decode requests arrive first and occupy slots
        for i in 0..64 {
            trace.push(Request { id: i, arrival: 0.0, input_len: 64, output_len: 400, ..Default::default() });
        }
        trace.push(Request { id: 64, arrival: 1.0, input_len: 4096, output_len: 2, ..Default::default() });
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 3);
        let solo = serve_chunked(
            &cfg,
            &ChunkedConfig::sglang_1024(),
            &gt,
            &[Request { id: 0, arrival: 0.0, input_len: 4096, output_len: 2, ..Default::default() }],
            3,
        );
        let busy_ttft = recs.iter().find(|r| r.id == 64).unwrap().ttft();
        assert!(
            busy_ttft > 1.1 * solo[0].ttft(),
            "busy {busy_ttft} solo {}",
            solo[0].ttft()
        );
    }

    #[test]
    fn chunk_boundary_publication_serves_mid_prompt_arrivals() {
        use crate::testing::content_chain;
        // One long prompt chunk-prefills over many iterations; an
        // identical prompt arrives MID-prefill.  With chunk-boundary
        // publication the second request hits the already-computed
        // blocks instead of waiting for full-prompt completion.
        let (cfg, gt) = setup();
        let cfg = ServingConfig { prefix_cache: true, ..cfg };
        let nb = 512usize; // 8192 prompt tokens = 8+ chunks of 1024
        let contents: Vec<u64> = (0..nb as u64).collect();
        let hashes = content_chain(&contents);
        let input_len = nb * BLOCK_TOKENS + 8;
        let req = |id, arrival| Request {
            id,
            arrival,
            input_len,
            output_len: 2,
            block_hashes: hashes.clone(),
            session_id: Some(1),
            ..Default::default()
        };
        let trace = vec![req(0, 0.0), req(1, 0.2)];
        let out = serve_chunked_output(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 5);
        assert_eq!(out.records.len(), 2);
        let s = out.prefix;
        assert!(s.partial_insertions > 0, "no chunk-boundary publications: {s:?}");
        assert!(s.hits >= 1 && s.cached_tokens > 0, "mid-prompt arrival missed: {s:?}");
        assert!(
            s.partial_hits >= 1,
            "the hit must be attributed to partial publication: {s:?}"
        );
    }

    #[test]
    fn hybrid_batch_splits_cached_from_reload_context() {
        use crate::testing::content_chain;
        // Seed the cache with a 4-block prompt, then admit an identical
        // one: its first hybrid batch must report the adopted context
        // under ctx_cached (with the radix blocks charged) while a
        // cache-less request reports pure reload context.
        let cfg = ServingConfig { prefix_cache: true, ..ServingConfig::default() };
        let gt = GroundTruth::new(GpuSpec::a100());
        let hashes = content_chain(&[1, 2, 3, 4]);
        let input_len = 4 * BLOCK_TOKENS + 8;
        let warm = Request {
            id: 0,
            arrival: 0.0,
            input_len,
            output_len: 2,
            block_hashes: hashes.clone(),
            session_id: Some(1),
            ..Default::default()
        };
        let mut core = EngineCore::new(cfg, gt, vec![warm], &CoreOptions::default());
        let mut policy = ChunkedPolicy::new(ChunkedConfig::sglang_1024());
        core.run(&mut policy);
        // identical prompt arrives after the first published
        core.push_request(Request {
            id: 1,
            arrival: core.now() + 1.0,
            input_len,
            output_len: 2,
            block_hashes: hashes,
            session_id: Some(1),
            ..Default::default()
        });
        core.sim.run_for(2.0);
        core.admit_arrivals();
        assert_eq!(core.waiting[0].req.cached_len, 4 * BLOCK_TOKENS, "adoption expected");
        let batch = build_hybrid_batch(&mut core, 1024);
        assert_eq!(batch.ctx_cached, 4 * BLOCK_TOKENS);
        assert_eq!(batch.radix_blocks, 4, "radix walk charged once, at the first chunk");
        assert_eq!(batch.ctx_max, 4 * BLOCK_TOKENS, "done == cached at the first chunk");
        assert_eq!(batch.ctx_reload(), 0, "nothing reloaded yet: all prior context is adopted");
        // and the per-iteration overhead prices those blocks
        let ccfg = ChunkedConfig::sglang_1024();
        let expect = ccfg.iter_overhead + 4.0 * ccfg.radix_lookup_per_block;
        assert!((ccfg.iteration_overhead(&batch) - expect).abs() < 1e-15);
    }

    #[test]
    fn radix_lookup_overhead_lands_in_ttft() {
        use crate::testing::content_chain;
        // Two identical long prompts, the second arriving after the
        // first has fully published: it adopts ~512 blocks.  With a
        // deliberately large per-block radix cost, its TTFT must grow
        // by about blocks x cost relative to a free-lookup run — and
        // the cold first request must not pay a thing.
        let (cfg, gt) = setup();
        let cfg = ServingConfig { prefix_cache: true, ..cfg };
        let nb = 512usize;
        let contents: Vec<u64> = (0..nb as u64).collect();
        let hashes = content_chain(&contents);
        let input_len = nb * BLOCK_TOKENS + 8;
        let req = |id, arrival| Request {
            id,
            arrival,
            input_len,
            output_len: 2,
            block_hashes: hashes.clone(),
            session_id: Some(1),
            ..Default::default()
        };
        // arrival 30 s: far past the first prompt's completion, so the
        // whole prefix is published and adopted at admission
        let trace = vec![req(0, 0.0), req(1, 30.0)];
        let run = |per_block: f64| {
            let ccfg = ChunkedConfig {
                radix_lookup_per_block: per_block,
                ..ChunkedConfig::sglang_1024()
            };
            serve_chunked_output(&cfg, &ccfg, &gt, &trace, 5)
        };
        let free = run(0.0);
        let costly = run(1e-3);
        assert_eq!(free.records.len(), 2);
        assert_eq!(costly.records.len(), 2);
        // adoption happened (otherwise the test measures nothing)
        assert!(free.prefix.hits >= 1, "{:?}", free.prefix);
        let adopted_blocks = (input_len - 1) / BLOCK_TOKENS; // lookup cap
        let expected = adopted_blocks as f64 * 1e-3;
        let ttft = |out: &EngineOutput, id| {
            out.records.iter().find(|r| r.id == id).unwrap().ttft()
        };
        // the cold request pays nothing...
        assert_eq!(
            ttft(&free, 0),
            ttft(&costly, 0),
            "cold request must not pay the radix walk"
        );
        // ...the adopting request pays ~blocks x cost
        let delta = ttft(&costly, 1) - ttft(&free, 1);
        assert!(
            delta > 0.5 * expected && delta < 2.0 * expected,
            "radix overhead missing from TTFT accounting: delta {delta:.4}s \
             vs expected ~{expected:.4}s over {adopted_blocks} blocks"
        );
    }

    #[test]
    fn tpot_stable_under_small_chunks() {
        // The selling point of chunked prefill: decode latency stays
        // bounded because each iteration is budget-capped.
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 4.0, 30, 31);
        let recs = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 4);
        let s = summarize(&recs, &cfg.slo, None);
        assert!(s.mean_tpot < 0.5, "tpot {}", s.mean_tpot);
    }

    #[test]
    fn vllm_overhead_worse_than_sglang() {
        let (cfg, gt) = setup();
        let trace = generate_n_requests(&Dataset::sharegpt(), 6.0, 30, 41);
        let v = serve_chunked(&cfg, &ChunkedConfig::vllm_1024(), &gt, &trace, 5);
        let s = serve_chunked(&cfg, &ChunkedConfig::sglang_1024(), &gt, &trace, 5);
        let sv = summarize(&v, &cfg.slo, None);
        let ss = summarize(&s, &cfg.slo, None);
        assert!(sv.mean_ttft > ss.mean_ttft, "vllm {} sglang {}", sv.mean_ttft, ss.mean_ttft);
    }
}
