//! Timeline recording: time-bucketed samples of system state (prefill SM
//! allocation, concurrent tokens, waiting queue depth, utilization) —
//! the raw data behind the paper's Fig. 12.

/// One sampled instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineSample {
    pub t: f64,
    /// SMs currently provisioned to prefill.
    pub prefill_sms: usize,
    /// SMs currently provisioned to decode.
    pub decode_sms: usize,
    /// Tokens being prefilled this instant (0 when no active prefill).
    pub prefill_tokens: usize,
    /// Active decode batch size.
    pub decode_batch: usize,
    /// Requests waiting for prefill.
    pub waiting: usize,
    /// Whole-GPU compute utilization over the last window.
    pub compute_util: f64,
    /// Bandwidth utilization over the last window.
    pub bandwidth_util: f64,
    /// Online-calibration samples ingested so far (0 with calibration
    /// off — the counters ride the timeline so drift adaptation can be
    /// plotted against the partition trace).
    pub calib_samples: u64,
    /// Mean |predicted-observed|/predicted residual so far.
    pub calib_residual: f64,
}

/// Fleet-lifecycle actions the cluster autoscaler can take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Spawn a replica (capacity below the arrival-rate SLO envelope).
    ScaleOut,
    /// Drain and release a replica (sustained capacity surplus).
    ScaleIn,
    /// Deweight-and-drain a replica whose drift events keep firing
    /// (health-driven removal, as opposed to capacity-driven `ScaleIn`).
    Retire,
    /// Refresh a replica's offline perf grid in place (converged
    /// calibrator, persistently high residual).  Fleet size unchanged.
    Reprofile,
    /// Replica killed by failure injection: no drain, prefix-affinity
    /// sessions re-home via the retire machinery, and in-flight requests
    /// either re-queue elsewhere or are counted lost.
    Crash,
}

/// One autoscaler decision, stamped on the global virtual timeline.
/// Cluster runs surface these in `ClusterOutput::scale_events` and on
/// the affected replica's own [`Timeline`] / `EngineOutput`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScaleEvent {
    pub t: f64,
    pub action: ScaleAction,
    /// The replica acted on (the new replica's id for `ScaleOut`).
    pub replica: usize,
    /// Active (non-draining) fleet size after the action.
    pub fleet_after: usize,
}

/// Append-only timeline.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    samples: Vec<TimelineSample>,
    events: Vec<ScaleEvent>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline::default()
    }

    pub fn push(&mut self, s: TimelineSample) {
        debug_assert!(
            self.samples.last().map(|p| p.t <= s.t).unwrap_or(true),
            "timeline must be monotone"
        );
        self.samples.push(s);
    }

    /// Record a fleet-lifecycle event affecting this engine (recorded
    /// regardless of sample recording — lifecycle is always cheap).
    pub fn push_event(&mut self, e: ScaleEvent) {
        self.events.push(e);
    }

    /// Fleet-lifecycle events affecting this engine, in time order.
    pub fn events(&self) -> &[ScaleEvent] {
        &self.events
    }

    pub fn samples(&self) -> &[TimelineSample] {
        &self.samples
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Resample onto a uniform grid (nearest previous sample), for plotting.
    pub fn resample(&self, dt: f64) -> Vec<TimelineSample> {
        if self.samples.is_empty() {
            return Vec::new();
        }
        // A non-positive (or NaN) step would loop forever or never
        // terminate the grid walk; there is no meaningful resampling.
        if dt <= 0.0 || dt.is_nan() {
            return Vec::new();
        }
        let t0 = self.samples[0].t;
        let t1 = self.samples.last().unwrap().t;
        let mut out = Vec::new();
        let mut idx = 0;
        let mut t = t0;
        while t <= t1 {
            while idx + 1 < self.samples.len() && self.samples[idx + 1].t <= t {
                idx += 1;
            }
            let mut s = self.samples[idx];
            s.t = t;
            out.push(s);
            t += dt;
        }
        out
    }

    /// Mean of a field over the recorded span (duration-weighted).
    pub fn mean_of(&self, f: impl Fn(&TimelineSample) -> f64) -> f64 {
        if self.samples.len() < 2 {
            // empty timeline → 0.0 (NaN would poison downstream
            // aggregates that fold means together)
            return self.samples.first().map(|s| f(s)).unwrap_or(0.0);
        }
        let mut num = 0.0;
        let mut den = 0.0;
        for w in self.samples.windows(2) {
            let dt = w[1].t - w[0].t;
            num += f(&w[0]) * dt;
            den += dt;
        }
        if den <= 0.0 {
            f(&self.samples[0])
        } else {
            num / den
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: f64, sms: usize, waiting: usize) -> TimelineSample {
        TimelineSample {
            t,
            prefill_sms: sms,
            decode_sms: 108 - sms,
            prefill_tokens: 0,
            decode_batch: 0,
            waiting,
            compute_util: 0.0,
            bandwidth_util: 0.0,
            calib_samples: 0,
            calib_residual: 0.0,
        }
    }

    #[test]
    fn push_and_len() {
        let mut tl = Timeline::new();
        assert!(tl.is_empty());
        tl.push(s(0.0, 54, 0));
        tl.push(s(1.0, 84, 2));
        assert_eq!(tl.len(), 2);
    }

    #[test]
    fn resample_uniform() {
        let mut tl = Timeline::new();
        tl.push(s(0.0, 10, 0));
        tl.push(s(1.0, 20, 1));
        tl.push(s(3.0, 30, 2));
        let r = tl.resample(1.0);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].prefill_sms, 10);
        assert_eq!(r[1].prefill_sms, 20);
        assert_eq!(r[2].prefill_sms, 20); // holds previous value at t=2
        assert_eq!(r[3].prefill_sms, 30);
    }

    #[test]
    fn resample_rejects_degenerate_steps() {
        let mut tl = Timeline::new();
        tl.push(s(0.0, 10, 0));
        tl.push(s(1.0, 20, 1));
        for bad in [0.0, -1.0, f64::NAN] {
            assert!(tl.resample(bad).is_empty(), "dt={bad} must yield nothing");
        }
        assert!(Timeline::new().resample(1.0).is_empty());
    }

    #[test]
    fn mean_of_empty_timeline_is_zero() {
        let tl = Timeline::new();
        let m = tl.mean_of(|s| s.compute_util);
        assert_eq!(m, 0.0, "empty timeline must not produce NaN");
    }

    #[test]
    fn weighted_mean() {
        let mut tl = Timeline::new();
        tl.push(s(0.0, 100, 0));
        tl.push(s(1.0, 0, 0)); // value 100 held for 1s
        tl.push(s(3.0, 0, 0)); // value 0 held for 2s
        let m = tl.mean_of(|x| x.prefill_sms as f64);
        assert!((m - 100.0 / 3.0).abs() < 1e-9, "{m}");
    }

    #[test]
    fn empty_resample() {
        assert!(Timeline::new().resample(0.5).is_empty());
    }

    #[test]
    fn scale_events_ride_the_timeline() {
        let mut tl = Timeline::new();
        assert!(tl.events().is_empty());
        let out = ScaleEvent { t: 1.0, action: ScaleAction::ScaleOut, replica: 2, fleet_after: 3 };
        let ret = ScaleEvent { t: 9.0, action: ScaleAction::Retire, replica: 1, fleet_after: 2 };
        tl.push_event(out);
        tl.push_event(ret);
        assert_eq!(tl.events().len(), 2);
        assert_eq!(tl.events()[0].action, ScaleAction::ScaleOut);
        assert_eq!(tl.events()[1].fleet_after, 2);
        // events are independent of sample recording
        assert!(tl.is_empty());
    }
}
