//! Serving metrics: TTFT, TPOT, normalized TTFT, throughput, goodput /
//! SLO attainment, plus the timeline recorder behind Fig. 12.

pub mod timeline;

use crate::config::SloSpec;
use crate::util::stats;

/// Final per-request measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: f64,
    pub input_len: usize,
    pub output_len: usize,
    /// Time the first output token was produced (absolute).
    pub first_token_time: f64,
    /// Time the final token was produced (absolute).
    pub finish_time: f64,
    /// Time the prefill started executing (for queueing-delay analysis).
    pub prefill_start: f64,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token_time - self.arrival
    }

    /// TTFT per input token (paper's "normalized input latency"), seconds.
    pub fn norm_ttft(&self) -> f64 {
        self.ttft() / self.input_len.max(1) as f64
    }

    /// Mean time per output token after the first, seconds.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.finish_time - self.first_token_time) / (self.output_len - 1) as f64
    }

    pub fn queueing_delay(&self) -> f64 {
        self.prefill_start - self.arrival
    }

    pub fn e2e_latency(&self) -> f64 {
        self.finish_time - self.arrival
    }

    /// Both phase SLOs met (goodput definition, §4.1).
    pub fn meets_slo(&self, slo: &SloSpec) -> bool {
        self.ttft() <= slo.ttft_budget(self.input_len) && self.tpot() <= slo.tpot_budget()
    }
}

/// Why a request left the system without completing.  Completed requests
/// produce a [`RequestRecord`]; every other exit produces an
/// [`OutcomeRecord`] instead — the two streams partition the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Client disconnected (`Request::cancel_at`); KV released mid-flight.
    Cancelled,
    /// Completion deadline passed (`Request::deadline`) before the request
    /// finished; dropped rather than serving a late answer.
    Expired,
    /// In flight on a replica that crashed and not recoverable by
    /// re-queueing (prefill progress was lost with the replica).
    Lost,
}

/// Terminal event for a request that did not complete.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutcomeRecord {
    pub id: u64,
    pub outcome: RequestOutcome,
    /// Instant the request left the system (virtual-clock seconds).
    pub t: f64,
    /// Output tokens already produced (and streamed) before the exit.
    pub tokens_out: usize,
}

/// Per-outcome counters for one run; `submitted()` is the conservation
/// check every lifecycle test asserts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LifecycleStats {
    pub completed: usize,
    pub cancelled: usize,
    pub expired: usize,
    pub lost: usize,
}

impl LifecycleStats {
    /// Count outcomes: every submitted request is exactly one of
    /// completed / cancelled / expired / lost.
    pub fn from_parts(records: &[RequestRecord], outcomes: &[OutcomeRecord]) -> LifecycleStats {
        let mut s = LifecycleStats {
            completed: records.len(),
            ..LifecycleStats::default()
        };
        for o in outcomes {
            match o.outcome {
                RequestOutcome::Cancelled => s.cancelled += 1,
                RequestOutcome::Expired => s.expired += 1,
                RequestOutcome::Lost => s.lost += 1,
            }
        }
        s
    }

    pub fn submitted(&self) -> usize {
        self.completed + self.cancelled + self.expired + self.lost
    }
}

/// Merge per-replica outcome streams into one id-ordered stream, the
/// non-completion counterpart of [`merge_records`].
pub fn merge_outcomes<'a>(
    parts: impl IntoIterator<Item = &'a [OutcomeRecord]>,
) -> Vec<OutcomeRecord> {
    let mut out: Vec<OutcomeRecord> = parts.into_iter().flat_map(|p| p.iter().copied()).collect();
    out.sort_by_key(|o| o.id);
    out
}

/// Aggregated results for one serving run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub n_requests: usize,
    pub duration: f64,
    pub mean_ttft: f64,
    pub p90_ttft: f64,
    pub p99_ttft: f64,
    pub mean_norm_ttft: f64,
    pub mean_tpot: f64,
    pub p90_tpot: f64,
    pub mean_queueing: f64,
    /// Output tokens per second over the run.
    pub throughput_tok_s: f64,
    /// Requests per second completed.
    pub throughput_req_s: f64,
    /// Fraction of requests meeting both SLOs.
    pub slo_attainment: f64,
    pub mean_e2e: f64,
}

/// Merge per-replica record streams into one id-ordered stream, directly
/// comparable (and summarizable) like a single-GPU run.
pub fn merge_records<'a>(
    parts: impl IntoIterator<Item = &'a [RequestRecord]>,
) -> Vec<RequestRecord> {
    let mut out: Vec<RequestRecord> = parts
        .into_iter()
        .flat_map(|p| p.iter().cloned())
        .collect();
    out.sort_by_key(|r| r.id);
    out
}

/// Goodput (§4.1): requests meeting both SLOs, per second.
pub fn goodput_req_s(records: &[RequestRecord], slo: &SloSpec, duration: Option<f64>) -> f64 {
    let s = summarize(records, slo, duration);
    s.slo_attainment * s.throughput_req_s
}

/// Summarize a completed run.  `duration` defaults to the span from first
/// arrival to last finish when `None`.
pub fn summarize(records: &[RequestRecord], slo: &SloSpec, duration: Option<f64>) -> RunSummary {
    assert!(!records.is_empty(), "summarize() on empty run");
    let ttfts: Vec<f64> = records.iter().map(|r| r.ttft()).collect();
    let norm_ttfts: Vec<f64> = records.iter().map(|r| r.norm_ttft()).collect();
    let tpots: Vec<f64> = records
        .iter()
        .filter(|r| r.output_len > 1)
        .map(|r| r.tpot())
        .collect();
    let queueing: Vec<f64> = records.iter().map(|r| r.queueing_delay()).collect();
    let e2e: Vec<f64> = records.iter().map(|r| r.e2e_latency()).collect();
    let total_tokens: usize = records.iter().map(|r| r.output_len).sum();
    let start = records
        .iter()
        .map(|r| r.arrival)
        .fold(f64::INFINITY, f64::min);
    let end = records
        .iter()
        .map(|r| r.finish_time)
        .fold(f64::NEG_INFINITY, f64::max);
    let duration = duration.unwrap_or(end - start).max(1e-9);
    let met = records.iter().filter(|r| r.meets_slo(slo)).count();
    RunSummary {
        n_requests: records.len(),
        duration,
        mean_ttft: stats::mean(&ttfts),
        p90_ttft: stats::percentile(&ttfts, 90.0),
        p99_ttft: stats::percentile(&ttfts, 99.0),
        mean_norm_ttft: stats::mean(&norm_ttfts),
        mean_tpot: if tpots.is_empty() { 0.0 } else { stats::mean(&tpots) },
        p90_tpot: if tpots.is_empty() { 0.0 } else { stats::percentile(&tpots, 90.0) },
        mean_queueing: stats::mean(&queueing),
        throughput_tok_s: total_tokens as f64 / duration,
        throughput_req_s: records.len() as f64 / duration,
        slo_attainment: met as f64 / records.len() as f64,
        mean_e2e: stats::mean(&e2e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, prefill_start: f64, first: f64, finish: f64, il: usize, ol: usize) -> RequestRecord {
        RequestRecord {
            id: 0,
            arrival,
            input_len: il,
            output_len: ol,
            first_token_time: first,
            finish_time: finish,
            prefill_start,
        }
    }

    #[test]
    fn ttft_tpot_basic() {
        let r = rec(1.0, 1.2, 1.5, 2.5, 100, 11);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
        assert!((r.norm_ttft() - 0.005).abs() < 1e-12);
        assert!((r.queueing_delay() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn single_token_output_tpot_zero() {
        let r = rec(0.0, 0.0, 0.2, 0.2, 10, 1);
        assert_eq!(r.tpot(), 0.0);
    }

    #[test]
    fn slo_check() {
        let slo = SloSpec {
            norm_ttft_ms_per_token: 2.0,
            tpot_ms: 100.0,
        };
        // budget for 100 tokens: 0.2 s TTFT, 0.1 s TPOT
        let ok = rec(0.0, 0.0, 0.15, 1.0, 100, 11); // tpot 0.085
        let bad_ttft = rec(0.0, 0.0, 0.5, 1.0, 100, 11);
        let bad_tpot = rec(0.0, 0.0, 0.1, 3.0, 100, 11);
        assert!(ok.meets_slo(&slo));
        assert!(!bad_ttft.meets_slo(&slo));
        assert!(!bad_tpot.meets_slo(&slo));
    }

    #[test]
    fn summary_throughput() {
        let slo = SloSpec::sharegpt();
        let records = vec![
            rec(0.0, 0.0, 0.1, 1.0, 50, 10),
            rec(0.5, 0.6, 0.7, 2.0, 50, 30),
        ];
        let s = summarize(&records, &slo, Some(2.0));
        assert_eq!(s.n_requests, 2);
        assert!((s.throughput_tok_s - 20.0).abs() < 1e-9);
        assert!((s.throughput_req_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn summary_duration_inferred() {
        let slo = SloSpec::sharegpt();
        let records = vec![rec(1.0, 1.0, 1.5, 3.0, 10, 5)];
        let s = summarize(&records, &slo, None);
        assert!((s.duration - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_records_orders_by_id() {
        let a = vec![rec(0.0, 0.0, 0.1, 0.5, 10, 2)];
        let mut b = vec![rec(0.0, 0.0, 0.2, 0.6, 10, 2)];
        b[0].id = 5;
        let mut c = vec![rec(0.0, 0.0, 0.3, 0.7, 10, 2)];
        c[0].id = 2;
        let merged = merge_records([b.as_slice(), a.as_slice(), c.as_slice()]);
        let ids: Vec<u64> = merged.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 2, 5]);
    }

    #[test]
    fn goodput_counts_only_slo_met() {
        let slo = SloSpec {
            norm_ttft_ms_per_token: 2.0,
            tpot_ms: 100.0,
        };
        let records = vec![
            rec(0.0, 0.0, 0.1, 0.5, 100, 5), // ok
            rec(0.0, 0.0, 5.0, 9.0, 100, 5), // ttft violated
        ];
        let g = goodput_req_s(&records, &slo, Some(2.0));
        assert!((g - 0.5).abs() < 1e-12, "goodput {g}");
    }

    #[test]
    fn lifecycle_stats_partition_submitted() {
        let records = vec![rec(0.0, 0.0, 0.1, 0.5, 10, 2)];
        let outcomes = vec![
            OutcomeRecord { id: 1, outcome: RequestOutcome::Cancelled, t: 0.3, tokens_out: 1 },
            OutcomeRecord { id: 2, outcome: RequestOutcome::Expired, t: 0.4, tokens_out: 0 },
            OutcomeRecord { id: 3, outcome: RequestOutcome::Lost, t: 0.5, tokens_out: 2 },
            OutcomeRecord { id: 4, outcome: RequestOutcome::Cancelled, t: 0.6, tokens_out: 0 },
        ];
        let s = LifecycleStats::from_parts(&records, &outcomes);
        assert_eq!(s.completed, 1);
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(s.lost, 1);
        assert_eq!(s.submitted(), 5);
    }

    #[test]
    fn merge_outcomes_orders_by_id() {
        let a = vec![OutcomeRecord { id: 7, outcome: RequestOutcome::Lost, t: 1.0, tokens_out: 0 }];
        let b = vec![OutcomeRecord { id: 3, outcome: RequestOutcome::Cancelled, t: 0.5, tokens_out: 1 }];
        let merged = merge_outcomes([a.as_slice(), b.as_slice()]);
        let ids: Vec<u64> = merged.iter().map(|o| o.id).collect();
        assert_eq!(ids, vec![3, 7]);
    }

    #[test]
    fn attainment_fraction() {
        let slo = SloSpec {
            norm_ttft_ms_per_token: 2.0,
            tpot_ms: 100.0,
        };
        let records = vec![
            rec(0.0, 0.0, 0.1, 0.5, 100, 5),  // ok
            rec(0.0, 0.0, 5.0, 9.0, 100, 5),  // ttft violated
        ];
        let s = summarize(&records, &slo, None);
        assert!((s.slo_attainment - 0.5).abs() < 1e-12);
    }
}
