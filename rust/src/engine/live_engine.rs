//! Live mode: real concurrent prefill/decode engines over the PJRT
//! runtime (§3.5's architecture on real compute).
//!
//! Two OS threads own the two phases.  They coordinate exclusively
//! through the shared [`MetadataBuffer`] (status heartbeats + the
//! copy-free handoff queue) — no central controller — and share the
//! KV pool inside [`ModelRuntime`], mirroring the paper's
//! shared-GPU-memory design.
//!
//! Requests are the same [`workload::Request`] the simulators consume
//! (prompt token ids travel alongside, index-aligned), so one trace —
//! lifecycle annotations included — drives the simulator, the gateway,
//! and the real model: `cancel_at` (the client disconnect) and
//! `deadline` are honored on both engine threads, releasing KV and
//! counting the request instead of recording it.
//!
//! Honest scope note: the CPU PJRT client executes one computation at a
//! time, so the runtime sits behind a mutex and the *spatial* sharing of
//! compute is the simulator's domain (`sim_engine`).  What live mode
//! proves end-to-end is the paper's system architecture: decentralized
//! engines, metadata-buffer coordination, copy-free prefill→decode
//! migration, continuous batching, and Python-free serving.
//!
//! [`workload::Request`]: crate::workload::Request

use crate::engine::metadata::{Handoff, MetadataBuffer};
use crate::metrics::RequestRecord;
use crate::runtime::ModelRuntime;
use crate::util::error::Result;
use crate::workload::Request;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live serving statistics beyond the per-request records.
#[derive(Debug, Clone, Default)]
pub struct LiveStats {
    pub decode_iterations: u64,
    pub max_batch_seen: usize,
    pub handoff_latency_mean: f64,
    /// Requests whose client disconnected (`Request::cancel_at`).
    pub cancelled: usize,
    /// Requests dropped at their `Request::deadline`.
    pub expired: usize,
}

/// Mutex-guarded runtime that may cross threads.
///
/// SAFETY: `ModelRuntime` is `!Send` because the `xla` crate's client is
/// `Rc`-based and PJRT handles are raw pointers.  Every access to the
/// runtime — including creation/drop of PJRT temporaries inside
/// `prefill`/`decode`/`release` — happens while holding this mutex, so no
/// two threads ever touch the `Rc` counters or C handles concurrently;
/// the final drop occurs on the parent thread after both engine threads
/// have been joined.
struct SharedRuntime(Mutex<ModelRuntime>);
unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    fn lock(&self) -> std::sync::MutexGuard<'_, ModelRuntime> {
        self.0.lock().unwrap()
    }
}

/// True when the lifecycle says this request is over at `now`:
/// cancellation first (the disconnect already happened), deadline next.
/// Returns `Some(true)` for cancel, `Some(false)` for expiry.
fn lifecycle_due(cancel_at: Option<f64>, deadline: Option<f64>, now: f64) -> Option<bool> {
    if matches!(cancel_at, Some(t) if t <= now) {
        return Some(true);
    }
    if matches!(deadline, Some(d) if d <= now) {
        return Some(false);
    }
    None
}

/// Serve a trace on the live engines; blocks until completion.
/// `prompts[i]` holds the already-tokenized prompt of `trace[i]`.
/// Completed requests yield records; cancelled/expired ones are counted
/// in [`LiveStats`] — every submitted request ends exactly once.
pub fn serve_live(
    runtime: ModelRuntime,
    trace: Vec<Request>,
    prompts: Vec<Vec<i32>>,
) -> Result<(Vec<RequestRecord>, LiveStats)> {
    assert_eq!(trace.len(), prompts.len(), "one prompt per request");
    let rt = Arc::new(SharedRuntime(Mutex::new(runtime)));
    let meta = Arc::new(MetadataBuffer::new());
    let records = Arc::new(Mutex::new(Vec::<RequestRecord>::new()));
    let cancelled = Arc::new(AtomicUsize::new(0));
    let expired = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let n_requests = trace.len();
    let max_batch = rt.lock().max_batch();

    // ---------------- prefill engine ----------------
    let p_rt = rt.clone();
    let p_meta = meta.clone();
    let p_records = records.clone();
    let p_cancelled = cancelled.clone();
    let p_expired = expired.clone();
    let prefill = std::thread::Builder::new()
        .name("bullet-prefill".into())
        .spawn(move || -> Result<()> {
            for (req, prompt) in trace.into_iter().zip(prompts) {
                // wait for arrival
                loop {
                    let now = t0.elapsed().as_secs_f64();
                    if now >= req.arrival {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs_f64(
                        (req.arrival - now).min(0.002),
                    ));
                }
                // lifecycle check before any GPU work: a disconnected or
                // already-expired request never prefills
                match lifecycle_due(req.cancel_at, req.deadline, t0.elapsed().as_secs_f64()) {
                    Some(true) => {
                        p_cancelled.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    Some(false) => {
                        p_expired.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    None => {}
                }
                p_meta.publish_prefill(prompt.len(), 0, 0);
                let prefill_start = t0.elapsed().as_secs_f64();
                let first = {
                    let mut rt = p_rt.lock();
                    rt.prefill(req.id, &prompt)?
                };
                let first_token_time = t0.elapsed().as_secs_f64();
                // the disconnect/deadline may have landed mid-prefill:
                // release the KV instead of migrating a dead request
                if let Some(cancel) = lifecycle_due(req.cancel_at, req.deadline, first_token_time) {
                    let mut rt = p_rt.lock();
                    rt.release(req.id)?;
                    if cancel {
                        p_cancelled.fetch_add(1, Ordering::Relaxed);
                    } else {
                        p_expired.fetch_add(1, Ordering::Relaxed);
                    }
                } else if req.output_len <= 1 {
                    let mut rt = p_rt.lock();
                    rt.release(req.id)?;
                    p_records.lock().unwrap().push(RequestRecord {
                        id: req.id,
                        arrival: req.arrival,
                        input_len: prompt.len(),
                        output_len: req.output_len,
                        first_token_time,
                        finish_time: first_token_time,
                        prefill_start,
                    });
                } else {
                    // copy-free migration: only metadata travels.
                    p_meta.push_handoff(Handoff {
                        req_id: req.id,
                        seq_id: req.id,
                        input_len: prompt.len(),
                        output_len: req.output_len,
                        first_token: first,
                        first_token_time,
                        arrival: req.arrival,
                        prefill_start,
                        cancel_at: req.cancel_at,
                        deadline: req.deadline,
                    });
                }
                p_meta.publish_prefill(0, 0, 0);
            }
            p_meta.request_shutdown(); // no more prefills
            Ok(())
        })
        .expect("spawn prefill");

    // ---------------- decode engine ----------------
    let d_rt = rt.clone();
    let d_meta = meta.clone();
    let d_records = records.clone();
    let d_cancelled = cancelled.clone();
    let d_expired = expired.clone();
    let decode = std::thread::Builder::new()
        .name("bullet-decode".into())
        .spawn(move || -> Result<LiveStats> {
            struct Active {
                h: Handoff,
                last_token: i32,
                tokens_out: usize,
            }
            let mut batch: Vec<Active> = Vec::new();
            let mut stats = LiveStats::default();
            let mut handoff_lat = Vec::new();
            loop {
                // join migrated requests at the iteration boundary
                for h in d_meta.drain_handoffs(max_batch - batch.len()) {
                    handoff_lat.push(t0.elapsed().as_secs_f64() - h.first_token_time);
                    batch.push(Active {
                        last_token: h.first_token,
                        tokens_out: 1,
                        h,
                    });
                }
                // lifecycle sweep at the iteration boundary: cancelled
                // or expired slots release their KV mid-decode and leave
                // the batch before the next iteration is launched
                let sweep_t = t0.elapsed().as_secs_f64();
                let mut i = 0;
                while i < batch.len() {
                    match lifecycle_due(batch[i].h.cancel_at, batch[i].h.deadline, sweep_t) {
                        Some(cancel) => {
                            let a = batch.remove(i);
                            {
                                let mut rt = d_rt.lock();
                                rt.release(a.h.seq_id)?;
                            }
                            if cancel {
                                d_cancelled.fetch_add(1, Ordering::Relaxed);
                            } else {
                                d_expired.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        None => i += 1,
                    }
                }
                if batch.is_empty() {
                    if d_meta.is_shutdown() && d_meta.pending_handoffs() == 0 {
                        break;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                    continue;
                }
                let seqs: Vec<u64> = batch.iter().map(|a| a.h.seq_id).collect();
                let toks: Vec<i32> = batch.iter().map(|a| a.last_token).collect();
                let iter_t0 = Instant::now();
                let next = {
                    let mut rt = d_rt.lock();
                    rt.decode(&seqs, &toks)?
                };
                stats.decode_iterations += 1;
                stats.max_batch_seen = stats.max_batch_seen.max(batch.len());
                let ctx_sum: usize = batch.iter().map(|a| a.h.input_len + a.tokens_out).sum();
                d_meta.publish_decode(batch.len(), ctx_sum, iter_t0.elapsed().as_secs_f64());

                // First apply every slot's new token, THEN retire the
                // finished ones (removing mid-application would desync
                // `next` indices from batch slots).
                for (a, &t) in batch.iter_mut().zip(&next) {
                    a.last_token = t;
                    a.tokens_out += 1;
                }
                let finish_time = t0.elapsed().as_secs_f64();
                let mut i = 0;
                while i < batch.len() {
                    if batch[i].tokens_out >= batch[i].h.output_len {
                        let a = batch.remove(i);
                        {
                            let mut rt = d_rt.lock();
                            rt.release(a.h.seq_id)?;
                        }
                        d_records.lock().unwrap().push(RequestRecord {
                            id: a.h.req_id,
                            arrival: a.h.arrival,
                            input_len: a.h.input_len,
                            output_len: a.h.output_len,
                            first_token_time: a.h.first_token_time,
                            finish_time,
                            prefill_start: a.h.prefill_start,
                        });
                    } else {
                        i += 1;
                    }
                }
            }
            stats.handoff_latency_mean = if handoff_lat.is_empty() {
                0.0
            } else {
                handoff_lat.iter().sum::<f64>() / handoff_lat.len() as f64
            };
            Ok(stats)
        })
        .expect("spawn decode");

    prefill.join().expect("prefill panicked")?;
    let mut stats = decode.join().expect("decode panicked")?;
    let records = Arc::try_unwrap(records)
        .expect("records still shared")
        .into_inner()
        .unwrap();
    stats.cancelled = cancelled.load(Ordering::Relaxed);
    stats.expired = expired.load(Ordering::Relaxed);
    assert_eq!(
        records.len() + stats.cancelled + stats.expired,
        n_requests,
        "live engine lost requests"
    );
    Ok((records, stats))
}
